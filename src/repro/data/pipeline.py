"""brTPF-backed training data plane.

Data curation is expressed as BGP queries over a *metadata triple store*
(doc -> hasDomain / hasQuality / hasLang triples). The pipeline executes
the selection through the actual brTPF client, so example selection
inherits the paper's network-load reduction: on a sharded corpus the
bindings (candidate doc ids) travel to the metadata store instead of the
full posting lists traveling to the trainer.

The token payloads themselves are synthetic (this container has no
corpus); the selection path is the real integration point.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List

import numpy as np

from ..core import (BGP, BrTPFClient, BrTPFServer, ServerConfig,
                    TermDictionary, TripleStore, parse_bgp)


@dataclasses.dataclass
class SyntheticCorpus:
    """Documents with metadata triples + deterministic synthetic tokens."""

    dictionary: TermDictionary
    store: TripleStore
    doc_ids: List[int]                  # term ids of doc entities
    doc_lengths: Dict[int, int]
    vocab_size: int
    seed: int = 0

    @classmethod
    def generate(cls, num_docs: int = 200, vocab_size: int = 1024,
                 seed: int = 0) -> "SyntheticCorpus":
        rng = np.random.default_rng(seed)
        d = TermDictionary()
        HAS_DOMAIN = d.intern("hasDomain")
        HAS_QUALITY = d.intern("hasQuality")
        HAS_LANG = d.intern("hasLang")
        TYPE = d.intern("type")
        DOC = d.intern("Document")
        domains = [d.intern(x) for x in
                   ("web", "code", "science", "news", "books")]
        quals = [d.intern(f"q{i}") for i in range(5)]
        langs = [d.intern(x) for x in ("en", "de", "es")]
        rows, doc_ids, lengths = [], [], {}
        for i in range(num_docs):
            doc = d.intern(f"doc{i}")
            doc_ids.append(doc)
            rows.append((doc, TYPE, DOC))
            rows.append((doc, HAS_DOMAIN,
                         domains[int(rng.integers(len(domains)))]))
            rows.append((doc, HAS_QUALITY,
                         quals[int(rng.zipf(1.5) - 1) % 5]))
            rows.append((doc, HAS_LANG,
                         langs[int(rng.integers(len(langs)))]))
            lengths[doc] = int(rng.integers(64, 512))
        return cls(d, TripleStore(np.asarray(rows, np.int32)), doc_ids,
                   lengths, vocab_size, seed)

    def tokens_for(self, doc_id: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 1_000_003 + doc_id)
        return rng.integers(
            1, self.vocab_size,
            size=self.doc_lengths.get(doc_id, 128)).astype(np.int32)


@dataclasses.dataclass
class PipelineStats:
    num_requests: int = 0
    data_received: int = 0
    selected_docs: int = 0


class BrTPFDataPipeline:
    """Select documents with a BGP via brTPF; stream packed LM batches."""

    def __init__(self, corpus: SyntheticCorpus, selection_query: str,
                 batch_size: int, seq_len: int,
                 max_mpr: int = 30, seed: int = 0) -> None:
        self.corpus = corpus
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.seed = seed
        self.server = BrTPFServer(corpus.store,
                                  ServerConfig(max_mpr=max_mpr))
        self.bgp = parse_bgp(selection_query, corpus.dictionary)
        self.stats = PipelineStats()
        self._selected = self._select()

    def _select(self) -> List[int]:
        client = BrTPFClient(self.server)
        res = client.execute(self.bgp)
        self.stats.num_requests = res.num_requests
        self.stats.data_received = res.data_received
        # by convention the first variable of the query binds the doc
        docs = sorted({int(row[0]) for row in res.solutions})
        self.stats.selected_docs = len(docs)
        if not docs:
            raise ValueError("selection query matched no documents")
        return docs

    @property
    def selected_docs(self) -> List[int]:
        return list(self._selected)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self.batches()

    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        """Infinite stream of packed {tokens, targets} batches."""
        rng = np.random.default_rng(self.seed)
        buf = np.empty((0,), np.int32)
        need = self.batch_size * (self.seq_len + 1)
        while True:
            while buf.shape[0] < need:
                doc = self._selected[int(rng.integers(
                    len(self._selected)))]
                buf = np.concatenate([buf, self.corpus.tokens_for(doc)])
            chunk = buf[:need].reshape(self.batch_size, self.seq_len + 1)
            buf = buf[need:]
            yield {"tokens": chunk[:, :-1], "targets": chunk[:, 1:]}
