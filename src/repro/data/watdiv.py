"""WatDiv-like synthetic RDF dataset + diverse BGP query workload.

The paper's experiments use the Waterloo SPARQL Diversity Test Suite
(WatDiv) 10M-triple dataset and 145 BGP queries drawn uniformly at random
from its stress-test workload (section 5.2). WatDiv itself is not
available offline, so this module generates a *structurally analogous*
e-commerce graph (users, products, reviews, retailers, genres, cities)
with zipfian degree distributions, plus a stress-style query workload
covering WatDiv's four template families:

  L (linear/path), S (star), F (snowflake), C (complex).

Scale is configurable; benchmarks default to ~100K triples so the full
TPF-client request explosion stays tractable on one CPU core. The
relative TPF-vs-brTPF effects the paper reports are scale-free (they are
driven by intermediate-result sizes, which the zipfian skew preserves).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from ..core.bgp import BGP, parse_bgp
from ..core.rdf import TermDictionary
from ..core.store import TripleStore


@dataclasses.dataclass
class WatDivScale:
    users: int = 1000
    products: int = 500
    reviews: int = 1500
    retailers: int = 20
    genres: int = 25
    cities: int = 40
    tags: int = 60
    likes_per_user: float = 4.0
    friends_per_user: float = 2.0
    zipf_a: float = 1.6          # product-popularity skew


@dataclasses.dataclass
class WatDivData:
    dictionary: TermDictionary
    store: TripleStore
    scale: WatDivScale

    @property
    def num_triples(self) -> int:
        return len(self.store)


def _zipf_choice(rng, n, size, a):
    """Zipf-skewed choice over range(n)."""
    ranks = rng.zipf(a, size=size)
    return np.minimum(ranks - 1, n - 1).astype(np.int64)


def generate(scale: Optional[WatDivScale] = None,
             seed: int = 0) -> WatDivData:
    if scale is None:
        scale = WatDivScale()
    rng = np.random.default_rng(seed)
    d = TermDictionary()
    rows: List[Tuple[int, int, int]] = []

    # entity ids
    users = [d.intern(f"user{i}") for i in range(scale.users)]
    prods = [d.intern(f"product{i}") for i in range(scale.products)]
    revs = [d.intern(f"review{i}") for i in range(scale.reviews)]
    rets = [d.intern(f"retailer{i}") for i in range(scale.retailers)]
    genres = [d.intern(f"genre{i}") for i in range(scale.genres)]
    cities = [d.intern(f"city{i}") for i in range(scale.cities)]
    tags = [d.intern(f"tag{i}") for i in range(scale.tags)]
    ratings = [d.intern(f"rating{i}") for i in range(1, 6)]

    # predicates / classes
    TYPE = d.intern("type")
    LIKES = d.intern("likes")
    FRIEND = d.intern("friendOf")
    LIVES = d.intern("livesIn")
    GENRE = d.intern("hasGenre")
    TAG = d.intern("hasTag")
    SOLD = d.intern("soldBy")
    REVIEWS = d.intern("reviewsProduct")
    AUTHOR = d.intern("hasAuthor")
    RATING = d.intern("hasRating")
    C_USER, C_PROD, C_REV, C_RET = (d.intern(c) for c in
                                    ("User", "Product", "Review",
                                     "Retailer"))

    add = rows.append
    for u in users:
        add((u, TYPE, C_USER))
        add((u, LIVES, cities[int(rng.integers(len(cities)))]))
        n_likes = 1 + rng.poisson(scale.likes_per_user - 1)
        for p_idx in _zipf_choice(rng, len(prods), n_likes, scale.zipf_a):
            add((u, LIKES, prods[int(p_idx)]))
        n_fr = rng.poisson(scale.friends_per_user)
        for f_idx in rng.integers(0, len(users), n_fr):
            if users[int(f_idx)] != u:
                add((u, FRIEND, users[int(f_idx)]))
    for p in prods:
        add((p, TYPE, C_PROD))
        add((p, GENRE, genres[int(_zipf_choice(rng, len(genres), 1, 1.4)[0])]))
        add((p, SOLD, rets[int(rng.integers(len(rets)))]))
        for t_idx in rng.choice(len(tags), size=int(rng.integers(1, 4)),
                                replace=False):
            add((p, TAG, tags[int(t_idx)]))
    for r in revs:
        add((r, TYPE, C_REV))
        add((r, REVIEWS,
             prods[int(_zipf_choice(rng, len(prods), 1, scale.zipf_a)[0])]))
        add((r, AUTHOR, users[int(rng.integers(len(users)))]))
        add((r, RATING, ratings[int(rng.integers(len(ratings)))]))
    for rt in rets:
        add((rt, TYPE, C_RET))

    triples = np.asarray(rows, dtype=np.int32)
    return WatDivData(d, TripleStore(triples), scale)


# ---------------------------------------------------------------------------
# Stress-style query workload (four WatDiv template families)
# ---------------------------------------------------------------------------

_TEMPLATES = [
    # -- L: linear / path ---------------------------------------------------
    ("L1", "?u likes ?p\n?p hasGenre {genre}"),
    ("L2", "?u friendOf ?v\n?v livesIn {city}"),
    ("L3", "?r reviewsProduct ?p\n?p soldBy {retailer}"),
    ("L4", "?u friendOf ?v\n?v likes ?p\n?p hasGenre {genre}"),
    # -- S: star ------------------------------------------------------------
    ("S1", "?p hasGenre {genre}\n?p soldBy ?r\n?p hasTag ?t"),
    ("S2", "?u type User\n?u livesIn {city}\n?u likes ?p"),
    ("S3", "?r reviewsProduct {product}\n?r hasRating ?g\n?r hasAuthor ?u"),
    ("S4", "?p type Product\n?p hasTag {tag}\n?p soldBy ?ret"),
    # -- F: snowflake ---------------------------------------------------------
    ("F1", "?r reviewsProduct ?p\n?r hasAuthor ?u\n?p hasGenre {genre}\n"
           "?u livesIn ?c"),
    ("F2", "?u likes ?p\n?u livesIn {city}\n?p soldBy ?ret\n?p hasTag ?t"),
    ("F3", "?r reviewsProduct ?p\n?r hasRating {rating}\n?p hasGenre ?g\n"
           "?p soldBy {retailer}"),
    # -- C: complex -----------------------------------------------------------
    ("C1", "?u likes ?p\n?r reviewsProduct ?p\n?r hasAuthor ?v\n"
           "?v livesIn {city}\n?p hasGenre ?g"),
    ("C2", "?u friendOf ?v\n?u likes ?p\n?v likes ?p\n?p hasGenre {genre}"),
    ("C3", "?r reviewsProduct ?p\n?r hasAuthor ?u\n?u friendOf ?v\n"
           "?p hasTag {tag}\n?r hasRating {rating}"),
]


def generate_workload(data: WatDivData, num_queries: int = 145,
                      seed: int = 1) -> List[Tuple[str, BGP]]:
    """Draw queries uniformly at random from the template families with
    random constant instantiation (the paper's 145-query selection)."""
    rng = np.random.default_rng(seed)
    s = data.scale
    out: List[Tuple[str, BGP]] = []
    for _ in range(num_queries):
        name, tmpl = _TEMPLATES[int(rng.integers(len(_TEMPLATES)))]
        q = tmpl.format(
            genre=f"genre{int(_zipf_choice(rng, s.genres, 1, 1.4)[0])}",
            city=f"city{int(rng.integers(s.cities))}",
            retailer=f"retailer{int(rng.integers(s.retailers))}",
            product=f"product{int(_zipf_choice(rng, s.products, 1, 1.6)[0])}",
            tag=f"tag{int(rng.integers(s.tags))}",
            rating=f"rating{int(rng.integers(1, 6))}",
        )
        out.append((name, parse_bgp(q, data.dictionary)))
    return out
