"""Data plane: WatDiv-like workloads + brTPF-backed training pipeline."""
