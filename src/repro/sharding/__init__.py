"""Sharding: logical-axis rules mapping models onto meshes."""
from .rules import (active, constrain, default_rules, param_shardings,
                    spec_for, use_rules)

__all__ = ["active", "constrain", "default_rules", "param_shardings",
           "spec_for", "use_rules"]
