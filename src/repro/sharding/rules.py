"""Logical-axis sharding: one model definition, any mesh.

Parameters and activations are annotated with *logical* axis names
("embed", "ff", "heads", "experts", "batch", ...). A ``Rules`` object
maps logical names to mesh axes; ``constrain`` applies
``with_sharding_constraint`` when a rule-set is active and is a no-op
otherwise (single-device smoke tests never touch the mesh machinery).

Default rules implement the production layout:
  batch        -> (pod, data)   [DP across pods and the data axis]
  ff/heads/... -> model         [TP: Megatron-style column/row splits]
  experts      -> model         [EP: expert parallelism for MoE]
  kv_seq       -> data          [SP: sequence-sharded KV cache, decode]
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import tree_flatten_with_path

MeshAxes = Union[None, str, Tuple[str, ...]]

_state = threading.local()


def default_rules(multi_pod: bool = False) -> Dict[str, MeshAxes]:
    dp: MeshAxes = ("pod", "data") if multi_pod else "data"
    return {
        # activations
        "batch": dp,
        "seq": None,
        "kv_seq": "data",          # sequence-sharded cache for B=1 decode
        "act_embed": None,
        "act_heads": "model",
        "act_kv_heads": "model",   # only when kv_heads divides the axis
        "act_ff": "model",
        # parameters
        "embed": None,
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "ff_expert": None,         # expert-internal dim stays local
        "experts": "model",
        "experts_r": None,         # router output dim (tiny) replicated
        "ssm_inner": "model",
        "layers": None,
        # ZeRO: optimizer state / grad accumulators shard their largest
        # replicated dim over the data axis (pod included when present)
        "zero": dp,
    }


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Dict[str, MeshAxes]):
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, rules)
    try:
        yield
    finally:
        _state.ctx = prev


def active() -> Optional[Tuple[Mesh, Dict[str, MeshAxes]]]:
    return getattr(_state, "ctx", None)


def spec_for(axes: Sequence[Optional[str]],
             rules: Dict[str, MeshAxes]) -> P:
    """Logical axes tuple -> PartitionSpec, dropping unknown names."""
    parts = []
    used = set()

    def resolve(name):
        if name is None:
            return None
        target = rules.get(name)
        if target is None:
            return None
        # avoid using one mesh axis twice in a spec
        flat = (target,) if isinstance(target, str) else tuple(target)
        flat = tuple(a for a in flat if a not in used)
        if not flat:
            return None
        used.update(flat)
        return flat if len(flat) > 1 else flat[0]

    for name in axes:
        parts.append(resolve(name))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Apply a logical sharding constraint if rules are active.

    Divisibility-aware: a mapped mesh axis that does not evenly divide
    the tensor dimension is dropped (e.g. 2 KV heads cannot shard over a
    16-way model axis -- they stay replicated for that arch)."""
    ctx = active()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = spec_for(axes, rules)
    parts = list(spec) + [None] * (x.ndim - len(spec))
    fixed = []
    for dim, part in zip(x.shape, parts, strict=True):
        if part is not None:
            names = (part,) if isinstance(part, str) else tuple(part)
            size = 1
            for n in names:
                size *= mesh.shape[n]
            if dim % size != 0:
                part = None
        fixed.append(part)
    while fixed and fixed[-1] is None:
        fixed.pop()
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))


def param_shardings(axes_tree, mesh: Mesh, rules: Dict[str, MeshAxes],
                    shapes_tree=None):
    """Map an axes pytree (tuples of logical names) to NamedShardings.

    With ``shapes_tree`` (matching pytree of ShapeDtypeStructs/arrays),
    applies the same divisibility guard as ``constrain``."""
    is_leaf = lambda a: a is None or isinstance(a, tuple)

    def leaf(axes, shape=None):
        if axes is None:
            return NamedSharding(mesh, P())
        spec = spec_for(axes, rules)
        if shape is not None:
            parts = list(spec) + [None] * (len(shape.shape) - len(spec))
            fixed = []
            for dim, part in zip(shape.shape, parts, strict=True):
                if part is not None:
                    names = ((part,) if isinstance(part, str)
                             else tuple(part))
                    size = 1
                    for n in names:
                        size *= mesh.shape[n]
                    if dim % size != 0:
                        part = None
                fixed.append(part)
            while fixed and fixed[-1] is None:
                fixed.pop()
            spec = P(*fixed)
        return NamedSharding(mesh, spec)

    if shapes_tree is None:
        return jax.tree.map(leaf, axes_tree, is_leaf=is_leaf)
    # axes_tree has tuple leaves where shapes_tree has array leaves;
    # walk shapes_tree and look up axes by path
    flat_axes, _ = tree_flatten_with_path(axes_tree, is_leaf=is_leaf)
    flat_shapes, treedef = tree_flatten_with_path(shapes_tree)
    axes_by_path = {path: a for path, a in flat_axes}
    out = [leaf(axes_by_path.get(path), s) for path, s in flat_shapes]
    return jax.tree.unflatten(treedef, out)
