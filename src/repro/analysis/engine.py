"""Analysis driver: target discovery, parsing, and rule dispatch."""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Iterable, List, Optional, Sequence

from .findings import SEVERITY_ERROR, Finding

# Directories scanned (relative to the repo root) when no explicit
# paths are given. tests/ is deliberately excluded: its fixture files
# are intentionally rule-violating.
_DEFAULT_SCAN_DIRS = ("src", "benchmarks")
_SKIP_DIR_NAMES = {"__pycache__", ".git", "tests", "fixtures"}


@dataclasses.dataclass
class Module:
    """One parsed Python source file."""

    path: Path
    rel: str                           # path relative to the scan root
    tree: ast.Module
    source: str

    @property
    def filename(self) -> str:
        return self.path.name


@dataclasses.dataclass
class AnalysisContext:
    """Everything a rule gets to see: parsed modules plus the budget
    file (the one non-Python artifact with an invariant of its own)."""

    root: Path
    modules: List[Module]
    budgets_path: Optional[Path]
    parse_failures: List[Finding]
    _callgraph: Optional[object] = dataclasses.field(default=None,
                                                     repr=False)

    def callgraph(self):
        if self._callgraph is None:
            from .callgraph import CallGraph
            self._callgraph = CallGraph.build(self.modules)
        return self._callgraph

    def modules_named(self, filename: str) -> List[Module]:
        return [m for m in self.modules if m.filename == filename]


def _find_repo_root() -> Path:
    """Repo root = nearest ancestor of this package holding src/."""
    here = Path(__file__).resolve()
    for parent in here.parents:
        if (parent / "src" / "repro").is_dir() and parent.name != "src":
            return parent
    return Path.cwd()


def _iter_py_files(base: Path) -> Iterable[Path]:
    for path in sorted(base.rglob("*.py")):
        if any(part in _SKIP_DIR_NAMES for part in path.parts):
            continue
        yield path


def load_context(paths: Sequence[str] = ()) -> AnalysisContext:
    """Build the analysis context.

    No paths: scan the repo's ``src/`` and ``benchmarks/`` trees. A
    directory path: treat it as a miniature root (its ``*.py`` files
    plus an optional ``budgets.json``) -- this is how the fixture-based
    self-tests exercise the budget rule. A file path: analyze just it.
    """
    if paths:
        files: List[Path] = []
        budgets: Optional[Path] = None
        roots: List[Path] = []
        for raw in paths:
            p = Path(raw).resolve()
            if p.is_dir():
                roots.append(p)
                files.extend(p.rglob("*.py"))
                cand = p / "budgets.json"
                if cand.is_file():
                    budgets = cand
            elif p.suffix == ".json":
                budgets = p
                roots.append(p.parent)
            else:
                files.append(p)
                roots.append(p.parent)
        root = roots[0] if roots else Path.cwd()
        files = sorted(set(files))
    else:
        root = _find_repo_root()
        files = []
        for sub in _DEFAULT_SCAN_DIRS:
            base = root / sub
            if base.is_dir():
                files.extend(_iter_py_files(base))
        budgets = root / "benchmarks" / "budgets.json"
        if not budgets.is_file():
            budgets = None

    modules: List[Module] = []
    failures: List[Finding] = []
    for path in files:
        rel = _rel_to(path, root)
        try:
            source = path.read_text()
            tree = ast.parse(source, filename=str(path))
        except (SyntaxError, OSError) as exc:
            line = getattr(exc, "lineno", 1) or 1
            failures.append(Finding(
                file=rel, line=line, col=0, rule="PARSE",
                severity=SEVERITY_ERROR,
                message=f"could not parse module: {exc}"))
            continue
        modules.append(Module(path=path, rel=rel, tree=tree, source=source))
    return AnalysisContext(root=root, modules=modules,
                           budgets_path=budgets, parse_failures=failures)


def _rel_to(path: Path, root: Path) -> str:
    try:
        return path.relative_to(root).as_posix()
    except ValueError:
        return path.as_posix()


def run_analysis(ctx: AnalysisContext,
                 select: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run (selected) rules over the context; findings sorted by
    location for stable output."""
    from .rules import ALL_RULES
    findings: List[Finding] = list(ctx.parse_failures)
    for rule in ALL_RULES:
        if select and rule.rule_id not in select:
            continue
        findings.extend(rule.check(ctx))
    return sorted(findings)
