"""Lightweight intra-package call graph.

Python call targets are not statically resolvable in general, so the
graph over-approximates by *method name*: a call ``x.f(...)`` or
``f(...)`` is an edge to every function named ``f`` anywhere in the
analyzed modules. That is exactly the right bias for reachability
rules like "every mutation reaches an invalidation": over-approximation
can only create false *negatives* for the rule's complement, i.e. it
never flags code that does reach a sink under some resolution.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterable, List, Set, Tuple

from .engine import Module


@dataclasses.dataclass
class FunctionInfo:
    qualname: str                      # module:Class.method or module:func
    name: str                          # bare function/method name
    module: Module
    node: ast.AST                      # FunctionDef | AsyncFunctionDef
    calls: Set[str]                    # bare names of call targets


class CallGraph:
    def __init__(self) -> None:
        self.functions: Dict[str, FunctionInfo] = {}
        self.by_name: Dict[str, List[FunctionInfo]] = {}

    @classmethod
    def build(cls, modules: Iterable[Module]) -> "CallGraph":
        graph = cls()
        for mod in modules:
            for qual, node in _walk_functions(mod.tree):
                info = FunctionInfo(
                    qualname=f"{mod.rel}:{qual}",
                    name=node.name,
                    module=mod,
                    node=node,
                    calls=_called_names(node),
                )
                graph.functions[info.qualname] = info
                graph.by_name.setdefault(info.name, []).append(info)
        return graph

    def reaches(self, start: FunctionInfo, sinks: Set[str],
                max_depth: int = 12) -> bool:
        """True if any call chain from ``start`` hits a name in
        ``sinks`` (including a direct call)."""
        seen: Set[str] = {start.qualname}
        frontier = [start]
        for _ in range(max_depth):
            next_frontier: List[FunctionInfo] = []
            for info in frontier:
                if info.calls & sinks:
                    return True
                for callee_name in info.calls:
                    for callee in self.by_name.get(callee_name, ()):
                        if callee.qualname not in seen:
                            seen.add(callee.qualname)
                            next_frontier.append(callee)
            if not next_frontier:
                return False
            frontier = next_frontier
        return False


def _walk_functions(tree: ast.Module) -> List[Tuple[str, ast.AST]]:
    out: List[Tuple[str, ast.AST]] = []

    def visit(node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}.{child.name}" if prefix else child.name
                out.append((qual, child))
                visit(child, qual)
            elif isinstance(child, ast.ClassDef):
                qual = f"{prefix}.{child.name}" if prefix else child.name
                visit(child, qual)

    visit(tree, "")
    return out


def _called_names(func: ast.AST) -> Set[str]:
    """Bare names of every call target in ``func``, nested defs
    included (calling a function that closes over mutation context is
    still part of its behavior)."""
    names: Set[str] = set()
    for node in ast.walk(func):
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Name):
                names.add(node.func.id)
            elif isinstance(node.func, ast.Attribute):
                names.add(node.func.attr)
    return names
