"""CLI entry point: ``python -m repro.analysis [paths...]``.

Exit status is 1 when any error-severity finding (or parse failure)
is reported, 0 on a clean tree -- CI and scripts/verify.sh key off
that. ``--format json`` emits a machine-readable report.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from .engine import load_context, run_analysis
from .findings import SEVERITY_ERROR
from .rules import ALL_RULES


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=("repro-lint: static enforcement of the repo's "
                     "kernel-launch, cache-coherence, accounting, and "
                     "async-safety invariants"))
    parser.add_argument(
        "paths", nargs="*",
        help=("files or directories to analyze (default: the repo's "
              "src/ and benchmarks/ trees)"))
    parser.add_argument(
        "--format", choices=("text", "json"), default="text",
        help="output format (default: text)")
    parser.add_argument(
        "--select", default="",
        help="comma-separated rule ids to run (default: all)")
    parser.add_argument(
        "--list-rules", action="store_true",
        help="list rule ids and summaries, then exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.rule_id}  {rule.summary}")
        return 0

    select = [r.strip() for r in args.select.split(",") if r.strip()] \
        or None
    known = {rule.rule_id for rule in ALL_RULES}
    if select:
        unknown = [r for r in select if r not in known]
        if unknown:
            parser.error(f"unknown rule id(s): {', '.join(unknown)}")

    ctx = load_context(args.paths)
    findings = run_analysis(ctx, select=select)
    errors = [f for f in findings if f.severity == SEVERITY_ERROR]

    if args.format == "json":
        print(json.dumps({
            "findings": [f.to_dict() for f in findings],
            "counts": {
                "total": len(findings),
                "error": len(errors),
                "modules": len(ctx.modules),
            },
        }, indent=2))
    else:
        for finding in findings:
            print(finding.format())
        print(f"{len(findings)} finding(s) ({len(errors)} error) "
              f"across {len(ctx.modules)} module(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
