"""Finding record emitted by analysis rules."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

SEVERITY_ERROR = "error"
SEVERITY_WARNING = "warning"


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One analyzer diagnostic, anchored to a source location.

    Ordering is (file, line, col, rule) so reports are stable across
    runs regardless of rule execution order.
    """

    file: str
    line: int
    col: int
    rule: str
    severity: str
    message: str

    def format(self) -> str:
        return (f"{self.file}:{self.line}:{self.col}: "
                f"{self.severity} {self.rule} {self.message}")

    def to_dict(self) -> Dict[str, Any]:
        return {
            "rule": self.rule,
            "severity": self.severity,
            "file": self.file,
            "line": self.line,
            "col": self.col,
            "message": self.message,
        }
