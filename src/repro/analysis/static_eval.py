"""Static-expression resolution for the kernel-launch rules.

Decides whether an expression inside a jitted kernel wrapper is
*static* -- known at trace time -- or potentially a traced value. The
judgment is deliberately conservative and syntactic: a name is static
if it is a module-level constant, a parameter listed in the enclosing
function's ``static_argnames``, or a local assigned from an expression
that is itself static. Array ``.shape`` accesses are static (shapes are
part of the abstract value), as are arithmetic/len/min/max over static
operands. Anything else -- in particular a bare parameter of a jitted
function that is *not* in ``static_argnames`` -- is treated as traced.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

_STATIC_BUILTINS = {"len", "max", "min", "int", "abs", "sum", "bool"}

# Attribute names whose access on *any* object yields a static value:
# array shapes (and derived rank/size) are trace-time constants.
_STATIC_ATTRS = {"shape", "ndim", "size", "dtype"}


def module_constants(tree: ast.Module) -> Set[str]:
    """Names bound at module level to literal constants."""
    consts: Set[str] = set()
    for node in tree.body:
        if isinstance(node, ast.Assign) and isinstance(node.value,
                                                       ast.Constant):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    consts.add(tgt.id)
        elif (isinstance(node, ast.AnnAssign)
              and isinstance(node.target, ast.Name)
              and isinstance(node.value, ast.Constant)):
            consts.add(node.target.id)
    return consts


def _str_elements(node: ast.expr) -> List[str]:
    """Extract string elements from a Constant/Tuple/List literal."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out: List[str] = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.append(elt.value)
        return out
    return []


def jit_static_argnames(func: ast.AST) -> Optional[Set[str]]:
    """``static_argnames`` of the enclosing ``jax.jit`` decorator.

    Returns None when the function is not jitted (host-level code whose
    parameters are concrete Python values, hence static), and the
    possibly-empty set of static parameter names when it is.
    """
    if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return None
    for dec in func.decorator_list:
        names = _jit_names_from_decorator(dec)
        if names is not None:
            return names
    return None


def _dotted(node: ast.expr) -> str:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _jit_names_from_decorator(dec: ast.expr) -> Optional[Set[str]]:
    # @jax.jit / @jit -- jitted, no static argnames.
    if _dotted(dec) in ("jax.jit", "jit"):
        return set()
    if not isinstance(dec, ast.Call):
        return None
    callee = _dotted(dec.func)
    # @functools.partial(jax.jit, static_argnames=(...)) and
    # @jax.jit(static_argnames=(...)) both carry the kwarg directly.
    is_partial_jit = (callee in ("functools.partial", "partial")
                      and dec.args
                      and _dotted(dec.args[0]) in ("jax.jit", "jit"))
    is_jit_call = callee in ("jax.jit", "jit")
    if not (is_partial_jit or is_jit_call):
        return None
    names: Set[str] = set()
    for kw in dec.keywords:
        if kw.arg in ("static_argnames", "static_argnums") and kw.value:
            names.update(_str_elements(kw.value))
    return names


def static_env(func: ast.AST, consts: Set[str]) -> Set[str]:
    """Names statically resolvable inside ``func``'s body.

    Seeds: module constants plus static parameters. Locals assigned
    from static expressions join the set; two passes reach the fixed
    point for the straight-line assignment chains the kernels use.
    """
    env = set(consts)
    static_params = jit_static_argnames(func)
    if isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
        args = func.args
        all_params = [a.arg for a in (args.posonlyargs + args.args
                                      + args.kwonlyargs)]
        if static_params is None:
            env.update(all_params)      # not jitted: concrete host values
        else:
            env.update(p for p in all_params if p in static_params)
        body = func.body
    else:
        body = getattr(func, "body", [])

    for _ in range(2):
        for stmt in ast.walk(ast.Module(body=list(body), type_ignores=[])):
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                tgt = stmt.targets[0]
                if isinstance(tgt, ast.Name) and is_static(stmt.value, env):
                    env.add(tgt.id)
            elif (isinstance(stmt, ast.AnnAssign)
                  and isinstance(stmt.target, ast.Name)
                  and stmt.value is not None
                  and is_static(stmt.value, env)):
                env.add(stmt.target.id)
    return env


def is_static(node: ast.expr, env: Set[str]) -> bool:
    return not nonstatic_parts(node, env)


def nonstatic_parts(node: ast.expr, env: Set[str]) -> List[ast.expr]:
    """Sub-expressions of ``node`` that defeat static resolution.

    Returns the offending leaves (for precise findings); empty means
    the whole expression is static.
    """
    if isinstance(node, ast.Constant):
        return []
    if isinstance(node, ast.Name):
        return [] if node.id in env else [node]
    if isinstance(node, (ast.Tuple, ast.List)):
        out: List[ast.expr] = []
        for elt in node.elts:
            out.extend(nonstatic_parts(elt, env))
        return out
    if isinstance(node, ast.Attribute):
        # x.shape is static for traced x; other attribute chains are
        # host-object reads (module constants, self.<config>), which
        # are concrete at trace time.
        return []
    if isinstance(node, ast.Subscript):
        # x.shape[0] and tuple[i] indexing over static parts.
        sub = nonstatic_parts(node.slice, env)
        if isinstance(node.value, ast.Attribute):
            if node.value.attr in _STATIC_ATTRS:
                return sub
            return sub + [node]
        return sub + nonstatic_parts(node.value, env)
    if isinstance(node, ast.BinOp):
        return (nonstatic_parts(node.left, env)
                + nonstatic_parts(node.right, env))
    if isinstance(node, ast.UnaryOp):
        return nonstatic_parts(node.operand, env)
    if isinstance(node, ast.BoolOp):
        out = []
        for v in node.values:
            out.extend(nonstatic_parts(v, env))
        return out
    if isinstance(node, ast.Compare):
        out = nonstatic_parts(node.left, env)
        for c in node.comparators:
            out.extend(nonstatic_parts(c, env))
        return out
    if isinstance(node, ast.IfExp):
        return (nonstatic_parts(node.test, env)
                + nonstatic_parts(node.body, env)
                + nonstatic_parts(node.orelse, env))
    if isinstance(node, ast.Call):
        if (isinstance(node.func, ast.Name)
                and node.func.id in _STATIC_BUILTINS):
            out = []
            for a in node.args:
                out.extend(nonstatic_parts(a, env))
            for kw in node.keywords:
                if kw.value is not None:
                    out.extend(nonstatic_parts(kw.value, env))
            return out
        return [node]
    return [node]
