"""Resilience rule (RS...).

PR 10's retry layer is safe because every retry decision funnels
through ONE predicate (``repro.serving.resilience.is_retryable``): 503
admission control and transient transport failures are retried,
malformed requests and maxMpR violations are not. A hand-rolled retry
loop that pattern-matches exceptions itself will eventually retry a
permanent error forever (or drop a transient one), and a transport
error swallowed without a trace is an availability bug that never shows
up in metrics. RS001 pins both shapes down statically:

* an ``except`` for a transport-family exception (``TransportError``,
  ``InjectedFault``, ``QueueSaturated``, ``DeadlineExceeded``) inside a
  retry loop (a ``while`` loop, or a ``for`` over ``range(...)`` --
  the bounded-attempt idioms) must consult ``is_retryable`` somewhere
  in that loop;
* any such handler, loop or not, must not swallow silently: its body
  must re-raise, reference the bound exception, or record a counter
  (an augmented assignment or a ``record*``/``append``/``add`` call) --
  so every absorbed failure leaves a trace the metrics can surface.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set, Tuple

from ..engine import AnalysisContext
from ..findings import SEVERITY_ERROR, Finding
from . import Rule

# Exception names whose handlers RS001 audits. Matched on the last
# dotted component, so ``faults.InjectedFault`` triggers too. Plain
# TimeoutError is deliberately absent: it guards many non-transport
# waits and would drown the rule in false positives.
_TRANSPORT_EXCEPTIONS = {"TransportError", "InjectedFault",
                         "QueueSaturated", "DeadlineExceeded"}

_RECORDING_METHODS = ("record", "append", "add", "put", "set_exception")


def _exception_names(node: Optional[ast.expr]) -> Set[str]:
    """Last dotted component of every exception named by an except
    clause (handles ``except X``, ``except pkg.X``, ``except (X, Y)``;
    a bare ``except:`` audits nothing -- it is someone else's problem)."""
    if node is None:
        return set()
    if isinstance(node, ast.Tuple):
        out: Set[str] = set()
        for elt in node.elts:
            out |= _exception_names(elt)
        return out
    if isinstance(node, ast.Name):
        return {node.id}
    if isinstance(node, ast.Attribute):
        return {node.attr}
    return set()


def _mentions_name(nodes, name: str) -> bool:
    for stmt in nodes:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Name) and node.id == name:
                return True
    return False


def _records_failure(handler: ast.ExceptHandler) -> bool:
    """Does the handler leave a trace? Re-raise, touch the bound
    exception, bump a counter, or call a recording method."""
    for stmt in handler.body:
        for node in ast.walk(stmt):
            if isinstance(node, (ast.Raise, ast.AugAssign)):
                return True
            if (handler.name is not None and isinstance(node, ast.Name)
                    and node.id == handler.name):
                return True
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr.startswith(_RECORDING_METHODS)):
                return True
    return False


def _is_retry_loop(node: ast.AST) -> bool:
    """The bounded-attempt loop idioms: ``while ...`` or
    ``for _ in range(...)``."""
    if isinstance(node, ast.While):
        return True
    if isinstance(node, ast.For):
        it = node.iter
        return (isinstance(it, ast.Call)
                and isinstance(it.func, ast.Name)
                and it.func.id == "range")
    return False


def _handlers_with_loops(tree: ast.AST):
    """Yield (handler, enclosing retry loop or None), outermost loop
    first, without descending into nested function definitions twice
    (every def gets its own walk from the module root -- the loop stack
    resets at def boundaries, since a closure's loop is not the def's)."""
    stack: List[Tuple[ast.AST, Optional[ast.AST]]] = [(tree, None)]
    while stack:
        node, loop = stack.pop()
        if isinstance(node, ast.ExceptHandler):
            yield node, loop
        here = loop
        if _is_retry_loop(node):
            here = node
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.Lambda)):
            here = None
        for child in ast.iter_child_nodes(node):
            stack.append((child, here))


def check_retry_discipline(ctx: AnalysisContext) -> List[Finding]:
    """RS001: retry loops consult ``is_retryable``; transport-error
    handlers never swallow silently."""
    findings: List[Finding] = []
    for mod in ctx.modules:
        for handler, loop in _handlers_with_loops(mod.tree):
            caught = _exception_names(handler.type) & _TRANSPORT_EXCEPTIONS
            if not caught:
                continue
            names = "/".join(sorted(caught))
            if loop is not None and not _mentions_name([loop],
                                                       "is_retryable"):
                findings.append(Finding(
                    file=mod.rel, line=handler.lineno,
                    col=handler.col_offset, rule="RS001",
                    severity=SEVERITY_ERROR,
                    message=(f"retry loop catches {names} without "
                             "consulting the central is_retryable() "
                             "predicate (repro.serving.resilience) -- "
                             "blind retries eventually retry permanent "
                             "errors")))
            elif not _records_failure(handler):
                findings.append(Finding(
                    file=mod.rel, line=handler.lineno,
                    col=handler.col_offset, rule="RS001",
                    severity=SEVERITY_ERROR,
                    message=(f"except {names} swallows the failure "
                             "silently: re-raise, reference the bound "
                             "exception, or record a counter so the "
                             "metrics surface it")))
    return findings


RULES = [
    Rule("RS001", "retry loops use is_retryable(); no silent "
                  "transport-error swallows", check_retry_discipline),
]
