"""Cache-coherence rules (CC...).

The unified ``FragmentStore`` (PR 4) made one layer responsible for
keeping the selector memo, range memo, and HTTP page cache coherent
with the underlying ``TripleStore`` pages: eviction releases flow
through ``on_release`` so candidate-range spans die with the cache
entries that justified materializing them. Two conventions keep that
true and both are purely social without this pass: store internals stay
inside ``fragments.py``, and any code path that mutates triple/pattern
data must reach an invalidation.
"""
from __future__ import annotations

import ast
from typing import List

from ..engine import AnalysisContext
from ..findings import SEVERITY_ERROR, Finding
from . import Rule

# FragmentStore-private structures. (LRUCache in cache.py has its own
# unrelated ``_entries``, so that name is intentionally not listed.)
_FRAGMENT_INTERNALS = {"_data_lru", "_page_lru", "_pattern_refs"}
_FRAGMENTS_FILE = "fragments.py"

# Attributes whose (re)assignment counts as mutating triple-pattern
# data backing cached ranges.
_MUTATED_ATTRS = {"triples", "_indexes"}

# Attributes whose (re)assignment constitutes a placement cutover: a
# server swapping its FederatedStore (docs/federation.md, "Placement")
# serves the same key ranges from new shard boundaries, so every cached
# fragment/range must be invalidated with the swap.
_CUTOVER_ATTRS = {"federated"}

# Call names that constitute (or lead to) cache invalidation.
_INVALIDATION_SINKS = {"on_release", "evict", "evict_page",
                       "evict_candidate_range", "clear", "invalidate",
                       "trim"}


def check_fragmentstore_internals(ctx: AnalysisContext) -> List[Finding]:
    """CC001: FragmentStore internals are not reached into from
    outside fragments.py."""
    findings: List[Finding] = []
    for mod in ctx.modules:
        if mod.filename == _FRAGMENTS_FILE:
            continue
        for node in ast.walk(mod.tree):
            if (isinstance(node, ast.Attribute)
                    and node.attr in _FRAGMENT_INTERNALS):
                findings.append(Finding(
                    file=mod.rel, line=node.lineno, col=node.col_offset,
                    rule="CC001", severity=SEVERITY_ERROR,
                    message=(f"access to FragmentStore internal "
                             f"'{node.attr}' outside fragments.py; go "
                             "through the public evict/on_release/"
                             "stats API so coherence accounting stays "
                             "centralized")))
    return findings


def _mutations(func_node: ast.AST,
               attrs=frozenset(_MUTATED_ATTRS)) -> List[ast.stmt]:
    """Statements in ``func_node`` that rebind or store into an
    attribute named in ``attrs``."""
    hits: List[ast.stmt] = []
    for node in ast.walk(func_node):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not func_node:
            continue
        targets: List[ast.expr] = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        for tgt in targets:
            if (isinstance(tgt, ast.Attribute)
                    and tgt.attr in attrs):
                hits.append(node)
            elif (isinstance(tgt, ast.Subscript)
                  and isinstance(tgt.value, ast.Attribute)
                  and tgt.value.attr in attrs):
                hits.append(node)
    return hits


def check_mutation_invalidation(ctx: AnalysisContext) -> List[Finding]:
    """CC002: a function mutating TripleStore data must reach a
    FragmentStore invalidation in the call graph. ``__init__`` is
    exempt (construction precedes any cache entries)."""
    findings: List[Finding] = []
    graph = ctx.callgraph()
    for info in graph.functions.values():
        if info.name == "__init__":
            continue
        hits = _mutations(info.node)
        if not hits:
            continue
        if graph.reaches(info, _INVALIDATION_SINKS):
            continue
        for stmt in hits:
            findings.append(Finding(
                file=info.module.rel, line=stmt.lineno,
                col=stmt.col_offset, rule="CC002",
                severity=SEVERITY_ERROR,
                message=(f"'{info.name}' mutates triple-pattern data "
                         "but no FragmentStore invalidation "
                         "(on_release/evict/clear) is reachable from "
                         "it; cached candidate ranges would go "
                         "stale")))
    return findings


def check_repartition_invalidation(ctx: AnalysisContext) -> List[Finding]:
    """CC003: a placement cutover (rebinding a ``.federated`` store)
    must reach a FragmentStore invalidation in the call graph.

    The repartitioned store serves identical fragments from new shard
    boundaries, but cached pages/ranges were computed (and accounted)
    against the old ones -- a swap that keeps them resident would serve
    stale residency decisions after cutover. ``__init__`` is exempt
    (first construction precedes any cache entries)."""
    findings: List[Finding] = []
    graph = ctx.callgraph()
    for info in graph.functions.values():
        if info.name == "__init__":
            continue
        hits = _mutations(info.node, attrs=_CUTOVER_ATTRS)
        if not hits:
            continue
        if graph.reaches(info, _INVALIDATION_SINKS):
            continue
        for stmt in hits:
            findings.append(Finding(
                file=info.module.rel, line=stmt.lineno,
                col=stmt.col_offset, rule="CC003",
                severity=SEVERITY_ERROR,
                message=(f"'{info.name}' swaps a federated store "
                         "(placement cutover) but no FragmentStore "
                         "invalidation (on_release/evict/clear) is "
                         "reachable from it; fragments cached against "
                         "the old shard boundaries would stay "
                         "resident")))
    return findings


RULES = [
    Rule("CC001", "FragmentStore internals stay inside fragments.py",
         check_fragmentstore_internals),
    Rule("CC002", "data mutation reaches cache invalidation",
         check_mutation_invalidation),
    Rule("CC003", "placement cutover reaches cache invalidation",
         check_repartition_invalidation),
]
