"""Dead-code rules (DC...).

Unreachable statements and dead stores are how accounting bugs hide:
a counter increment after a ``continue``, or a recomputed buffer whose
first computation was already charged to a cost model. These two rules
keep the tree free of both shapes.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from ..engine import AnalysisContext
from ..findings import SEVERITY_ERROR, Finding
from . import Rule

_TERMINATORS = (ast.Return, ast.Raise, ast.Break, ast.Continue)

# Call targets considered pure for the duplicate-store rule: value
# constructors whose result depends only on their (pure) arguments.
_PURE_CALLS = {"empty", "zeros", "ones", "full", "array", "asarray",
               "arange", "int", "float", "tuple", "list", "dict", "set",
               "frozenset", "len", "max", "min", "abs"}


def _stmt_lists(tree: ast.AST):
    for node in ast.walk(tree):
        for field in ("body", "orelse", "finalbody"):
            stmts = getattr(node, field, None)
            if isinstance(stmts, list) and stmts \
                    and all(isinstance(s, ast.stmt) for s in stmts):
                yield node, stmts


def check_unreachable(ctx: AnalysisContext) -> List[Finding]:
    """DC001: statements after return/raise/break/continue, and
    branches dead under a constant test."""
    findings: List[Finding] = []
    for mod in ctx.modules:
        for _, stmts in _stmt_lists(mod.tree):
            for i, stmt in enumerate(stmts[:-1]):
                if isinstance(stmt, _TERMINATORS):
                    nxt = stmts[i + 1]
                    findings.append(Finding(
                        file=mod.rel, line=nxt.lineno,
                        col=nxt.col_offset, rule="DC001",
                        severity=SEVERITY_ERROR,
                        message=("unreachable code after "
                                 f"'{type(stmt).__name__.lower()}'")))
                    break
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.If) \
                    and isinstance(node.test, ast.Constant):
                dead = node.orelse if node.test.value else node.body
                if dead:
                    findings.append(Finding(
                        file=mod.rel, line=dead[0].lineno,
                        col=dead[0].col_offset, rule="DC001",
                        severity=SEVERITY_ERROR,
                        message=("branch is dead: if-test is the "
                                 f"constant {node.test.value!r}")))
            elif isinstance(node, ast.While) \
                    and isinstance(node.test, ast.Constant) \
                    and not node.test.value and node.body:
                findings.append(Finding(
                    file=mod.rel, line=node.body[0].lineno,
                    col=node.body[0].col_offset, rule="DC001",
                    severity=SEVERITY_ERROR,
                    message="while-body is dead: test is constant false"))
    return findings


def _is_pure_value(node: ast.expr) -> bool:
    if isinstance(node, (ast.Constant, ast.Name)):
        return True
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(_is_pure_value(e) for e in node.elts)
    if isinstance(node, ast.UnaryOp):
        return _is_pure_value(node.operand)
    if isinstance(node, ast.BinOp):
        return _is_pure_value(node.left) and _is_pure_value(node.right)
    if isinstance(node, ast.Attribute):
        return _is_pure_value(node.value)
    if isinstance(node, ast.Call):
        name = node.func.attr if isinstance(node.func, ast.Attribute) \
            else (node.func.id if isinstance(node.func, ast.Name) else "")
        if name not in _PURE_CALLS:
            return False
        return (all(_is_pure_value(a) for a in node.args)
                and all(kw.value is not None
                        and _is_pure_value(kw.value)
                        for kw in node.keywords))
    return False


def _disqualified_names(func: ast.AST) -> Set[str]:
    """Local names whose value may change through aliasing or in-place
    mutation between two textual assignments."""
    out: Set[str] = set()
    for node in ast.walk(func):
        if isinstance(node, ast.AugAssign) \
                and isinstance(node.target, ast.Name):
            out.add(node.target.id)
        elif isinstance(node, (ast.Assign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for tgt in targets:
                if isinstance(tgt, (ast.Subscript, ast.Attribute)) \
                        and isinstance(tgt.value, ast.Name):
                    out.add(tgt.value.id)
        elif isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and isinstance(node.func.value, ast.Name):
            out.add(node.func.value.id)   # method call may mutate
        elif isinstance(node, (ast.Global, ast.Nonlocal)):
            out.update(node.names)
        elif isinstance(node, ast.Delete):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    out.add(tgt.id)
        elif isinstance(node, (ast.For, ast.comprehension)):
            tgt = node.target
            for leaf in ast.walk(tgt):
                if isinstance(leaf, ast.Name):
                    out.add(leaf.id)
    return out


def _rebound_names(func: ast.AST) -> Set[str]:
    """Names (re)bound anywhere in the function body -- a value
    expression referencing one of these can differ between two
    textually identical assignments."""
    out: Set[str] = set()
    for node in ast.walk(func):
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for tgt in targets:
                for leaf in ast.walk(tgt):
                    if isinstance(leaf, ast.Name):
                        out.add(leaf.id)
        elif isinstance(node, (ast.For, ast.comprehension)):
            for leaf in ast.walk(node.target):
                if isinstance(leaf, ast.Name):
                    out.add(leaf.id)
        elif isinstance(node, ast.withitem) and node.optional_vars:
            for leaf in ast.walk(node.optional_vars):
                if isinstance(leaf, ast.Name):
                    out.add(leaf.id)
        elif isinstance(node, ast.ExceptHandler) and node.name:
            out.add(node.name)
        elif isinstance(node, ast.NamedExpr) \
                and isinstance(node.target, ast.Name):
            out.add(node.target.id)
    return out


def check_duplicate_stores(ctx: AnalysisContext) -> List[Finding]:
    """DC002: the same name assigned the same pure value twice,
    unconditionally, within one function -- the second store is dead
    (or the first is, either way one of them shouldn't exist)."""
    findings: List[Finding] = []
    for mod in ctx.modules:
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            disqualified = _disqualified_names(node)
            rebound = _rebound_names(node)
            seen: Dict[Tuple[str, str], int] = {}
            for stmt in node.body:      # unconditional positions only
                if not (isinstance(stmt, ast.Assign)
                        and len(stmt.targets) == 1
                        and isinstance(stmt.targets[0], ast.Name)):
                    continue
                name = stmt.targets[0].id
                if name in disqualified or not _is_pure_value(stmt.value):
                    continue
                refs = {leaf.id for leaf in ast.walk(stmt.value)
                        if isinstance(leaf, ast.Name)}
                if refs & rebound:
                    continue            # operands may change in between
                key = (name, ast.dump(stmt.value))
                if key in seen:
                    findings.append(Finding(
                        file=mod.rel, line=stmt.lineno,
                        col=stmt.col_offset, rule="DC002",
                        severity=SEVERITY_ERROR,
                        message=(f"duplicate assignment to '{name}' "
                                 "with an identical value (first at "
                                 f"line {seen[key]}); the second "
                                 "store is dead")))
                else:
                    seen[key] = stmt.lineno
    return findings


RULES = [
    Rule("DC001", "no unreachable statements or dead branches",
         check_unreachable),
    Rule("DC002", "no duplicate unconditional pure stores",
         check_duplicate_stores),
]
