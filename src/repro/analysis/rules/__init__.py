"""Rule registry. Each rule module exports ``RULES: List[Rule]``."""
from __future__ import annotations

import dataclasses
from typing import Callable, List

from ..engine import AnalysisContext
from ..findings import Finding


@dataclasses.dataclass(frozen=True)
class Rule:
    rule_id: str
    summary: str
    check: Callable[[AnalysisContext], List[Finding]]


def _collect() -> List[Rule]:
    from . import (accounting, async_safety, cache_coherence, dead_code,
                   kernel_launch, resilience)
    rules: List[Rule] = []
    for mod in (kernel_launch, cache_coherence, accounting, async_safety,
                dead_code, resilience):
        rules.extend(mod.RULES)
    return rules


ALL_RULES: List[Rule] = _collect()

__all__ = ["ALL_RULES", "Rule"]
