"""Kernel-launch safety rules (KL...).

The Pallas kernels (PR 1, PR 3) are only correct under launch
conventions the call sites must uphold by hand: explicit launch
geometry on every ``pl.pallas_call``, block shapes that are static at
trace time (a traced Python scalar in a BlockSpec either fails deep in
Mosaic or silently retraces per shape), and power-of-two tile/window
capacities (lane alignment on TPU; the sharded window math in
docs/sharding.md additionally assumes window | range arithmetic that
only holds for powers of two).
"""
from __future__ import annotations

import ast
from typing import List, Optional, Tuple

from ..engine import AnalysisContext, Module
from ..findings import SEVERITY_ERROR, Finding
from ..static_eval import module_constants, nonstatic_parts, static_env
from . import Rule

REQUIRED_KWARGS = ("grid", "in_specs", "out_specs", "out_shape",
                   "interpret")

# Capacity-constant name tokens that must be powers of two. SLOTS /
# STREAM / SEGMENTS are the fused-launch table capacities (docs/
# fusion.md): the fused stream is tiled and padded to pow2 tile counts,
# and the slot/segment tables are sized from these caps, so a non-pow2
# cap silently breaks the padding arithmetic.
_POW2_TOKENS = {"BT", "BM", "BR", "LANES", "WINDOW", "BUCKET",
                "SLOTS", "STREAM", "SEGMENTS"}


def _dotted_tail(node: ast.expr) -> str:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _pallas_call_sites(mod: Module) -> List[Tuple[Optional[ast.AST],
                                                  ast.Call]]:
    """(enclosing function, call) for each ``pl.pallas_call`` site."""
    sites: List[Tuple[Optional[ast.AST], ast.Call]] = []

    def visit(node: ast.AST, func: Optional[ast.AST]) -> None:
        for child in ast.iter_child_nodes(node):
            enclosing = func
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                enclosing = child
            if (isinstance(child, ast.Call)
                    and _dotted_tail(child.func) == "pallas_call"):
                sites.append((func, child))
            visit(child, enclosing)

    visit(mod.tree, None)
    return sites


def check_pallas_kwargs(ctx: AnalysisContext) -> List[Finding]:
    """KL001: every pallas_call declares the full launch geometry."""
    findings: List[Finding] = []
    for mod in ctx.modules:
        for _, call in _pallas_call_sites(mod):
            present = {kw.arg for kw in call.keywords if kw.arg}
            missing = [k for k in REQUIRED_KWARGS if k not in present]
            if missing:
                findings.append(Finding(
                    file=mod.rel, line=call.lineno, col=call.col_offset,
                    rule="KL001", severity=SEVERITY_ERROR,
                    message=("pl.pallas_call missing required launch "
                             f"kwargs: {', '.join(missing)}")))
    return findings


def _block_shape_arg(call: ast.Call) -> Optional[ast.expr]:
    """The block-shape expression of a ``pl.BlockSpec(...)`` call."""
    if call.args:
        return call.args[0]
    for kw in call.keywords:
        if kw.arg == "block_shape":
            return kw.value
    return None


def check_static_block_shapes(ctx: AnalysisContext) -> List[Finding]:
    """KL002: BlockSpec block shapes and ShapeDtypeStruct dims resolve
    statically inside the enclosing (jitted) wrapper."""
    findings: List[Finding] = []
    for mod in ctx.modules:
        consts = module_constants(mod.tree)
        for func, call in _pallas_call_sites(mod):
            env = static_env(func, consts) if func is not None else consts
            for inner in ast.walk(call):
                if not isinstance(inner, ast.Call):
                    continue
                tail = _dotted_tail(inner.func)
                if tail == "BlockSpec":
                    shape = _block_shape_arg(inner)
                    if shape is None:
                        continue
                    bad = nonstatic_parts(shape, env)
                    if bad:
                        names = ", ".join(
                            ast.unparse(b) for b in bad[:3])
                        findings.append(Finding(
                            file=mod.rel, line=inner.lineno,
                            col=inner.col_offset, rule="KL002",
                            severity=SEVERITY_ERROR,
                            message=("BlockSpec block shape is not "
                                     "static at trace time "
                                     f"(non-static: {names}); mark the "
                                     "parameter static_argnames or "
                                     "derive it from a module constant "
                                     "/ input shape")))
                elif tail == "ShapeDtypeStruct" and inner.args:
                    bad = nonstatic_parts(inner.args[0], env)
                    if bad:
                        names = ", ".join(
                            ast.unparse(b) for b in bad[:3])
                        findings.append(Finding(
                            file=mod.rel, line=inner.lineno,
                            col=inner.col_offset, rule="KL002",
                            severity=SEVERITY_ERROR,
                            message=("out_shape dims are not static at "
                                     f"trace time (non-static: {names})")))
    return findings


def check_traced_grid(ctx: AnalysisContext) -> List[Finding]:
    """KL003: the launch grid must not capture traced Python scalars."""
    findings: List[Finding] = []
    for mod in ctx.modules:
        consts = module_constants(mod.tree)
        for func, call in _pallas_call_sites(mod):
            env = static_env(func, consts) if func is not None else consts
            for kw in call.keywords:
                if kw.arg != "grid" or kw.value is None:
                    continue
                bad = nonstatic_parts(kw.value, env)
                if bad:
                    names = ", ".join(ast.unparse(b) for b in bad[:3])
                    findings.append(Finding(
                        file=mod.rel, line=kw.value.lineno,
                        col=kw.value.col_offset, rule="KL003",
                        severity=SEVERITY_ERROR,
                        message=("pallas_call grid captures traced "
                                 f"value(s): {names}; grids must be "
                                 "Python ints at trace time")))
    return findings


def check_pow2_capacities(ctx: AnalysisContext) -> List[Finding]:
    """KL004: capacity constants (tile sizes, shard windows, range
    buckets) are powers of two."""
    findings: List[Finding] = []
    for mod in ctx.modules:
        for node in mod.tree.body:
            pairs: List[Tuple[ast.Name, ast.expr]] = []
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                if isinstance(node.targets[0], ast.Name):
                    pairs.append((node.targets[0], node.value))
            elif (isinstance(node, ast.AnnAssign)
                  and isinstance(node.target, ast.Name)
                  and node.value is not None):
                pairs.append((node.target, node.value))
            for name, value in pairs:
                if name.id != name.id.upper():
                    continue
                tokens = set(name.id.split("_"))
                if not tokens & _POW2_TOKENS:
                    continue
                if not (isinstance(value, ast.Constant)
                        and isinstance(value.value, int)
                        and not isinstance(value.value, bool)):
                    continue
                v = value.value
                if v <= 0 or v & (v - 1):
                    findings.append(Finding(
                        file=mod.rel, line=node.lineno,
                        col=node.col_offset, rule="KL004",
                        severity=SEVERITY_ERROR,
                        message=(f"capacity constant {name.id} = {v} is "
                                 "not a power of two; tile/window/bucket "
                                 "sizes must be lane- and "
                                 "window-aligned")))
    return findings


def _records_segments(mod: Module) -> bool:
    """Does this module append a ``LaunchRecord(...)`` carrying a
    ``segments=`` kwarg to a ``launches`` sink anywhere?"""
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "append"
                and _dotted_tail(node.func.value) == "launches"):
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if (isinstance(arg, ast.Call)
                    and _dotted_tail(arg.func) == "LaunchRecord"
                    and any(kw.arg == "segments" for kw in arg.keywords)):
                return True
    return False


def check_fused_launch_accounting(ctx: AnalysisContext) -> List[Finding]:
    """KL005: every module that launches the fused bind-join records
    its segment count into a LaunchRecord sink.

    ``fused_segments_per_launch`` (the headline metric of docs/
    fusion.md) and the simulator's fused cost model both read segment
    counts off ``LaunchRecord.segments`` -- a fused call site that does
    not append ``launches.append(LaunchRecord(..., segments=...))``
    silently drops its launches from that accounting. The match is on
    the exact call name ``bindjoin_fused`` (the marshaling op), not its
    ``*_pallas`` / ``*_ref`` internals, which are below the accounting
    boundary.
    """
    findings: List[Finding] = []
    for mod in ctx.modules:
        calls = [node for node in ast.walk(mod.tree)
                 if isinstance(node, ast.Call)
                 and _dotted_tail(node.func) == "bindjoin_fused"]
        if not calls or _records_segments(mod):
            continue
        call = calls[0]
        findings.append(Finding(
            file=mod.rel, line=call.lineno, col=call.col_offset,
            rule="KL005", severity=SEVERITY_ERROR,
            message=("module calls bindjoin_fused but never records a "
                     "segment count -- add launches.append("
                     "LaunchRecord(..., segments=...)) so fused "
                     "launches stay visible to "
                     "fused_segments_per_launch accounting")))
    return findings


RULES = [
    Rule("KL001", "pallas_call declares full launch geometry",
         check_pallas_kwargs),
    Rule("KL002", "BlockSpec/out_shape dims are static at trace time",
         check_static_block_shapes),
    Rule("KL003", "launch grid captures no traced scalars",
         check_traced_grid),
    Rule("KL004", "capacity constants are powers of two",
         check_pow2_capacities),
    Rule("KL005", "fused launches record segment counts",
         check_fused_launch_accounting),
]
