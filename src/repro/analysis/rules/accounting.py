"""Accounting-integrity rules (AC...).

The paper's evaluation is its request/transfer counts, and the repo's
launch-budget gates (PR 2, PR 5) regress on them -- so every launch
must be charged somewhere, exactly once, and every budget key must
name a metric that actually exists. Three rules:

* AC001 -- a ``LaunchRecord`` that is constructed but never appended to
  a ``launches`` accounting surface is a launch the server will never
  charge;
* AC002 -- in a disposition chain over launch records (testing
  ``.skipped`` / ``.fast_path``), every path must increment exactly one
  of the launch counters (``kernel_launches`` / ``fast_path_selects``
  / ``launches_skipped``) -- zero drops the launch from the ledger, two
  double-charges it;
* AC003 -- every ``benchmarks/budgets.json`` key must resolve to a
  metric ``core/metrics.py`` emits, otherwise the budget gate
  silently gates nothing.
"""
from __future__ import annotations

import ast
import json
from typing import List, Sequence, Set

from ..engine import AnalysisContext
from ..findings import SEVERITY_ERROR, Finding
from . import Rule

# Counter fields that charge a launch disposition. ``launches`` covers
# the generic name; the live Counters field is ``kernel_launches``.
_DISPOSITION_COUNTERS = {"launches", "kernel_launches",
                         "fast_path_selects", "launches_skipped"}
_DISPOSITION_FLAGS = {"skipped", "fast_path"}

_ACCOUNTING_SURFACE = "launches"


def _is_launchrecord_call(node: ast.expr) -> bool:
    if not isinstance(node, ast.Call):
        return False
    func = node.func
    name = func.id if isinstance(func, ast.Name) else (
        func.attr if isinstance(func, ast.Attribute) else "")
    return name == "LaunchRecord"


def check_launchrecord_sink(ctx: AnalysisContext) -> List[Finding]:
    """AC001: every LaunchRecord construction is appended to a
    ``launches`` list at the construction site."""
    findings: List[Finding] = []
    for mod in ctx.modules:
        accounted: Set[int] = set()
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "append"):
                continue
            recv = node.func.value
            recv_name = recv.id if isinstance(recv, ast.Name) else (
                recv.attr if isinstance(recv, ast.Attribute) else "")
            if recv_name != _ACCOUNTING_SURFACE:
                continue
            for arg in node.args:
                if _is_launchrecord_call(arg):
                    accounted.add(id(arg))
        for node in ast.walk(mod.tree):
            if _is_launchrecord_call(node) and id(node) not in accounted:
                findings.append(Finding(
                    file=mod.rel, line=node.lineno, col=node.col_offset,
                    rule="AC001", severity=SEVERITY_ERROR,
                    message=("LaunchRecord constructed outside a "
                             "'launches.append(...)' accounting sink; "
                             "this launch will never be charged to "
                             "Counters")))
    return findings


def _mentions_disposition(test: ast.expr) -> bool:
    return any(isinstance(n, ast.Attribute)
               and n.attr in _DISPOSITION_FLAGS
               for n in ast.walk(test))


def _terminates(stmts: Sequence[ast.stmt]) -> bool:
    return bool(stmts) and isinstance(
        stmts[-1], (ast.Continue, ast.Break, ast.Return, ast.Raise))


def _split_paths(stmts: Sequence[ast.stmt]) -> List[List[ast.stmt]]:
    """Execution paths through a statement list, branching at each
    disposition test. Guard-with-continue chains and if/elif/else
    ladders both come out as one path per disposition."""
    stmts = list(stmts)
    for i, stmt in enumerate(stmts):
        if isinstance(stmt, ast.If) and _mentions_disposition(stmt.test):
            pre, rest = stmts[:i], stmts[i + 1:]
            taken = pre + list(stmt.body)
            if not _terminates(stmt.body):
                taken = taken + rest
            paths = [taken]
            for tail in _split_paths(pre + list(stmt.orelse) + rest):
                paths.append(tail)
            return paths
    return [stmts]


def _count_disposition_increments(stmts: Sequence[ast.stmt]) -> int:
    count = 0
    for stmt in stmts:
        for node in ast.walk(stmt):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if (isinstance(node, ast.AugAssign)
                    and isinstance(node.target, ast.Attribute)
                    and node.target.attr in _DISPOSITION_COUNTERS):
                count += 1
    return count


def check_disposition_paths(ctx: AnalysisContext) -> List[Finding]:
    """AC002: each path through a launch-disposition chain increments
    exactly one disposition counter."""
    findings: List[Finding] = []
    graph = ctx.callgraph()
    for info in graph.functions.values():
        for node in ast.walk(info.node):
            if not isinstance(node, ast.For):
                continue
            has_disposition = any(
                isinstance(n, ast.If) and _mentions_disposition(n.test)
                for n in ast.walk(node))
            if not has_disposition:
                continue
            if _count_disposition_increments(node.body) == 0:
                continue                  # not an accounting loop
            for path in _split_paths(node.body):
                n = _count_disposition_increments(path)
                if n != 1:
                    anchor = path[0] if path else node
                    findings.append(Finding(
                        file=info.module.rel, line=anchor.lineno,
                        col=anchor.col_offset, rule="AC002",
                        severity=SEVERITY_ERROR,
                        message=(f"launch-disposition path in "
                                 f"'{info.name}' increments {n} "
                                 "disposition counters (expected "
                                 "exactly 1 of kernel_launches/"
                                 "fast_path_selects/"
                                 "launches_skipped)")))
    return findings


def _emitted_metric_names(ctx: AnalysisContext) -> Set[str]:
    """Metric names core/metrics.py emits: Counters field names plus
    every string key of a dict literal in the module."""
    names: Set[str] = set()
    for mod in ctx.modules_named("metrics.py"):
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef) and node.name == "Counters":
                for stmt in node.body:
                    if (isinstance(stmt, ast.AnnAssign)
                            and isinstance(stmt.target, ast.Name)):
                        names.add(stmt.target.id)
            if isinstance(node, ast.Dict):
                for key in node.keys:
                    if (isinstance(key, ast.Constant)
                            and isinstance(key.value, str)):
                        names.add(key.value)
    return names


def _budget_key_line(source: str, key: str) -> int:
    for i, line in enumerate(source.splitlines(), start=1):
        if f'"{key}"' in line:
            return i
    return 1


def check_budget_keys(ctx: AnalysisContext) -> List[Finding]:
    """AC003: every budgets.json key resolves to an emitted metric."""
    if ctx.budgets_path is None:
        return []
    emitted = _emitted_metric_names(ctx)
    if not emitted:
        return []                         # no metrics module in scope
    try:
        source = ctx.budgets_path.read_text()
        budgets = json.loads(source)
    except (OSError, ValueError) as exc:
        return [Finding(
            file=ctx.budgets_path.name, line=1, col=0, rule="AC003",
            severity=SEVERITY_ERROR,
            message=f"could not load budgets file: {exc}")]

    findings: List[Finding] = []
    rel = ctx.budgets_path.name
    try:
        rel = ctx.budgets_path.relative_to(ctx.root).as_posix()
    except ValueError:
        pass
    for key in budgets:
        metric = key.split(":", 1)[1] if ":" in key else key
        base = metric[:-len("_per_request")] \
            if metric.endswith("_per_request") else metric
        candidates = {metric, base, f"kernel_{base}", f"kernel_{metric}"}
        if candidates & emitted:
            continue
        findings.append(Finding(
            file=rel, line=_budget_key_line(source, key), col=0,
            rule="AC003", severity=SEVERITY_ERROR,
            message=(f"budget key '{key}' does not resolve to any "
                     "metric emitted by core/metrics.py; the budget "
                     "gate would silently pass")))
    return findings


RULES = [
    Rule("AC001", "LaunchRecord lands on the launches accounting surface",
         check_launchrecord_sink),
    Rule("AC002", "each disposition path charges exactly one counter",
         check_disposition_paths),
    Rule("AC003", "budget keys resolve to emitted metrics",
         check_budget_keys),
]
