"""Async-safety rule (AS...).

The batching layer (PR 2) multiplexes every in-flight brTPF request
onto one event loop; a single blocking call inside an ``async def``
stalls the whole collector window and turns the measured batching win
into serialized latency. Blocking work belongs in the executor
(``loop.run_in_executor``) -- the analyzer flags direct blocking calls
inside coroutine bodies.
"""
from __future__ import annotations

import ast
from typing import List

from ..engine import AnalysisContext
from ..findings import SEVERITY_ERROR, Finding
from . import Rule

# Fully-dotted call names (and dotted prefixes) that block the loop.
# The serving edge (PR 7) runs every route handler as a coroutine on
# the shared loop, so loop-breaking calls (asyncio.run / uvicorn.run
# re-enter or replace the running loop) and sync HTTP clients are
# flagged alongside the classic sleep/subprocess offenders. Note the
# httpx entries are exact call names, not a prefix: the
# ``httpx.AsyncClient(...)`` constructor is loop-safe and must not
# false-positive.
_BLOCKING_DOTTED = {
    "time.sleep",
    "os.system",
    "os.popen",
    "socket.create_connection",
    "loop.run_until_complete",
    "asyncio.run",
    "uvicorn.run",
    "httpx.get",
    "httpx.post",
    "httpx.request",
}
_BLOCKING_PREFIXES = ("subprocess.", "urllib.request.", "requests.")
_BLOCKING_BARE = {"open", "input"}
# Zero-arg .result() is the concurrent.futures block-until-done idiom.
_BLOCKING_METHOD_NOARGS = {"result"}


def _dotted(node: ast.expr) -> str:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    else:
        parts.append("")
    return ".".join(reversed(parts))


def _blocking_reason(call: ast.Call) -> str:
    func = call.func
    if isinstance(func, ast.Name):
        if func.id in _BLOCKING_BARE:
            return f"'{func.id}()' performs synchronous I/O"
        return ""
    if not isinstance(func, ast.Attribute):
        return ""
    dotted = _dotted(func)
    if dotted in _BLOCKING_DOTTED:
        return f"'{dotted}()' blocks the event loop"
    if dotted.startswith(_BLOCKING_PREFIXES):
        return f"'{dotted}()' performs synchronous I/O"
    if (func.attr in _BLOCKING_METHOD_NOARGS and not call.args
            and not call.keywords):
        return (f"'.{func.attr}()' blocks until the future resolves; "
                "await it instead")
    return ""


def _walk_coroutine_body(func: ast.AsyncFunctionDef):
    """Yield nodes of the coroutine body, not descending into nested
    function definitions (nested async defs get their own visit)."""
    stack: List[ast.AST] = list(func.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def check_blocking_in_async(ctx: AnalysisContext) -> List[Finding]:
    """AS001: no blocking calls inside ``async def`` bodies."""
    findings: List[Finding] = []
    for mod in ctx.modules:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.AsyncFunctionDef):
                continue
            for inner in _walk_coroutine_body(node):
                if not isinstance(inner, ast.Call):
                    continue
                reason = _blocking_reason(inner)
                if reason:
                    findings.append(Finding(
                        file=mod.rel, line=inner.lineno,
                        col=inner.col_offset, rule="AS001",
                        severity=SEVERITY_ERROR,
                        message=(f"blocking call inside async def "
                                 f"'{node.name}': {reason} (use "
                                 "loop.run_in_executor or an async "
                                 "equivalent)")))
    return findings


RULES = [
    Rule("AS001", "no blocking calls inside async def bodies",
         check_blocking_in_async),
]
