"""repro-lint: repo-specific static analysis for the brTPF codebase.

Five PRs of growth established correctness invariants that, until this
package, existed only as prose in docs/ and as individual parity tests:
byte-identical selector backends, coherent cache invalidation through
the unified :class:`~repro.core.fragments.FragmentStore`, honest
``Counters`` accounting for every launch disposition, and launch-budget
gates keyed by metric names. The paper's whole argument rests on
measured request/transfer counts, so a new code path that silently
violates one of these invariants corrupts the evaluation itself -- this
analyzer fails CI the moment that happens instead of waiting for a
parity test to cover the new path.

Four rule groups over ``ast`` walks plus a lightweight intra-package
call graph (docs/analysis.md describes each rule and the invariant it
protects):

* **kernel-launch safety** (KL...): every ``pl.pallas_call`` site has
  static block shapes, power-of-two capacities and no traced Python
  scalar captures;
* **cache coherence** (CC...): mutations of ``TripleStore``/pattern
  data must reach a ``FragmentStore`` invalidation in the call graph,
  and nothing outside ``fragments.py`` touches the store's internals;
* **accounting integrity** (AC...): every ``LaunchRecord`` lands on a
  ``launches`` accounting surface, every disposition path increments
  exactly one launch counter, and every ``benchmarks/budgets.json`` key
  resolves to a metric ``core/metrics.py`` emits;
* **async safety** (AS...): no blocking calls inside ``async def``
  bodies.

Run it: ``python -m repro.analysis`` (text) or ``--format json``
(machine-readable); exits nonzero on any error-severity finding.
"""
from .engine import AnalysisContext, Module, load_context, run_analysis
from .findings import SEVERITY_ERROR, SEVERITY_WARNING, Finding
from .rules import ALL_RULES, Rule

__all__ = [
    "ALL_RULES",
    "AnalysisContext",
    "Finding",
    "Module",
    "Rule",
    "SEVERITY_ERROR",
    "SEVERITY_WARNING",
    "load_context",
    "run_analysis",
]
