"""Gradient compression: int8 quantized cross-replica reduction with
error feedback.

The wire format halves (vs bf16) / quarters (vs f32) the gradient
all-reduce bytes -- the dominant collective term of data-parallel
training at scale. Scheme (per leaf):

  1. agree on a scale: ``psum-max`` of |g| over the data axis (a scalar
     per leaf -- negligible bytes);
  2. quantize to int8 with stochastic-free round-to-nearest, carry the
     quantization error into the next step (error feedback, which keeps
     the scheme unbiased over time);
  3. all-reduce the int8 payload (accumulated in int32 to avoid
     overflow across replicas);
  4. dequantize with scale / replica count.

``compressed_psum_tree`` is meant to be used inside ``shard_map`` over
the data axis; the pure ``quantize``/``dequantize`` pair is also used
by the checkpoint layer for compressed checkpoints.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any

Q_MAX = 127.0


def quantize(g: jnp.ndarray, scale: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    g32 = g.astype(jnp.float32)
    if scale is None:
        scale = jnp.max(jnp.abs(g32)) / Q_MAX + 1e-12
    q = jnp.clip(jnp.round(g32 / scale), -Q_MAX, Q_MAX).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray,
               dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_with_feedback(g: jnp.ndarray, error: jnp.ndarray
                           ) -> Tuple[jnp.ndarray, jnp.ndarray,
                                      jnp.ndarray]:
    """(quantized, scale, new_error). ``error`` is the residual carried
    from the previous step (same shape as g, f32)."""
    g32 = g.astype(jnp.float32) + error
    q, scale = quantize(g32)
    new_error = g32 - dequantize(q, scale)
    return q, scale, new_error


def init_error_state(params: Params) -> Params:
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum_tree(grads: Params, error_state: Params,
                         axis_name: str) -> Tuple[Params, Params]:
    """int8 compressed all-reduce (mean) over ``axis_name``; call inside
    shard_map. Returns (reduced grads f32, new error state)."""
    n = jax.lax.psum(1, axis_name)

    def one(g, err):
        g32 = g.astype(jnp.float32) + err
        # scale agreement across replicas (tiny collective)
        local_max = jnp.max(jnp.abs(g32))
        scale = jax.lax.pmax(local_max, axis_name) / Q_MAX + 1e-12
        q = jnp.clip(jnp.round(g32 / scale), -Q_MAX,
                     Q_MAX).astype(jnp.int8)
        new_err = g32 - q.astype(jnp.float32) * scale
        # int8 payload, int32 accumulation (wire bytes: 1 per element)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return total.astype(jnp.float32) * scale / n, new_err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e, strict=True):
        rg, ne = one(g, e)
        out_g.append(rg)
        out_e.append(ne)
    return (jax.tree.unflatten(treedef, out_g),
            jax.tree.unflatten(treedef, out_e))
