"""The training loop: checkpoint/restart, failure recovery, stragglers.

Designed for the 1000+-node regime described in the brief; on this CPU
container the same code runs single-process and the failure paths are
exercised by tests through injection hooks.

Fault-tolerance model:
* **checkpoint/restart** -- async sharded checkpoints every
  ``ckpt_every`` steps; on any step failure the trainer restores the
  latest valid checkpoint and replays from there (up to
  ``max_restarts``).
* **node failure** -- in a real deployment a device failure surfaces as
  a distributed runtime error from the step function; the same
  restore-and-replay path handles it. ``failure_hook`` lets tests raise
  mid-run to exercise this.
* **straggler mitigation** -- per-step deadline: steps slower than
  ``straggler_factor`` x the rolling median are logged and counted; the
  launcher can respond (re-slice data, drop the slow host) via the
  ``on_straggler`` callback. On one host this is advisory only.
* **elastic scaling** -- checkpoints are mesh-independent (host numpy +
  manifest), so ``Trainer.restore_onto`` can re-shard the state onto a
  different mesh/sharding tree (tested with a resharding restore).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import numpy as np

from . import checkpoint as ckpt


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 50
    ckpt_keep: int = 3
    max_restarts: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10


@dataclasses.dataclass
class TrainerReport:
    steps_run: int = 0
    restarts: int = 0
    stragglers: int = 0
    losses: List[float] = dataclasses.field(default_factory=list)
    final_loss: float = float("nan")


class Trainer:
    def __init__(self, cfg: TrainerConfig, step_fn: Callable,
                 params: Any, opt_state: Any,
                 failure_hook: Optional[Callable[[int], None]] = None,
                 on_straggler: Optional[Callable[[int, float],
                                                 None]] = None) -> None:
        self.cfg = cfg
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.failure_hook = failure_hook
        self.on_straggler = on_straggler
        self.checkpointer = ckpt.AsyncCheckpointer(cfg.ckpt_dir,
                                                   keep=cfg.ckpt_keep)
        self.step = 0

    # -- checkpoint/restart ----------------------------------------------------

    def _state_tree(self) -> Dict[str, Any]:
        return {"params": self.params, "opt_state": self.opt_state}

    def try_resume(self, shardings: Any = None) -> bool:
        latest = ckpt.latest_step(self.cfg.ckpt_dir)
        if latest is None:
            return False
        step, tree = ckpt.restore(self.cfg.ckpt_dir, self._state_tree(),
                                  shardings)
        self.params = tree["params"]
        self.opt_state = tree["opt_state"]
        self.step = step
        return True

    def restore_onto(self, shardings: Any) -> None:
        """Elastic path: restore latest checkpoint re-sharded onto a new
        mesh (shardings pytree matching the state tree)."""
        step, tree = ckpt.restore(self.cfg.ckpt_dir, self._state_tree(),
                                  shardings)
        self.params = tree["params"]
        self.opt_state = tree["opt_state"]
        self.step = step

    # -- the loop ----------------------------------------------------------------

    def train(self, data_iter: Iterator[Dict[str, Any]]) -> TrainerReport:
        report = TrainerReport()
        cfg = self.cfg
        durations: List[float] = []
        restarts = 0

        while self.step < cfg.total_steps:
            try:
                batch = next(data_iter)
                if self.failure_hook is not None:
                    self.failure_hook(self.step)
                t0 = time.perf_counter()
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0

                # straggler detection against the rolling median
                durations.append(dt)
                if len(durations) >= 8:
                    med = float(np.median(durations[-32:]))
                    if dt > cfg.straggler_factor * med:
                        report.stragglers += 1
                        if self.on_straggler is not None:
                            self.on_straggler(self.step, dt)

                self.step += 1
                report.steps_run += 1
                report.losses.append(loss)
                report.final_loss = loss

                if self.step % cfg.ckpt_every == 0:
                    self.checkpointer.save(self.step, self._state_tree())
            except (StopIteration, KeyboardInterrupt):
                break
            except Exception:
                restarts += 1
                report.restarts = restarts
                if restarts > cfg.max_restarts:
                    raise
                # failure recovery: restore latest valid checkpoint
                self.checkpointer.wait()
                if not self.try_resume():
                    # no checkpoint yet: restart from current state
                    pass

        self.checkpointer.wait()
        return report
