"""Sharded, fault-tolerant checkpointing (no orbax in this environment).

Layout: one directory per step --

    <dir>/step_000123/
        leaf_000.npy ... leaf_NNN.npy     (one file per pytree leaf)
        manifest.json                     (tree structure, shapes, dtypes,
                                           per-leaf byte sizes, step)
        COMMIT                            (written last: atomicity marker)

Fault-tolerance contract:
* writes go to ``step_N.tmp`` and are renamed only after COMMIT exists,
  so a crash mid-write never corrupts the latest valid checkpoint;
* ``latest_step`` skips directories without COMMIT (partial writes);
* ``restore`` verifies per-leaf sizes against the manifest and falls
  back to the previous valid checkpoint on mismatch;
* ``AsyncCheckpointer`` runs saves on a background thread (training
  continues; ``wait()`` joins at shutdown) -- the async-checkpoint trick
  from the brief;
* restore accepts a ``shardings`` pytree, so a checkpoint written on one
  mesh can be restored onto another (elastic re-scale path).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

COMMIT_FILE = "COMMIT"


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def save(directory: str, step: int, tree: Any) -> str:
    """Synchronous atomic checkpoint write. Returns the final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    manifest: Dict[str, Any] = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "path": _path_str(path),
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "nbytes": int(arr.nbytes),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # commit marker then atomic rename
    with open(os.path.join(tmp, COMMIT_FILE), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def valid_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, COMMIT_FILE)):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = valid_steps(directory)
    return steps[-1] if steps else None


class CheckpointCorrupt(RuntimeError):
    pass


def _restore_one(directory: str, step: int, tree_like: Any,
                 shardings: Any = None) -> Any:
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    if len(manifest["leaves"]) != len(leaves):
        raise CheckpointCorrupt(
            f"leaf count mismatch: ckpt {len(manifest['leaves'])} vs "
            f"tree {len(leaves)}")
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    out = []
    for meta, _like, sh in zip(manifest["leaves"], leaves, shard_leaves,
                               strict=True):
        fpath = os.path.join(path, meta["file"])
        if (not os.path.exists(fpath)
                or os.path.getsize(fpath) < meta["nbytes"]):
            raise CheckpointCorrupt(f"missing/truncated leaf {fpath}")
        arr = np.load(fpath)
        if list(arr.shape) != meta["shape"]:
            raise CheckpointCorrupt(f"shape mismatch in {fpath}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def restore(directory: str, tree_like: Any, shardings: Any = None,
            step: Optional[int] = None) -> Any:
    """Restore the requested (default: latest) valid checkpoint, falling
    back to older ones if the newest turns out corrupt."""
    steps = valid_steps(directory)
    if step is not None:
        steps = [s for s in steps if s == step]
    if not steps:
        raise FileNotFoundError(f"no valid checkpoint in {directory}")
    for s in reversed(steps):
        try:
            return s, _restore_one(directory, s, tree_like, shardings)
        except CheckpointCorrupt:
            continue
    raise CheckpointCorrupt(f"all checkpoints in {directory} corrupt")


def cleanup(directory: str, keep: int = 3) -> None:
    steps = valid_steps(directory)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Background-thread checkpoint writer (one in flight at a time)."""

    def __init__(self, directory: str, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.saved_steps: List[int] = []

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        # snapshot to host before handing to the thread (device buffers
        # may be donated by the next step)
        host_tree = jax.tree.map(np.asarray, tree)

        def work():
            save(self.directory, step, host_tree)
            cleanup(self.directory, self.keep)
            self.saved_steps.append(step)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
