"""Optimizers from scratch (no optax in this environment).

AdamW with decoupled weight decay, fp32 moments, global-norm clipping,
and linear-warmup + cosine-decay schedules. The optimizer state pytree
mirrors params, so the sharding layer shards moments exactly like their
parameters (ZeRO-style sharded moments are a rules change, not a code
change).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray       # int32 scalar
    mu: Params              # first moment (fp32)
    nu: Params              # second moment (fp32)


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jnp.ndarray], jnp.ndarray]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params: Params) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree.map(jnp.copy, zeros))

    def update(self, grads: Params, state: AdamWState,
               params: Params) -> Tuple[Params, AdamWState, Dict]:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(grads)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * g * g,
                          state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self.learning_rate(step)

        def upd(p, m, n):
            mhat = m / bc1
            nhat = n / bc2
            u = mhat / (jnp.sqrt(nhat) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype)

        updates = jax.tree.map(upd, params, mu, nu)
        return (updates, AdamWState(step=step, mu=mu, nu=nu),
                {"grad_norm": gnorm, "lr": lr})


def apply_updates(params: Params, updates: Params) -> Params:
    return jax.tree.map(lambda p, u: p + u, params, updates)


def global_norm(tree: Params) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def warmup_cosine(peak_lr: float, warmup_steps: int,
                  total_steps: int, min_ratio: float = 0.1):
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(
            jnp.pi * prog))
        return peak_lr * jnp.minimum(warm, cos)

    return schedule


def constant_lr(lr: float):
    return lambda step: jnp.full((), lr, jnp.float32)
