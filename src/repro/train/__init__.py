"""Training runtime: optimizer, loop, checkpointing, compression."""
