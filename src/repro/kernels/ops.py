"""Public jit'd wrappers around the Pallas kernels.

Handles padding to tile multiples, backend selection (``interpret=True``
whenever the default backend is not TPU -- this container is CPU-only and
validates kernels in interpret mode, the TPU path is the target), and the
jnp-side epilogues (mask -> compacted indices).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .bindjoin import (DEFAULT_BM, DEFAULT_BT, DEFAULT_FUSED_BT,
                       bindjoin_fused_pallas, bindjoin_grouped_pallas,
                       bindjoin_pallas)
from .tpf_match import DEFAULT_BR, LANES, tpf_match_pallas


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, mult: int, fill) -> jnp.ndarray:
    n = x.shape[0]
    rem = (-n) % mult
    if rem == 0 and n > 0:
        return x
    pad = max(rem, mult if n == 0 else rem)
    return jnp.concatenate(
        [x, jnp.full((pad,), fill, dtype=x.dtype)], axis=0)


def bindjoin(cand: jnp.ndarray, patterns: jnp.ndarray,
             pat_valid: jnp.ndarray, *, bt: int = DEFAULT_BT,
             bm: int = DEFAULT_BM,
             use_pallas: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Bind-join filter over candidate triples.

    Args:
      cand: int32 [T, 3] candidate data triples.
      patterns: int32 [M, 3] instantiated patterns (component < 0 = wild).
      pat_valid: int32 [M] (0 marks padding rows).

    Returns:
      keep: bool [T]  -- triple joins with >= 1 attached mapping.
      idx:  int32 [T] -- first matching pattern index (= padded M if none).
    """
    t = cand.shape[0]
    cs = _pad_to(cand[:, 0], bt, 0)
    cp = _pad_to(cand[:, 1], bt, 0)
    co = _pad_to(cand[:, 2], bt, 0)
    ps = _pad_to(patterns[:, 0], bm, 0)
    pp = _pad_to(patterns[:, 1], bm, 0)
    po = _pad_to(patterns[:, 2], bm, 0)
    pv = _pad_to(pat_valid.astype(jnp.int32), bm, 0)
    if use_pallas:
        keep, idx = bindjoin_pallas(cs, cp, co, ps, pp, po, pv,
                                    bt=bt, bm=bm,
                                    interpret=_use_interpret())
    else:
        keep, idx = ref.bindjoin_ref(cs, cp, co, ps, pp, po, pv)
        keep = keep.astype(jnp.int32)
    return keep[:t].astype(bool), idx[:t]


def padded_pattern_slots(m: int, bm: int = DEFAULT_BM) -> int:
    """Per-group pattern-slot count after padding to the m-tile size --
    the single source of truth for the launch geometry that
    ``bindjoin_grouped`` uses and the selector/sim cost models charge."""
    return max(m + (-m) % bm, bm)


def bindjoin_grouped(cand: jnp.ndarray, patterns: jnp.ndarray,
                     pat_valid: jnp.ndarray, *, bt: int = DEFAULT_BT,
                     bm: int = DEFAULT_BM, use_pallas: bool = True
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Grouped bind-join filter: G pattern sets, one candidate pass.

    Args:
      cand: int32 [T, 3] candidate data triples (shared by all groups).
      patterns: int32 [G, M, 3] per-group instantiated patterns
        (component < 0 = wild).
      pat_valid: int32 [G, M] (0 marks padding rows).

    Returns:
      keep:   bool  [T, G] -- triple joins with >= 1 of group g's patterns.
      idx:    int32 [T, G] -- first matching within-group pattern index
        (= padded M if none).
      nmatch: int32 [T, G] -- matching-pattern count (cnt contribution).
    """
    t = cand.shape[0]
    g, m = patterns.shape[0], patterns.shape[1]
    cs = _pad_to(cand[:, 0], bt, 0)
    cp = _pad_to(cand[:, 1], bt, 0)
    co = _pad_to(cand[:, 2], bt, 0)
    mp = padded_pattern_slots(m, bm)

    def pad_flat(x, fill):
        out = jnp.full((g, mp), fill, dtype=x.dtype)
        return out.at[:, :m].set(x).reshape(g * mp)

    ps = pad_flat(patterns[:, :, 0], 0)
    pp = pad_flat(patterns[:, :, 1], 0)
    po = pad_flat(patterns[:, :, 2], 0)
    pv = pad_flat(pat_valid.astype(jnp.int32), 0)
    if use_pallas:
        keep, idx, nmatch = bindjoin_grouped_pallas(
            cs, cp, co, ps, pp, po, pv, groups=g, bt=bt, bm=bm,
            interpret=_use_interpret())
    else:
        keep, idx, nmatch = ref.bindjoin_grouped_ref(
            cs, cp, co, ps.reshape(g, mp), pp.reshape(g, mp),
            po.reshape(g, mp), pv.reshape(g, mp))
        keep = keep.astype(jnp.int32)
    return keep[:t].astype(bool), idx[:t], nmatch[:t]


def bindjoin_fused(cand: jnp.ndarray, seg_of_tile: jnp.ndarray,
                   patterns: jnp.ndarray, pat_valid: jnp.ndarray, *,
                   bt: int = DEFAULT_FUSED_BT, bm: int = DEFAULT_BM,
                   use_pallas: bool = True
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Cross-pattern fused bind-join: S segments share one candidate pass.

    Args:
      cand: int32 [T, 3] concatenated candidate stream, T % bt == 0;
        each bt-tile's rows belong to one segment (callers tile-align
        every segment's block -- ``kernel_selectors.select_fused``).
      seg_of_tile: int32 [T // bt] per-tile segment id (-1 = dead tile).
      patterns: int32 [S, G, M, 3] per-segment per-group instantiated
        patterns (component < 0 = wild).
      pat_valid: int32 [S, G, M] (0 marks padding rows).

    Returns:
      keep:   bool  [T, G] -- row matches its own segment's group g.
      idx:    int32 [T, G] -- first matching within-group pattern index
        (= padded M if none).
      nmatch: int32 [T, G] -- matching-pattern count (cnt contribution).
    """
    t = cand.shape[0]
    s, g, m = patterns.shape[0], patterns.shape[1], patterns.shape[2]
    assert t % bt == 0, (t, bt)
    mp = padded_pattern_slots(m, bm)

    def pad_flat(x, fill):
        out = jnp.full((s, g, mp), fill, dtype=x.dtype)
        return out.at[:, :, :m].set(x).reshape(s * g * mp)

    ps = pad_flat(patterns[:, :, :, 0], 0)
    pp = pad_flat(patterns[:, :, :, 1], 0)
    po = pad_flat(patterns[:, :, :, 2], 0)
    pv = pad_flat(pat_valid.astype(jnp.int32), 0)
    if use_pallas:
        keep, idx, nmatch = bindjoin_fused_pallas(
            seg_of_tile.astype(jnp.int32), cand[:, 0], cand[:, 1],
            cand[:, 2], ps, pp, po, pv, segments=s, groups=g, bt=bt, bm=bm,
            interpret=_use_interpret())
    else:
        seg_of_row = jnp.repeat(seg_of_tile.astype(jnp.int32), bt)
        keep, idx, nmatch = ref.bindjoin_fused_ref(
            cand[:, 0], cand[:, 1], cand[:, 2], seg_of_row,
            ps.reshape(s, g, mp), pp.reshape(s, g, mp),
            po.reshape(s, g, mp), pv.reshape(s, g, mp))
        keep = keep.astype(jnp.int32)
    return keep.astype(bool), idx, nmatch


def tpf_match(cand: jnp.ndarray, pattern_vec: jnp.ndarray, *,
              br: int = DEFAULT_BR,
              use_pallas: bool = True) -> jnp.ndarray:
    """Single-pattern match mask over candidate triples.

    Args:
      cand: int32 [T, 3]; pattern_vec: int32 [8]
        = [s, p, o, eq_sp, eq_so, eq_po, 0, 0], components < 0 wild.
    Returns: bool [T].
    """
    t = cand.shape[0]
    tile = br * LANES
    cs = _pad_to(cand[:, 0], tile, -1)
    cp = _pad_to(cand[:, 1], tile, -2)   # s != p for padding rows ->
    co = _pad_to(cand[:, 2], tile, -3)   # eq_* constraints reject them
    if use_pallas:
        mask = tpf_match_pallas(cs, cp, co, pattern_vec, br=br,
                                interpret=_use_interpret())
    else:
        mask = ref.tpf_match_ref(cs, cp, co, pattern_vec).astype(jnp.int32)
    return mask[:t].astype(bool)


@functools.partial(jax.jit, static_argnames=("capacity",))
def compact_mask(mask: jnp.ndarray, capacity: int):
    """Turn a bool mask into (indices[capacity], count) with -1 padding --
    the fixed-shape 'page' epilogue used by the federation path."""
    count = jnp.sum(mask.astype(jnp.int32))
    order = jnp.argsort(~mask, stable=True)        # True rows first
    n = order.shape[0]
    if n < capacity:
        order = jnp.concatenate(
            [order, jnp.full((capacity - n,), -1, order.dtype)])
    idx = order[:capacity]
    valid = jnp.arange(capacity) < count
    return jnp.where(valid, idx, -1), count


def pattern_vec_from(tp_tuple, eq_sp=0, eq_so=0, eq_po=0) -> np.ndarray:
    """Host helper: build the int32[8] pattern vector for tpf_match."""
    s, p, o = tp_tuple
    return np.array([s, p, o, eq_sp, eq_so, eq_po, 0, 0], dtype=np.int32)
