"""Pallas TPU kernel: the server-side bind-join filter.

This is the compute hot-spot of a brTPF server: for every candidate
triple in the fragment's prefix range, decide whether it matches at least
one of the (instantiated, deduped) patterns derived from the attached
solution mappings -- an OR-reduction over an outer-product compare grid.

TPU adaptation (vs. the paper's per-pattern HDT lookups): the Java
servlet loops over the instantiated patterns and queries the backend per
pattern. On TPU we invert the loop: stream candidate triples through VMEM
once and compare each tile against *all* patterns resident in VMEM --
one HBM pass over the candidates instead of M passes, and the (BT x BM)
compare grid maps onto the VPU's (8 x 128) vector lanes.

Tiling:
  grid = (ceil(T / BT), ceil(M / BM));  m is the inner (reduction) axis.
  candidate components: three (BT, 1)-blocks replicated across the m axis
  pattern components:   three (1, BM)-blocks replicated across the t axis
  outputs keep/idx:     (BT, 1)-blocks accumulated across m steps
    (output revisiting across the inner grid axis is the standard Pallas
     reduction idiom: initialize at m == 0, combine otherwise).

VMEM per step at (BT, BM) = (1024, 128): compare grid 1024*128*4 B
= 512 KiB for the int32 index grid plus 3 * 4 KiB pattern/candidate
vectors -- comfortably inside the ~16 MiB VMEM budget, and the minor
dimension is a full 128-lane multiple.

The *grouped* variant serves the server's cross-request batching: G
concurrent brTPF requests for the same triple pattern share one HBM pass
over the (identical) candidate range. Their pattern sets are padded to a
common M and laid out side by side on the m axis; the m-tile -> group
mapping is static (tiles_per_group = M // BM), so outputs land in
per-group (BT, 1) columns of (T, G) result arrays, and the per-row match
*count* output gives each request its Definition-2 ``cnt`` estimate from
the same launch.

The kernel is agnostic to what the candidate block contains and in what
order: since the Omega-restricted pruning PR (docs/pruning.md) callers
stream the merged union of per-binding sub-ranges -- a subset of the
prefix range in mixed physical order -- whenever the attached mappings
allow it. Everything here only requires that each candidate triple
appear exactly once (the hosts' span-merge/dedup contract); the
first-match/ordering semantics are restored by the host epilogue.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BT = 1024
DEFAULT_BM = 128

# Fused launches tile the candidate stream finer than the same-pattern
# grouped kernel: each segment's block is tile-aligned independently, so
# a smaller tile bounds the per-segment alignment waste while staying a
# multiple of the VPU's 8-row sublane.
DEFAULT_FUSED_BT = 256


def _bindjoin_kernel(cs_ref, cp_ref, co_ref, ps_ref, pp_ref, po_ref,
                     pv_ref, keep_ref, idx_ref, *, bm: int, m_total: int):
    m_step = pl.program_id(1)

    cs = cs_ref[...]          # (BT, 1) int32
    cp = cp_ref[...]
    co = co_ref[...]
    ps = ps_ref[...]          # (1, BM) int32
    pp = pp_ref[...]
    po = po_ref[...]
    pv = pv_ref[...]          # (1, BM) int32 validity

    comp = (
        ((ps < 0) | (cs == ps))
        & ((pp < 0) | (cp == pp))
        & ((po < 0) | (co == po))
        & (pv != 0)
    )                          # (BT, BM) bool

    any_m = jnp.any(comp, axis=1, keepdims=True)              # (BT, 1)
    # Global pattern index of each column in this m-tile.
    col = jax.lax.broadcasted_iota(jnp.int32, comp.shape, 1)
    col = col + m_step * bm
    big = jnp.int32(m_total)
    first = jnp.min(jnp.where(comp, col, big), axis=1,
                    keepdims=True).astype(jnp.int32)          # (BT, 1)

    @pl.when(m_step == 0)
    def _init():
        keep_ref[...] = any_m.astype(jnp.int32)
        idx_ref[...] = first

    @pl.when(m_step != 0)
    def _accum():
        keep_ref[...] = jnp.maximum(keep_ref[...], any_m.astype(jnp.int32))
        idx_ref[...] = jnp.minimum(idx_ref[...], first)


@functools.partial(jax.jit, static_argnames=("bt", "bm", "interpret"))
def bindjoin_pallas(cand_s, cand_p, cand_o, pat_s, pat_p, pat_o, pat_valid,
                    *, bt: int = DEFAULT_BT, bm: int = DEFAULT_BM,
                    interpret: bool = False):
    """Tiled bind-join filter. Inputs must be padded: T % bt == 0 and
    M % bm == 0 (``ops.bindjoin`` handles padding). Returns
    (keep int32[T], idx int32[T]) with idx == M_padded when no match."""
    t = cand_s.shape[0]
    m = pat_s.shape[0]
    assert t % bt == 0 and m % bm == 0, (t, m, bt, bm)

    cand2 = lambda x: x.reshape(t, 1)
    pat2 = lambda x: x.reshape(1, m)

    grid = (t // bt, m // bm)
    kernel = functools.partial(_bindjoin_kernel, bm=bm, m_total=m)
    keep, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),   # cand s
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),   # cand p
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),   # cand o
            pl.BlockSpec((1, bm), lambda i, j: (0, j)),   # pat s
            pl.BlockSpec((1, bm), lambda i, j: (0, j)),   # pat p
            pl.BlockSpec((1, bm), lambda i, j: (0, j)),   # pat o
            pl.BlockSpec((1, bm), lambda i, j: (0, j)),   # pat valid
        ],
        out_specs=[
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, 1), jnp.int32),
            jax.ShapeDtypeStruct((t, 1), jnp.int32),
        ],
        interpret=interpret,
    )(cand2(cand_s), cand2(cand_p), cand2(cand_o),
      pat2(pat_s), pat2(pat_p), pat2(pat_o), pat2(pat_valid))
    return keep.reshape(t), idx.reshape(t)


def _bindjoin_grouped_kernel(cs_ref, cp_ref, co_ref, ps_ref, pp_ref,
                             po_ref, pv_ref, keep_ref, idx_ref, nmatch_ref,
                             *, bm: int, m_per_group: int):
    tiles_per_group = m_per_group // bm
    m_step = pl.program_id(1) % tiles_per_group   # m-tile within the group

    cs = cs_ref[...]          # (BT, 1) int32
    cp = cp_ref[...]
    co = co_ref[...]
    ps = ps_ref[...]          # (1, BM) int32, this group's pattern tile
    pp = pp_ref[...]
    po = po_ref[...]
    pv = pv_ref[...]          # (1, BM) int32 validity

    comp = (
        ((ps < 0) | (cs == ps))
        & ((pp < 0) | (cp == pp))
        & ((po < 0) | (co == po))
        & (pv != 0)
    )                          # (BT, BM) bool

    any_m = jnp.any(comp, axis=1, keepdims=True)              # (BT, 1)
    # dtype pinned: under an enable_x64 context (the sharded windowed
    # path traces with int64 keys live) the sum would promote to int64
    # and no longer match the int32 output ref.
    cnt_m = jnp.sum(comp.astype(jnp.int32), axis=1,
                    keepdims=True).astype(jnp.int32)          # (BT, 1)
    # Within-group pattern index of each column in this m-tile.
    col = jax.lax.broadcasted_iota(jnp.int32, comp.shape, 1)
    col = col + m_step * bm
    big = jnp.int32(m_per_group)
    first = jnp.min(jnp.where(comp, col, big), axis=1,
                    keepdims=True).astype(jnp.int32)          # (BT, 1)

    @pl.when(m_step == 0)
    def _init():
        keep_ref[...] = any_m.astype(jnp.int32)
        idx_ref[...] = first
        nmatch_ref[...] = cnt_m

    @pl.when(m_step != 0)
    def _accum():
        keep_ref[...] = jnp.maximum(keep_ref[...], any_m.astype(jnp.int32))
        idx_ref[...] = jnp.minimum(idx_ref[...], first)
        nmatch_ref[...] = nmatch_ref[...] + cnt_m


@functools.partial(jax.jit,
                   static_argnames=("groups", "bt", "bm", "interpret"))
def bindjoin_grouped_pallas(cand_s, cand_p, cand_o, pat_s, pat_p, pat_o,
                            pat_valid, *, groups: int,
                            bt: int = DEFAULT_BT, bm: int = DEFAULT_BM,
                            interpret: bool = False):
    """Grouped bind-join filter: one candidate pass, G pattern sets.

    Pattern inputs are flat ``int32 [G * Mp]`` with ``Mp`` (= per-group
    padded pattern count) a multiple of ``bm``; candidates ``int32 [T]``
    with ``T`` a multiple of ``bt`` (``ops.bindjoin_grouped`` pads).
    Returns (keep int32[T, G], idx int32[T, G], nmatch int32[T, G]) where
    ``idx == Mp`` when a row matches none of group g's patterns and
    ``nmatch`` counts group g's matching patterns per row.
    """
    t = cand_s.shape[0]
    gm = pat_s.shape[0]
    assert gm % groups == 0, (gm, groups)
    mp = gm // groups
    assert t % bt == 0 and mp % bm == 0, (t, mp, bt, bm)
    tiles_per_group = mp // bm

    cand2 = lambda x: x.reshape(t, 1)
    pat2 = lambda x: x.reshape(1, gm)

    grid = (t // bt, gm // bm)
    kernel = functools.partial(_bindjoin_grouped_kernel, bm=bm,
                               m_per_group=mp)
    out_spec = pl.BlockSpec((bt, 1),
                            lambda i, j: (i, j // tiles_per_group))
    keep, idx, nmatch = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),   # cand s
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),   # cand p
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),   # cand o
            pl.BlockSpec((1, bm), lambda i, j: (0, j)),   # pat s
            pl.BlockSpec((1, bm), lambda i, j: (0, j)),   # pat p
            pl.BlockSpec((1, bm), lambda i, j: (0, j)),   # pat o
            pl.BlockSpec((1, bm), lambda i, j: (0, j)),   # pat valid
        ],
        out_specs=[out_spec, out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((t, groups), jnp.int32),
            jax.ShapeDtypeStruct((t, groups), jnp.int32),
            jax.ShapeDtypeStruct((t, groups), jnp.int32),
        ],
        interpret=interpret,
    )(cand2(cand_s), cand2(cand_p), cand2(cand_o),
      pat2(pat_s), pat2(pat_p), pat2(pat_o), pat2(pat_valid))
    return keep, idx, nmatch


def _bindjoin_fused_kernel(seg_ref, cs_ref, cp_ref, co_ref, ps_ref, pp_ref,
                           po_ref, pv_ref, keep_ref, idx_ref, nmatch_ref,
                           *, bm: int, m_per_group: int, m_per_seg: int):
    """Heterogeneous-batch bind-join: the kernel resolves its segment.

    Each candidate tile carries a segment id (``seg_ref``, one scalar per
    t-tile); the flat pattern table holds every segment's slot block side
    by side, so the tile's pattern slice starts at
    ``seg * m_per_seg + j * bm`` -- a dynamic ``pl.ds`` slice into the
    VMEM-resident table. Dead padding tiles carry segment id -1 and
    match nothing.
    """
    tiles_per_group = m_per_group // bm
    j = pl.program_id(1)
    m_step = j % tiles_per_group     # m-tile within this tile's group

    seg = seg_ref[0, 0]              # this candidate tile's segment id
    live = seg >= 0
    col0 = jnp.maximum(seg, 0) * m_per_seg + j * bm

    cs = cs_ref[...]                 # (BT, 1) int32
    cp = cp_ref[...]
    co = co_ref[...]
    ps = ps_ref[:, pl.ds(col0, bm)]  # (1, BM) -- this segment's slot tile
    pp = pp_ref[:, pl.ds(col0, bm)]
    po = po_ref[:, pl.ds(col0, bm)]
    pv = pv_ref[:, pl.ds(col0, bm)]

    comp = (
        ((ps < 0) | (cs == ps))
        & ((pp < 0) | (cp == pp))
        & ((po < 0) | (co == po))
        & (pv != 0)
        & live
    )                                # (BT, BM) bool

    any_m = jnp.any(comp, axis=1, keepdims=True)              # (BT, 1)
    cnt_m = jnp.sum(comp.astype(jnp.int32), axis=1,
                    keepdims=True).astype(jnp.int32)          # (BT, 1)
    col = jax.lax.broadcasted_iota(jnp.int32, comp.shape, 1)
    col = col + m_step * bm
    big = jnp.int32(m_per_group)
    first = jnp.min(jnp.where(comp, col, big), axis=1,
                    keepdims=True).astype(jnp.int32)          # (BT, 1)

    @pl.when(m_step == 0)
    def _init():
        keep_ref[...] = any_m.astype(jnp.int32)
        idx_ref[...] = first
        nmatch_ref[...] = cnt_m

    @pl.when(m_step != 0)
    def _accum():
        keep_ref[...] = jnp.maximum(keep_ref[...], any_m.astype(jnp.int32))
        idx_ref[...] = jnp.minimum(idx_ref[...], first)
        nmatch_ref[...] = nmatch_ref[...] + cnt_m


@functools.partial(jax.jit,
                   static_argnames=("segments", "groups", "bt", "bm",
                                    "interpret"))
def bindjoin_fused_pallas(seg_of_tile, cand_s, cand_p, cand_o, pat_s, pat_p,
                          pat_o, pat_valid, *, segments: int, groups: int,
                          bt: int = DEFAULT_FUSED_BT, bm: int = DEFAULT_BM,
                          interpret: bool = False):
    """Cross-pattern fused bind-join: S segments, one launch.

    ``seg_of_tile`` is int32 ``[T // bt]`` mapping each candidate tile to
    its segment (-1 = dead padding tile). Pattern inputs are flat int32
    ``[segments * groups * Mp]`` slot tables -- per segment, ``groups``
    pattern sets of ``Mp`` (multiple of ``bm``) slots. Candidates are
    int32 ``[T]`` with ``T`` a multiple of ``bt``; every tile's rows
    belong to one segment (``ops.bindjoin_fused`` marshals/pads).

    Returns (keep, idx, nmatch) int32 ``[T, groups]`` where column g of a
    row is that row's result against *its own segment's* group-g pattern
    set (``idx == Mp`` when no match).
    """
    t = cand_s.shape[0]
    sgm = pat_s.shape[0]
    assert sgm % (segments * groups) == 0, (sgm, segments, groups)
    mp = sgm // (segments * groups)
    assert t % bt == 0 and mp % bm == 0, (t, mp, bt, bm)
    assert seg_of_tile.shape[0] == t // bt, (seg_of_tile.shape, t, bt)
    tiles_per_group = mp // bm
    m_per_seg = groups * mp

    cand2 = lambda x: x.reshape(t, 1)
    pat2 = lambda x: x.reshape(1, sgm)

    grid = (t // bt, m_per_seg // bm)
    kernel = functools.partial(_bindjoin_fused_kernel, bm=bm,
                               m_per_group=mp, m_per_seg=m_per_seg)
    out_spec = pl.BlockSpec((bt, 1),
                            lambda i, j: (i, j // tiles_per_group))
    keep, idx, nmatch = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),    # segment id
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),   # cand s
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),   # cand p
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),   # cand o
            pl.BlockSpec((1, sgm), lambda i, j: (0, 0)),  # pat s (table)
            pl.BlockSpec((1, sgm), lambda i, j: (0, 0)),  # pat p
            pl.BlockSpec((1, sgm), lambda i, j: (0, 0)),  # pat o
            pl.BlockSpec((1, sgm), lambda i, j: (0, 0)),  # pat valid
        ],
        out_specs=[out_spec, out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((t, groups), jnp.int32),
            jax.ShapeDtypeStruct((t, groups), jnp.int32),
            jax.ShapeDtypeStruct((t, groups), jnp.int32),
        ],
        interpret=interpret,
    )(seg_of_tile.reshape(t // bt, 1),
      cand2(cand_s), cand2(cand_p), cand2(cand_o),
      pat2(pat_s), pat2(pat_p), pat2(pat_o), pat2(pat_valid))
    return keep, idx, nmatch
