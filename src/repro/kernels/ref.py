"""Pure-jnp oracles for the Pallas kernels.

These are the semantic ground truth: every kernel test sweeps shapes and
dtypes and asserts allclose/array_equal against these functions.

Conventions (shared with the kernels):
* triples are structure-of-arrays int32 ``s[T], p[T], o[T]`` -- lane-
  friendly on TPU (the AoS ``[T, 3]`` layout would put 3 in the minor
  dimension, wasting 125/128 lanes);
* *instantiated* pattern components use ``component < 0`` as wildcard;
* validity masks flag padding rows (fixed shapes on TPU).
"""
from __future__ import annotations

import jax.numpy as jnp


def bindjoin_ref(cand_s, cand_p, cand_o, pat_s, pat_p, pat_o, pat_valid):
    """Reference bindings-restricted filter.

    For candidate triples t (SoA, [T]) and M instantiated patterns
    ([M], wildcard < 0, ``pat_valid`` zero for padding), compute:

      keep[T]  -- does t match at least one valid instantiated pattern?
      idx[T]   -- smallest matching pattern index (T-side provenance),
                  or M if none.

    This is the server-side semantics of Definition 1 *after* step 1-3 of
    the section-4.1 algorithm (patterns already instantiated + deduped).
    """
    t_s, m = cand_s.shape[0], pat_s.shape[0]
    cs = cand_s[:, None]
    cp = cand_p[:, None]
    co = cand_o[:, None]
    ms = pat_s[None, :]
    mp = pat_p[None, :]
    mo = pat_o[None, :]
    comp = (
        ((ms < 0) | (cs == ms))
        & ((mp < 0) | (cp == mp))
        & ((mo < 0) | (co == mo))
        & (pat_valid[None, :] != 0)
    )  # [T, M]
    keep = jnp.any(comp, axis=1)
    big = jnp.int32(m)
    idx_grid = jnp.where(comp, jnp.arange(m, dtype=jnp.int32)[None, :], big)
    idx = jnp.min(idx_grid, axis=1).astype(jnp.int32)
    return keep, idx


def bindjoin_grouped_ref(cand_s, cand_p, cand_o, pat_s, pat_p, pat_o,
                         pat_valid):
    """Reference grouped bind-join filter.

    Pattern components are ``[G, M]`` (G request groups sharing one
    candidate pass). Returns per-group results:

      keep[T, G]    -- row matches >= 1 valid pattern of group g
      idx[T, G]     -- smallest matching within-group pattern index
                       (= M when none)
      nmatch[T, G]  -- number of group g's patterns the row matches
                       (the Definition-2 ``cnt`` contribution of the row)
    """
    m = pat_s.shape[1]
    cs = cand_s[:, None, None]
    cp = cand_p[:, None, None]
    co = cand_o[:, None, None]
    ms = pat_s[None, :, :]
    mp = pat_p[None, :, :]
    mo = pat_o[None, :, :]
    comp = (
        ((ms < 0) | (cs == ms))
        & ((mp < 0) | (cp == mp))
        & ((mo < 0) | (co == mo))
        & (pat_valid[None, :, :] != 0)
    )  # [T, G, M]
    keep = jnp.any(comp, axis=-1)
    nmatch = jnp.sum(comp.astype(jnp.int32), axis=-1)
    big = jnp.int32(m)
    idx_grid = jnp.where(
        comp, jnp.arange(m, dtype=jnp.int32)[None, None, :], big)
    idx = jnp.min(idx_grid, axis=-1).astype(jnp.int32)
    return keep, idx, nmatch


def bindjoin_fused_ref(cand_s, cand_p, cand_o, seg_of_row, pat_s, pat_p,
                       pat_o, pat_valid):
    """Reference cross-pattern fused bind-join filter.

    Pattern components are ``[S, G, M]`` (S segments, each with G request
    groups); ``seg_of_row`` is int32 ``[T]`` mapping each candidate row
    to its segment (-1 = dead padding row, matches nothing). Returns
    keep/idx/nmatch ``[T, G]`` where column g holds the row's result
    against *its own segment's* group-g pattern set (idx = M if none).
    """
    m = pat_s.shape[2]
    seg = jnp.maximum(seg_of_row, 0)
    ms = pat_s[seg]                  # [T, G, M] per-row segment gather
    mp = pat_p[seg]
    mo = pat_o[seg]
    mv = pat_valid[seg]
    cs = cand_s[:, None, None]
    cp = cand_p[:, None, None]
    co = cand_o[:, None, None]
    comp = (
        ((ms < 0) | (cs == ms))
        & ((mp < 0) | (cp == mp))
        & ((mo < 0) | (co == mo))
        & (mv != 0)
        & (seg_of_row >= 0)[:, None, None]
    )  # [T, G, M]
    keep = jnp.any(comp, axis=-1)
    nmatch = jnp.sum(comp.astype(jnp.int32), axis=-1)
    big = jnp.int32(m)
    idx_grid = jnp.where(
        comp, jnp.arange(m, dtype=jnp.int32)[None, None, :], big)
    idx = jnp.min(idx_grid, axis=-1).astype(jnp.int32)
    return keep, idx, nmatch


def tpf_match_ref(cand_s, cand_p, cand_o, pattern_vec):
    """Reference triple-pattern matcher.

    ``pattern_vec`` is int32[8]: [s, p, o, eq_sp, eq_so, eq_po, 0, 0]
    where components < 0 are wildcards and the eq_* flags request
    repeated-variable equality between positions.
    """
    s, p, o = pattern_vec[0], pattern_vec[1], pattern_vec[2]
    eq_sp, eq_so, eq_po = pattern_vec[3], pattern_vec[4], pattern_vec[5]
    mask = (
        ((s < 0) | (cand_s == s))
        & ((p < 0) | (cand_p == p))
        & ((o < 0) | (cand_o == o))
    )
    mask &= (eq_sp == 0) | (cand_s == cand_p)
    mask &= (eq_so == 0) | (cand_s == cand_o)
    mask &= (eq_po == 0) | (cand_p == cand_o)
    return mask


def compat_join_ref(mu, omega, unbound=-1):
    """Reference mapping-compatibility matrix.

    ``mu``: int32[T, V] mappings extracted from fragment triples;
    ``omega``: int32[M, V] attached mappings. Returns bool[T, M] where
    entry (t, m) is SPARQL-compatibility of mu[t] and omega[m].
    """
    a = mu[:, None, :]
    b = omega[None, :, :]
    both = (a != unbound) & (b != unbound)
    return jnp.all(~both | (a == b), axis=-1)
