"""Pallas TPU kernel: single-triple-pattern matcher (the TPF selector).

Streams candidate triples through VMEM in (BT, 128)-shaped tiles and
evaluates one triple pattern (constants, wildcards, repeated-variable
equality constraints) per launch. The pattern itself is a tiny int32[8]
vector placed in its own (1, 8) VMEM block and replicated to every tile.

Layout: candidates are reshaped to (T // 128, 128) so the minor dim fills
all 128 lanes and the major dim tiles by rows -- each block is
(BR, 128) with BR a multiple of 8 (sublane-aligned for int32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_BR = 256  # rows of 128 lanes per tile -> 256*128*4B = 128 KiB/input


def _tpf_match_kernel(cs_ref, cp_ref, co_ref, pat_ref, mask_ref):
    cs = cs_ref[...]            # (BR, 128) int32
    cp = cp_ref[...]
    co = co_ref[...]
    pat = pat_ref[...]          # (1, 8) int32
    s, p, o = pat[0, 0], pat[0, 1], pat[0, 2]
    eq_sp, eq_so, eq_po = pat[0, 3], pat[0, 4], pat[0, 5]

    mask = (
        ((s < 0) | (cs == s))
        & ((p < 0) | (cp == p))
        & ((o < 0) | (co == o))
    )
    mask &= (eq_sp == 0) | (cs == cp)
    mask &= (eq_so == 0) | (cs == co)
    mask &= (eq_po == 0) | (cp == co)
    mask_ref[...] = mask.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def tpf_match_pallas(cand_s, cand_p, cand_o, pattern_vec, *,
                     br: int = DEFAULT_BR, interpret: bool = False):
    """Match one pattern against T (padded, T % (br*128) == 0) candidate
    triples. Returns int32[T] mask (1 = match)."""
    t = cand_s.shape[0]
    assert t % (br * LANES) == 0, (t, br)
    rows = t // LANES
    grid = (rows // br,)

    shape2 = lambda x: x.reshape(rows, LANES)
    mask = pl.pallas_call(
        _tpf_match_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        interpret=interpret,
    )(shape2(cand_s), shape2(cand_p), shape2(cand_o),
      pattern_vec.reshape(1, 8))
    return mask.reshape(t)
