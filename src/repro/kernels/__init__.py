"""Pallas TPU kernels for the brTPF compute hot-spots.

``bindjoin``  -- server-side bindings-restricted filter (Definition 1)
``tpf_match`` -- single-triple-pattern matcher (TPF selector)

Each kernel ships with a pure-jnp oracle in ``ref.py``; ``ops.py`` holds
the padded/jit public entry points (interpret mode off-TPU).
"""
from .ops import (bindjoin, bindjoin_grouped, compact_mask,
                  pattern_vec_from, tpf_match)

__all__ = ["bindjoin", "bindjoin_grouped", "compact_mask",
           "pattern_vec_from", "tpf_match"]
