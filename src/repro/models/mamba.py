"""Mamba (selective SSM) block -- the recurrent layers of Jamba.

Selective scan: per-channel state ``h_t = exp(dt_t * A) h_{t-1} +
dt_t * B_t x_t`` with input-dependent ``B_t, C_t, dt_t`` and readout
``y_t = C_t . h_t + D * x_t``.

TPU adaptation notes (recorded in DESIGN.md): Mamba-1's decay varies per
(channel, state) pair, so the chunked-matmul reformulation used for RWKV
would need a (chunk x chunk x d_state) pairwise grid *per channel* --
memory-prohibitive. The training path therefore uses ``lax.scan`` over
time with the state kept in registers/VMEM (constant memory, small HLO);
the decode path is a single fused state update. A Mamba-2-style
scalar-decay chunked variant is evaluated in the perf log as a
beyond-paper optimization.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import _init_normal

Params = Dict[str, Any]

DT_RANK = 64
SCAN_UNROLL = 16


def init_mamba(key, cfg: ArchConfig, dtype) -> Tuple[Params, Dict]:
    d = cfg.d_model
    din = cfg.ssm_expand * d
    n = cfg.ssm_state_dim
    ks = jax.random.split(key, 7)
    params: Params = {
        "in_proj": _init_normal(ks[0], (d, 2 * din), dtype, d ** -0.5),
        "conv_w": _init_normal(ks[1], (cfg.ssm_conv_dim, din), dtype, 0.2),
        "conv_b": jnp.zeros((din,), dtype),
        "x_proj": _init_normal(ks[2], (din, DT_RANK + 2 * n), dtype,
                               din ** -0.5),
        "dt_proj": _init_normal(ks[3], (DT_RANK, din), dtype,
                                DT_RANK ** -0.5),
        "dt_bias": jnp.full((din,), -4.6, dtype),   # softplus ~ 0.01
        "a_log": jnp.log(jnp.tile(
            jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (din, 1))),
        "d_skip": jnp.ones((din,), dtype),
        "out_proj": _init_normal(ks[4], (din, d), dtype, din ** -0.5),
    }
    axes = {
        "in_proj": ("embed", "ssm_inner"),
        "conv_w": (None, "ssm_inner"), "conv_b": ("ssm_inner",),
        "x_proj": ("ssm_inner", None),
        "dt_proj": (None, "ssm_inner"), "dt_bias": ("ssm_inner",),
        "a_log": ("ssm_inner", None), "d_skip": ("ssm_inner",),
        "out_proj": ("ssm_inner", "embed"),
    }
    return params, axes


def _ssm_inputs(params: Params, x: jnp.ndarray, cfg: ArchConfig):
    """Shared projections. x: (B,S,d) -> (u, gate, dt, b, c).

    u: (B,S,din) conv'd inputs; dt: (B,S,din); b,c: (B,S,N)."""
    n = cfg.ssm_state_dim
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    u, gate = jnp.split(xz, 2, axis=-1)
    # causal depthwise conv
    k = params["conv_w"].shape[0]
    u_pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    u = sum(u_pad[:, i : i + u.shape[1]] * params["conv_w"][i]
            for i in range(k)) + params["conv_b"]
    u = jax.nn.silu(u)
    proj = jnp.einsum("bse,er->bsr", u, params["x_proj"])
    dt_in, b, c = jnp.split(proj, [DT_RANK, DT_RANK + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_in, params["dt_proj"])
        + params["dt_bias"])
    return u, gate, dt, b, c


def mamba_block(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                return_state: bool = False):
    """Full-sequence selective scan. x: (B,S,d).

    With ``return_state`` also returns (conv_window, final_h) so the
    serving prefill can seed the decode cache."""
    b_, s, d = x.shape
    n = cfg.ssm_state_dim
    k = cfg.ssm_conv_dim
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    u_raw, gate = jnp.split(xz, 2, axis=-1)
    u_pad = jnp.pad(u_raw, ((0, 0), (k - 1, 0), (0, 0)))
    u = sum(u_pad[:, i : i + s] * params["conv_w"][i]
            for i in range(k)) + params["conv_b"]
    u = jax.nn.silu(u)
    proj = jnp.einsum("bse,er->bsr", u, params["x_proj"])
    dt_in, b, c = jnp.split(proj, [DT_RANK, DT_RANK + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_in, params["dt_proj"])
        + params["dt_bias"])
    a = -jnp.exp(params["a_log"])                     # (din, N), negative

    f32 = jnp.float32

    # Decay/drive are computed *inside* the scan step from the (B,din)
    # and (B,N) slices: materializing them up-front would allocate two
    # (B,S,din,N) tensors -- terabytes at Jamba scale. The recurrent
    # working set stays O(B*din*N).
    def step(h, inp):
        dt_t, u_t, b_t, c_t = inp                     # (B,din)x2,(B,N)x2
        dec = jnp.exp(dt_t.astype(f32)[..., None] * a)
        drv = (dt_t.astype(f32) * u_t.astype(f32))[..., None] \
            * b_t.astype(f32)[:, None, :]
        h = dec * h + drv
        y = jnp.einsum("ben,bn->be", h, c_t.astype(f32))
        return h, y

    h0 = jnp.zeros((b_, u.shape[-1], n), f32)
    xs = (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(u, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    # unroll: consecutive state updates fuse into one elementwise chain,
    # so h round-trips HBM once per UNROLL steps instead of every step
    h_final, ys = jax.lax.scan(step, h0, xs, unroll=SCAN_UNROLL)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)        # (B,S,din)
    y = y + params["d_skip"] * u
    y = y * jax.nn.silu(gate)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    if not return_state:
        return out
    # decode conv state = last K-1 raw (pre-conv) inputs
    if s >= k - 1:
        conv_window = u_raw[:, s - (k - 1):]
    else:
        conv_window = jnp.pad(u_raw, ((0, 0), (k - 1 - s, 0), (0, 0)))
    return out, (conv_window, h_final)


def mamba_decode(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                 conv_state: jnp.ndarray, ssm_state: jnp.ndarray):
    """One-token decode. x: (B,1,d); conv_state: (B,K-1,din);
    ssm_state: (B,din,N). Returns (y, new_conv_state, new_ssm_state)."""
    b_, _, d = x.shape
    n = cfg.ssm_state_dim
    k = cfg.ssm_conv_dim
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    u_raw, gate = jnp.split(xz[:, 0], 2, axis=-1)     # (B,din)
    window = jnp.concatenate([conv_state, u_raw[:, None, :]], axis=1)
    new_conv_state = window[:, 1:]
    u = jnp.einsum("bke,ke->be", window, params["conv_w"]) \
        + params["conv_b"]
    u = jax.nn.silu(u)
    proj = jnp.einsum("be,er->br", u, params["x_proj"])
    dt_in, bb, cc = jnp.split(proj, [DT_RANK, DT_RANK + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("br,re->be", dt_in, params["dt_proj"])
        + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    f32 = jnp.float32
    decay = jnp.exp(dt.astype(f32)[..., None] * a)    # (B,din,N)
    drive = (dt.astype(f32) * u.astype(f32))[..., None] \
        * bb.astype(f32)[:, None, :]
    h = decay * ssm_state + drive
    y = jnp.einsum("ben,bn->be", h, cc.astype(f32)).astype(x.dtype)
    y = y + params["d_skip"] * u
    y = y * jax.nn.silu(gate)
    y = jnp.einsum("be,ed->bd", y, params["out_proj"])
    return y[:, None, :], new_conv_state, h
