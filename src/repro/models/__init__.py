"""Model zoo: composable JAX model definitions for the assigned pool."""
