"""Core model layers: functional JAX modules with logical-axis metadata.

No flax: parameters are nested dicts of arrays, and every init function
returns ``(params, axes)`` where ``axes`` mirrors ``params`` with tuples
of *logical* axis names (e.g. ``("embed", "ff")``). The sharding layer
(``repro.sharding.rules``) maps logical names to mesh axes, so one model
definition serves every mesh.

Attention is written TPU-idiomatically: fused QKV-per-role projections
feeding the MXU with 128-aligned head dims, and a q-chunked causal
attention (``lax.scan`` over query blocks) that bounds the score buffer
to (chunk x S) -- the XLA-level equivalent of flash attention's memory
behaviour, which is what makes the 32K-prefill shapes compile within
HBM.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig

Params = Dict[str, Any]
Axes = Dict[str, Any]

# Default attention q-chunk (queries per scan step for long sequences).
ATTN_CHUNK = 1024
# Sequences at or below this use unchunked attention.
ATTN_CHUNK_THRESHOLD = 2048


# ---------------------------------------------------------------------------
# Param helpers
# ---------------------------------------------------------------------------

def _init_normal(key, shape, dtype, scale: float):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def dense_param(key, d_in: int, d_out: int, axes: Tuple, dtype,
                scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    return _init_normal(key, (d_in, d_out), dtype, scale), axes


def make_rms_norm(dtype):
    def init(key, d):
        return {"scale": jnp.ones((d,), dtype)}, {"scale": ("embed",)}

    return init


def rms_norm(params: Params, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def init_embeddings(key, cfg: ArchConfig, dtype) -> Tuple[Params, Axes]:
    k1, k2 = jax.random.split(key)
    params: Params = {
        "tok": _init_normal(k1, (cfg.vocab_size, cfg.d_model), dtype, 0.02),
    }
    axes: Axes = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        params["out"] = _init_normal(
            k2, (cfg.d_model, cfg.vocab_size), dtype, cfg.d_model ** -0.5)
        axes["out"] = ("embed", "vocab")
    return params, axes


def embed_tokens(params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["tok"], tokens, axis=0)


def unembed(params: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", x, params["tok"])
    return jnp.einsum("...d,dv->...v", x, params["out"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               head_dim: int) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (..., S)."""
    freqs = rope_freqs(head_dim)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA), train/prefill and decode-with-cache paths
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig, dtype) -> Tuple[Params, Axes]:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko, kb = jax.random.split(key, 5)
    params: Params = {}
    axes: Axes = {}
    params["wq"], axes["wq"] = dense_param(
        kq, d, cfg.num_heads * hd, ("embed", "heads"), dtype)
    params["wk"], axes["wk"] = dense_param(
        kk, d, cfg.num_kv_heads * hd, ("embed", "kv_heads"), dtype)
    params["wv"], axes["wv"] = dense_param(
        kv, d, cfg.num_kv_heads * hd, ("embed", "kv_heads"), dtype)
    params["wo"], axes["wo"] = dense_param(
        ko, cfg.num_heads * hd, d, ("heads", "embed"), dtype)
    if cfg.qkv_bias:
        params["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        params["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        params["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        axes["bq"] = ("heads",)
        axes["bk"] = ("kv_heads",)
        axes["bv"] = ("kv_heads",)
    return params, axes


def _project_qkv(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                 positions: Optional[jnp.ndarray]):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    from ..sharding.rules import constrain
    q = constrain(q.reshape(b, s, cfg.num_heads, hd),
                  "batch", "seq", "act_heads", None)
    k = constrain(k.reshape(b, s, cfg.num_kv_heads, hd),
                  "batch", "seq", "act_kv_heads", None)
    v = constrain(v.reshape(b, s, cfg.num_kv_heads, hd),
                  "batch", "seq", "act_kv_heads", None)
    if cfg.rope and positions is not None:
        q = apply_rope(q, positions, hd)
        k = apply_rope(k, positions, hd)
    return q, k, v


def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray, cfg: ArchConfig):
    """q: (B,Sq,Hq,hd), k: (B,Sk,Hkv,hd) -> scores (B,Hkv,G,Sq,Sk)."""
    b, sq, hq, hd = q.shape
    g = hq // max(cfg.num_kv_heads, 1)
    qg = q.reshape(b, sq, cfg.num_kv_heads, g, hd)
    # python float scale: keeps weak typing (no bf16 -> f32 promotion)
    return jnp.einsum("bqkgh,bskh->bkgqs", qg, k) * float(hd ** -0.5)


def _gqa_out(probs: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """probs: (B,Hkv,G,Sq,Sk), v: (B,Sk,Hkv,hd) -> (B,Sq,Hq*hd)."""
    b, hkv, g, sq, _ = probs.shape
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(b, sq, hkv * g * v.shape[-1])


def _causal_softmax(scores: jnp.ndarray, q_pos: jnp.ndarray,
                    k_pos: jnp.ndarray) -> jnp.ndarray:
    mask = q_pos[:, None] >= k_pos[None, :]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    return probs.astype(scores.dtype)


def attention(params: Params, x: jnp.ndarray, cfg: ArchConfig,
              positions: jnp.ndarray, chunk: int = ATTN_CHUNK,
              causal: bool = True) -> jnp.ndarray:
    out, _, _ = attention_with_kv(params, x, cfg, positions, chunk,
                                  causal)
    return out


def attention_with_kv(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                      positions: jnp.ndarray, chunk: int = ATTN_CHUNK,
                      causal: bool = True):
    """Self-attention for train/prefill (causal by default; encoders pass
    ``causal=False``). Also returns the (rotated) K/V so the serving
    prefill step can populate the decode cache in the same pass.

    For S > ATTN_CHUNK_THRESHOLD, scans over query chunks so the live
    score buffer is (chunk x S) instead of (S x S)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, x, cfg, positions)

    def softmax(scores, q_pos, k_pos):
        if causal:
            return _causal_softmax(scores, q_pos, k_pos)
        return jax.nn.softmax(scores.astype(jnp.float32),
                              axis=-1).astype(scores.dtype)

    if s <= ATTN_CHUNK_THRESHOLD or s % chunk != 0:
        scores = _gqa_scores(q, k, cfg)
        pos = positions[0]
        probs = softmax(scores, pos, pos)
        out = _gqa_out(probs, v)
    else:
        nchunk = s // chunk
        qc = q.reshape(b, nchunk, chunk, cfg.num_heads, -1)
        qc = jnp.moveaxis(qc, 1, 0)           # (n, B, chunk, Hq, hd)
        pc = positions.reshape(b, nchunk, chunk)
        pc = jnp.moveaxis(pc, 1, 0)

        def body(carry, inp):
            qi, pi = inp
            scores = _gqa_scores(qi, k, cfg)
            probs = softmax(scores, pi[0], positions[0])
            return carry, _gqa_out(probs, v)

        _, outs = jax.lax.scan(body, None, (qc, pc))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, -1)

    return jnp.einsum("bsh,hd->bsd", out, params["wo"]), k, v


def cross_attention(params: Params, x: jnp.ndarray, enc_out: jnp.ndarray,
                    cfg: ArchConfig) -> jnp.ndarray:
    """Encoder-decoder cross-attention: queries from x (B,Sq,d), keys and
    values from enc_out (B,Sk,d). No positional rotation, no mask."""
    b, sq, _ = x.shape
    sk = enc_out.shape[1]
    hd = cfg.resolved_head_dim
    enc_out = enc_out.astype(x.dtype)
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"])
    k = jnp.einsum("bsd,dh->bsh", enc_out, params["wk"])
    v = jnp.einsum("bsd,dh->bsh", enc_out, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(b, sq, cfg.num_heads, hd)
    k = k.reshape(b, sk, cfg.num_kv_heads, hd)
    v = v.reshape(b, sk, cfg.num_kv_heads, hd)
    scores = _gqa_scores(q, k, cfg)
    probs = jax.nn.softmax(scores.astype(jnp.float32),
                           axis=-1).astype(x.dtype)
    out = _gqa_out(probs, v)
    return jnp.einsum("bsh,hd->bsd", out, params["wo"])


def attention_decode(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                     cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                     cache_pos: jnp.ndarray):
    """One-token decode: x (B,1,d); cache_[kv]: (B,S,Hkv,hd).

    Returns (out (B,1,d), new_cache_k, new_cache_v)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    positions = jnp.broadcast_to(cache_pos[None], (b, 1))
    q, k, v = _project_qkv(params, x, cfg, positions)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), cache_pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), cache_pos, axis=1)
    s = cache_k.shape[1]
    scores = _gqa_scores(q, cache_k, cfg)          # (B,Hkv,G,1,S)
    k_pos = jnp.arange(s)
    mask = k_pos[None, :] <= cache_pos             # (1,S)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = _gqa_out(probs.astype(x.dtype), cache_v)
    return (jnp.einsum("bsh,hd->bsd", out, params["wo"]),
            cache_k, cache_v)


# ---------------------------------------------------------------------------
# SwiGLU FFN
# ---------------------------------------------------------------------------

def init_ffn(key, cfg: ArchConfig, dtype,
             d_ff: Optional[int] = None) -> Tuple[Params, Axes]:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    params, axes = {}, {}
    params["w_gate"], axes["w_gate"] = dense_param(
        k1, d, ff, ("embed", "ff"), dtype)
    params["w_up"], axes["w_up"] = dense_param(
        k2, d, ff, ("embed", "ff"), dtype)
    params["w_down"], axes["w_down"] = dense_param(
        k3, ff, d, ("ff", "embed"), dtype)
    return params, axes


def ffn(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    gate = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, params["w_gate"]))
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    return jnp.einsum("bsf,fd->bsd", gate * up, params["w_down"])
