"""Mixture-of-Experts FFN: top-k routing with capacity-based dispatch.

GShard/Switch-style: tokens are dispatched to per-expert capacity slots
with one-hot einsums, expert FFNs run as a batched matmul over the expert
axis, and results are combined with router weights. With ``experts``
sharded over the ``model`` mesh axis, XLA SPMD lowers the dispatch/
combine einsums to all-to-alls -- expert parallelism without any manual
collectives (the ragged variants are explored in the perf log).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import _init_normal

Params = Dict[str, Any]


def init_moe(key, cfg: ArchConfig, dtype) -> Tuple[Params, Dict]:
    m = cfg.moe
    d = cfg.d_model
    kr, k1, k2, k3 = jax.random.split(key, 4)
    e, f = m.num_experts, m.d_ff_expert
    params: Params = {
        "router": _init_normal(kr, (d, e), dtype, d ** -0.5),
        "w_gate": _init_normal(k1, (e, d, f), dtype, d ** -0.5),
        "w_up": _init_normal(k2, (e, d, f), dtype, d ** -0.5),
        "w_down": _init_normal(k3, (e, f, d), dtype, f ** -0.5),
    }
    axes = {
        "router": ("embed", "experts_r"),      # router stays replicated
        "w_gate": ("experts", "embed", "ff_expert"),
        "w_up": ("experts", "embed", "ff_expert"),
        "w_down": ("experts", "ff_expert", "embed"),
    }
    return params, axes


def moe_ffn(params: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """x: (B, S, d) -> (B, S, d). Aux losses returned via stop-grad-free
    side value in ``moe_ffn_with_aux``; this wrapper discards them."""
    out, _ = moe_ffn_with_aux(params, x, cfg)
    return out


# Tokens per routing group. Capacity (and hence the one-hot dispatch
# grid) is per *group*, so dispatch cost scales O(T * E * C_g) with
# C_g = O(GROUP_SIZE) -- constant in T -- instead of the O(T^2) a global
# capacity implies. This matches GShard/Switch, which route per group.
GROUP_SIZE = 1024


def moe_ffn_with_aux(params: Params, x: jnp.ndarray,
                     cfg: ArchConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if getattr(cfg, "moe_dispatch", "einsum") == "gather":
        return moe_ffn_gather(params, x, cfg)
    return _moe_ffn_einsum(params, x, cfg)


def moe_ffn_gather(params: Params, x: jnp.ndarray,
                   cfg: ArchConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort/gather-based dispatch (beyond-paper §Perf variant).

    Replaces the one-hot dispatch/combine einsums (2·T·E·C·d FLOPs
    each) with an argsort by expert + scatter-add into capacity slots +
    gather back: the dispatch itself costs ~zero FLOPs, leaving only the
    expert matmuls. Token drops (over capacity) follow sorted order
    rather than in-group order, which is a standard and accepted
    difference between the two dispatch families.
    """
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.experts_per_token
    t = b * s
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt, params["router"])
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topk_p, topk_i = jax.lax.top_k(probs, k)              # (T, k)
    topk_p = topk_p / jnp.maximum(
        jnp.sum(topk_p, axis=-1, keepdims=True), 1e-9)

    onehot_mean = jnp.mean(
        jax.nn.one_hot(topk_i, e, dtype=jnp.float32).sum(1), axis=0)
    aux = e * jnp.sum(onehot_mean * jnp.mean(probs, axis=0))

    capacity = max(int(m.capacity_factor * t * k / e), 1)

    flat_e = topk_i.reshape(t * k)                        # (T*k,)
    flat_gate = topk_p.reshape(t * k)
    flat_tok = jnp.arange(t * k, dtype=jnp.int32) // k
    order = jnp.argsort(flat_e)                           # group by expert
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))
    pos = jnp.arange(t * k) - seg_start[sorted_e]
    keep = pos < capacity
    slot = jnp.where(keep, sorted_e * capacity + pos, e * capacity)

    # dispatch: scatter tokens into (E*C, d) slots (gather, no matmul)
    src = xt[flat_tok[order]] * keep[:, None].astype(x.dtype)
    xin = jnp.zeros((e * capacity + 1, d), x.dtype).at[slot].add(src)
    xin = xin[:-1].reshape(e, capacity, d)

    h_gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin,
                                    params["w_gate"]))
    h_up = jnp.einsum("ecd,edf->ecf", xin, params["w_up"])
    h = jnp.einsum("ecf,efd->ecd", h_gate * h_up,
                   params["w_down"]).reshape(e * capacity, d)

    # combine: gather expert outputs back to tokens, weighted
    gathered = h[jnp.minimum(slot, e * capacity - 1)]
    gathered = gathered * (flat_gate[order] * keep)[:, None].astype(
        x.dtype)
    out = jnp.zeros((t, d), x.dtype).at[flat_tok[order]].add(gathered)
    return out.reshape(b, s, d), aux.astype(jnp.float32)


def _moe_ffn_einsum(params: Params, x: jnp.ndarray,
                    cfg: ArchConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.experts_per_token
    t = b * s
    tg = GROUP_SIZE if t % GROUP_SIZE == 0 else t
    g = t // tg
    xt = x.reshape(g, tg, d)

    logits = jnp.einsum("gtd,de->gte", xt, params["router"])
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topk_p, topk_i = jax.lax.top_k(probs, k)              # (G, Tg, k)
    topk_p = topk_p / jnp.maximum(
        jnp.sum(topk_p, axis=-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch): e * sum(frac_tokens * frac_p)
    onehot = jax.nn.one_hot(topk_i, e, dtype=jnp.float32)  # (G, Tg, k, E)
    tokens_per_expert = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))
    prob_per_expert = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(tokens_per_expert * prob_per_expert)

    capacity = max(int(m.capacity_factor * tg * k / e), 1)

    # position of each (token, choice) in its expert's per-group queue
    flat_onehot = onehot.reshape(g, tg * k, e)
    pos_in_expert = jnp.cumsum(flat_onehot, axis=1) - 1.0
    pos_in_expert = jnp.sum(pos_in_expert * flat_onehot, axis=-1)
    keep = (pos_in_expert < capacity).reshape(g, tg, k)
    pos_in_expert = pos_in_expert.reshape(g, tg, k)

    gate = (topk_p * keep).astype(jnp.float32)            # (G, Tg, k)
    cap_oh = jax.nn.one_hot(
        jnp.where(keep, pos_in_expert, capacity), capacity,
        dtype=jnp.float32)                                # (G, Tg, k, C)
    # dispatch/combine tensors (G, Tg, E, C)
    dispatch = jnp.einsum("gtke,gtkc->gtec",
                          onehot * keep[..., None], cap_oh)
    combine = jnp.einsum("gtk,gtke,gtkc->gtec", gate, onehot, cap_oh)

    xin = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x.dtype), xt)
    h_gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin,
                                    params["w_gate"]))
    h_up = jnp.einsum("gecd,edf->gecf", xin, params["w_up"])
    h = jnp.einsum("gecf,efd->gecd", h_gate * h_up, params["w_down"])
    out = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), h)
    return out.reshape(b, s, d), aux.astype(jnp.float32)
