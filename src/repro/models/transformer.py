"""Layer stack: scan-over-periods with heterogeneous block patterns.

The stack supports every assigned family with one mechanism:

* homogeneous decoders (dense/MoE/RWKV) have a block *period* of 1;
* Jamba's 1:7 attention:Mamba interleave with MoE-every-other-layer has
  a period of 8 -- within a period the blocks differ, across periods
  they repeat.

Parameters for each period position are stacked along a leading
``num_periods`` axis and the whole stack runs as one ``lax.scan`` (with
optional remat), so HLO size is O(period), not O(num_layers) -- this is
what keeps 72-layer Jamba compiling quickly on 512 host devices.

Caches follow the same layout: each period position owns a stacked
cache pytree; the decode scan threads them as scan xs/ys.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ATTN, ArchConfig, MAMBA, RWKV
from ..sharding.rules import constrain
from . import layers as L
from . import mamba as M
from . import moe as MOE
from . import rwkv as R

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Single block (one layer): init / apply / decode / cache
# ---------------------------------------------------------------------------

def init_block(key, cfg: ArchConfig, kind: str, is_moe: bool, dtype):
    keys = jax.random.split(key, 4)
    params: Params = {"norm1": jnp.ones((cfg.d_model,), dtype)}
    axes: Dict = {"norm1": ("embed",)}

    if kind == ATTN:
        params["mixer"], axes["mixer"] = L.init_attention(keys[0], cfg,
                                                          dtype)
    elif kind == MAMBA:
        params["mixer"], axes["mixer"] = M.init_mamba(keys[0], cfg, dtype)
    elif kind == RWKV:
        params["mixer"], axes["mixer"] = R.init_time_mix(keys[0], cfg,
                                                         dtype)
    else:
        raise ValueError(kind)

    params["norm2"] = jnp.ones((cfg.d_model,), dtype)
    axes["norm2"] = ("embed",)
    if kind == RWKV:
        params["ffn"], axes["ffn"] = R.init_channel_mix(keys[1], cfg,
                                                        dtype)
    elif is_moe:
        params["ffn"], axes["ffn"] = MOE.init_moe(keys[1], cfg, dtype)
    else:
        params["ffn"], axes["ffn"] = L.init_ffn(keys[1], cfg, dtype)
    return params, axes


def apply_block(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                kind: str, is_moe: bool,
                positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence block. Returns (x, moe_aux)."""
    eps = cfg.norm_eps
    h = L.rms_norm({"scale": params["norm1"]}, x, eps)
    if kind == ATTN:
        h = L.attention(params["mixer"], h, cfg, positions)
    elif kind == MAMBA:
        h = M.mamba_block(params["mixer"], h, cfg)
    else:
        h = R.time_mix(params["mixer"], h, cfg)
    x = x + h
    x = constrain(x, "batch", "seq", "act_embed")

    h = L.rms_norm({"scale": params["norm2"]}, x, eps)
    aux = jnp.zeros((), jnp.float32)
    if kind == RWKV:
        h = R.channel_mix(params["ffn"], h)
    elif is_moe:
        h, aux = MOE.moe_ffn_with_aux(params["ffn"], h, cfg)
    else:
        h = L.ffn(params["ffn"], h)
    x = x + h
    x = constrain(x, "batch", "seq", "act_embed")
    return x, aux


def init_block_cache(cfg: ArchConfig, kind: str, batch: int,
                     max_seq: int, dtype):
    """Decode cache pytree (+ logical axes) for one block."""
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    if kind == ATTN:
        shape = (batch, max_seq, cfg.num_kv_heads, hd)
        return ({"k": jnp.zeros(shape, dtype),
                 "v": jnp.zeros(shape, dtype)},
                {"k": ("batch", "kv_seq", "kv_heads", None),
                 "v": ("batch", "kv_seq", "kv_heads", None)})
    if kind == MAMBA:
        din = cfg.ssm_expand * d
        return ({"conv": jnp.zeros((batch, cfg.ssm_conv_dim - 1, din),
                                   dtype),
                 "ssm": jnp.zeros((batch, din, cfg.ssm_state_dim),
                                  jnp.float32)},
                {"conv": ("batch", None, "ssm_inner"),
                 "ssm": ("batch", "ssm_inner", None)})
    if kind == RWKV:
        h = d // cfg.rwkv_head_dim
        return ({"shift_t": jnp.zeros((batch, 1, d), dtype),
                 "shift_c": jnp.zeros((batch, 1, d), dtype),
                 "wkv": jnp.zeros((batch, h, cfg.rwkv_head_dim,
                                   cfg.rwkv_head_dim), jnp.float32)},
                {"shift_t": ("batch", None, "embed"),
                 "shift_c": ("batch", None, "embed"),
                 "wkv": ("batch", "heads", None, None)})
    raise ValueError(kind)


def apply_block_prefill(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                        kind: str, is_moe: bool, positions: jnp.ndarray,
                        max_seq: int):
    """Full-sequence block that also emits the decode cache for its
    layer (serving prefill). Returns (x, cache)."""
    eps = cfg.norm_eps
    b, s, d = x.shape
    h = L.rms_norm({"scale": params["norm1"]}, x, eps)
    if kind == ATTN:
        h, k, v = L.attention_with_kv(params["mixer"], h, cfg, positions)
        pad = [(0, 0), (0, max_seq - s), (0, 0), (0, 0)]
        cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
    elif kind == MAMBA:
        h, (conv, ssm) = M.mamba_block(params["mixer"], h, cfg,
                                       return_state=True)
        cache = {"conv": conv, "ssm": ssm}
    else:
        h, (shift, wkv) = R.time_mix(params["mixer"], h, cfg,
                                     return_state=True)
        cache = {"shift_t": shift, "wkv": wkv}
    x = x + h
    x = constrain(x, "batch", "seq", "act_embed")

    h = L.rms_norm({"scale": params["norm2"]}, x, eps)
    if kind == RWKV:
        cache["shift_c"] = h[:, -1:]
        h = R.channel_mix(params["ffn"], h)
    elif is_moe:
        h = MOE.moe_ffn(params["ffn"], h, cfg)
    else:
        h = L.ffn(params["ffn"], h)
    x = x + h
    x = constrain(x, "batch", "seq", "act_embed")
    return x, cache


def apply_block_decode(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                       kind: str, is_moe: bool, cache: Params,
                       cache_pos: jnp.ndarray):
    """One-token decode. x: (B,1,d). Returns (x, new_cache)."""
    eps = cfg.norm_eps
    h = L.rms_norm({"scale": params["norm1"]}, x, eps)
    new_cache = dict(cache)
    if kind == ATTN:
        h, ck, cv = L.attention_decode(params["mixer"], h, cfg,
                                       cache["k"], cache["v"], cache_pos)
        new_cache["k"], new_cache["v"] = ck, cv
    elif kind == MAMBA:
        h, conv, ssm = M.mamba_decode(params["mixer"], h, cfg,
                                      cache["conv"], cache["ssm"])
        new_cache["conv"], new_cache["ssm"] = conv, ssm
    else:
        h, shift, wkv = R.time_mix_decode(params["mixer"], h, cfg,
                                          cache["shift_t"], cache["wkv"])
        new_cache["shift_t"], new_cache["wkv"] = shift, wkv
    x = x + h

    h = L.rms_norm({"scale": params["norm2"]}, x, eps)
    if kind == RWKV:
        h_out = R.channel_mix(params["ffn"], h,
                              shift_state=cache["shift_c"])
        new_cache["shift_c"] = h  # pre-mix activation is next shift
        h = h_out
    elif is_moe:
        h = MOE.moe_ffn(params["ffn"], h, cfg)
    else:
        h = L.ffn(params["ffn"], h)
    return x + h, new_cache


# ---------------------------------------------------------------------------
# The stack: scan over periods
# ---------------------------------------------------------------------------

def _period_info(cfg: ArchConfig) -> Tuple[Tuple[str, ...], Tuple[bool, ...],
                                           int]:
    kinds = cfg.layer_kinds()
    plen = len(cfg.block_pattern)
    if cfg.num_layers % plen != 0:
        raise ValueError(
            f"{cfg.name}: num_layers {cfg.num_layers} not divisible by "
            f"block pattern period {plen}")
    num_periods = cfg.num_layers // plen
    pos_kinds = kinds[:plen]
    pos_moe = tuple(cfg.is_moe_layer(i) for i in range(plen))
    # verify moe-ness is period-stable (guaranteed when every_k | plen)
    for i in range(cfg.num_layers):
        assert cfg.is_moe_layer(i) == pos_moe[i % plen], (
            "MoE pattern must align with the block period")
    return pos_kinds, pos_moe, num_periods


def init_stack(key, cfg: ArchConfig, dtype):
    pos_kinds, pos_moe, num_periods = _period_info(cfg)
    params: Params = {}
    axes: Dict = {}
    for pos, (kind, is_moe) in enumerate(zip(pos_kinds, pos_moe, strict=True)):
        keys = jax.random.split(jax.random.fold_in(key, pos), num_periods)
        init_one = functools.partial(init_block, cfg=cfg, kind=kind,
                                     is_moe=is_moe, dtype=dtype)
        stacked = jax.vmap(lambda k: init_one(k)[0])(keys)
        _, ax = init_one(keys[0])
        params[f"pos{pos}"] = stacked
        axes[f"pos{pos}"] = jax.tree.map(
            lambda a: ("layers",) + a if isinstance(a, tuple) else a, ax,
            is_leaf=lambda a: a is None or isinstance(a, tuple))
    return params, axes


def _maybe_remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def apply_stack(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                positions: jnp.ndarray):
    """Full-sequence stack. Returns (x, total_moe_aux)."""
    pos_kinds, pos_moe, num_periods = _period_info(cfg)

    def period_fn(x, period_params):
        aux_total = jnp.zeros((), jnp.float32)
        for pos, (kind, is_moe) in enumerate(zip(pos_kinds, pos_moe, strict=True)):
            x, aux = apply_block(period_params[f"pos{pos}"], x, cfg,
                                 kind, is_moe, positions)
            aux_total = aux_total + aux
        return x, aux_total

    period_fn = _maybe_remat(period_fn, cfg)

    def body(carry, period_params):
        x, aux_sum = carry
        x, aux = period_fn(x, period_params)
        return (x, aux_sum + aux), None

    (x, aux_sum), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params)
    return x, aux_sum


def init_stack_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype):
    pos_kinds, _, num_periods = _period_info(cfg)
    cache: Params = {}
    axes: Dict = {}
    for pos, kind in enumerate(pos_kinds):
        one, ax = init_block_cache(cfg, kind, batch, max_seq, dtype)
        cache[f"pos{pos}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(
                a[None], (num_periods,) + a.shape), one)
        axes[f"pos{pos}"] = jax.tree.map(
            lambda a: ("layers",) + a if isinstance(a, tuple) else a, ax,
            is_leaf=lambda a: a is None or isinstance(a, tuple))
    return cache, axes


def apply_stack_prefill(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                        positions: jnp.ndarray, max_seq: int):
    """Full-sequence stack that also emits the full decode cache.

    Returns (x, cache) with the ``init_stack_cache`` layout."""
    pos_kinds, pos_moe, _ = _period_info(cfg)

    def body(x, period_params):
        caches = {}
        for pos, (kind, is_moe) in enumerate(zip(pos_kinds, pos_moe, strict=True)):
            x, c = apply_block_prefill(period_params[f"pos{pos}"], x,
                                       cfg, kind, is_moe, positions,
                                       max_seq)
            caches[f"pos{pos}"] = c
        return x, caches

    x, cache = jax.lax.scan(body, x, params)
    return x, cache


def apply_stack_decode(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                       cache: Params, cache_pos: jnp.ndarray):
    """One-token decode through the stack. Returns (x, new_cache)."""
    pos_kinds, pos_moe, _ = _period_info(cfg)

    def body(x, inp):
        period_params, period_cache = inp
        new_cache = {}
        for pos, (kind, is_moe) in enumerate(zip(pos_kinds, pos_moe, strict=True)):
            x, nc = apply_block_decode(
                period_params[f"pos{pos}"], x, cfg, kind, is_moe,
                period_cache[f"pos{pos}"], cache_pos)
            new_cache[f"pos{pos}"] = nc
        return x, new_cache

    x, new_cache = jax.lax.scan(body, x, (params, cache))
    return x, new_cache
