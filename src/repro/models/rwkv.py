"""RWKV6 ("Finch"): attention-free blocks with data-dependent decay.

Time-mix (WKV6): per-head matrix-valued recurrent state
``S_t = diag(w_t) S_{t-1} + k_t^T v_t`` with *data-dependent* per-channel
decay ``w_t = exp(-exp(w0 + lora(x_t)))``, read out as
``o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)``.

TPU adaptation: the sequential recurrence is reformulated as a *chunked
parallel scan* (the linear-attention chunk trick): within a chunk all
pairwise decays ``exp(cum_{t-1} - cum_j)`` are <= 1 (cumulative log-decay
is non-increasing), so the intra-chunk contribution is a masked matmul
and the inter-chunk contribution carries the state -- every exponent is
non-positive, so the computation is overflow-free by construction, and
the chunk matmuls feed the MXU instead of a length-S serial chain.
``wkv_reference`` is the step-by-step oracle the tests compare against.

Channel-mix is RWKV's two-matrix FFN with receptance gating.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import _init_normal

Params = Dict[str, Any]

LORA_DIM = 64


# ---------------------------------------------------------------------------
# WKV6 core
# ---------------------------------------------------------------------------

def wkv_reference(r, k, v, logw, u):
    """Sequential oracle. r,k,v,logw: (B,S,H,N); u: (H,N).

    Returns (o: (B,S,H,N), final_state: (B,H,N,N))."""
    b, s, h, n = r.shape
    state = jnp.zeros((b, h, n, n), jnp.float32)

    def step(state, inp):
        rt, kt, vt, lw = inp  # (B,H,N) each
        w = jnp.exp(lw)
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,N,N)
        bonus = state + u[None, :, :, None] * kv
        o = jnp.einsum("bhn,bhnm->bhm", rt, bonus)
        state = w[..., :, None] * state + kv
        return state, o

    xs = tuple(jnp.moveaxis(x.astype(jnp.float32), 1, 0)
               for x in (r, k, v, logw))
    state, o = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(o, 0, 1).astype(r.dtype), state


def wkv_chunked(r, k, v, logw, u, chunk: int,
                initial_state=None):
    """Chunked-parallel WKV6. Shapes as ``wkv_reference``.

    All decay exponents are differences ``cum_a - cum_b`` with a >= b in
    time order, hence <= 0: numerically safe in fp32 at any chunk size.
    """
    b, s, h, n = r.shape
    if s % chunk != 0:
        pad = chunk - s % chunk
        zf = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        out, st = wkv_chunked(zf(r), zf(k), zf(v), zf(logw), u, chunk,
                              initial_state)
        return out[:, :s], st
    nc = s // chunk
    f32 = jnp.float32
    # keep the full-sequence tensors in their input dtype; cast per-chunk
    # inside the scan (a full-sequence f32 copy of r/k/v/logw would be
    # 4x (B,S,H,N) fp32 resident buffers)
    rc, kc, vc, lwc = (x.reshape(b, nc, chunk, h, n)
                       for x in (r, k, v, logw))

    state0 = (initial_state if initial_state is not None
              else jnp.zeros((b, h, n, n), f32))

    def per_chunk(state, inp):
        rt, kt, vt, lw = (x.astype(f32) for x in inp)   # (B,C,H,N)
        cum = jnp.cumsum(lw, axis=1)    # inclusive, (B,C,H,N)
        ecum = cum - lw                 # exclusive (cum_{t-1})
        # -- intra-chunk: A[t,j] = r_t . (k_j * exp(ecum_t - cum_j)), j<t
        pair = ecum[:, :, None] - cum[:, None]     # (B,C,C,H,N) <= 0 for j<t
        t_idx = jnp.arange(chunk)
        causal = (t_idx[:, None] > t_idx[None, :])  # strict lower
        pair = jnp.where(causal[None, :, :, None, None], pair, -jnp.inf)
        a = jnp.einsum("bthn,bjhn,btjhn->bthj", rt, kt,
                       jnp.exp(pair))
        # diag bonus term
        diag = jnp.einsum("bthn,hn,bthn->bth", rt, u, kt)
        o = jnp.einsum("bthj,bjhn->bthn", a, vt)
        o = o + diag[..., None] * vt
        # -- inter-chunk: r_t * exp(ecum_t) @ state
        rdec = rt * jnp.exp(ecum)
        o = o + jnp.einsum("bthn,bhnm->bthm", rdec, state)
        # -- state update to chunk end
        kdec = kt * jnp.exp(cum[:, -1:, :, :] - cum)    # <= 0 exponent
        new_state = (jnp.exp(cum[:, -1])[..., None] * state
                     + jnp.einsum("bthn,bthm->bhnm", kdec, vt))
        return new_state, o

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (rc, kc, vc, lwc))
    state, o = jax.lax.scan(per_chunk, state0, xs)
    o = jnp.moveaxis(o, 0, 1).reshape(b, s, h, n)
    return o.astype(r.dtype), state


def wkv_step(r, k, v, logw, u, state):
    """Single-token decode. r,k,v,logw: (B,H,N); state: (B,H,N,N)."""
    f32 = jnp.float32
    rt, kt, vt, lw = (x.astype(f32) for x in (r, k, v, logw))
    kv = kt[..., :, None] * vt[..., None, :]
    o = jnp.einsum("bhn,bhnm->bhm",
                   rt, state + u[None, :, :, None] * kv)
    new_state = jnp.exp(lw)[..., :, None] * state + kv
    return o.astype(r.dtype), new_state


# ---------------------------------------------------------------------------
# Time-mix block
# ---------------------------------------------------------------------------

def init_time_mix(key, cfg: ArchConfig, dtype) -> Tuple[Params, Dict]:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    params: Params = {
        "mu": 0.5 * jnp.ones((5, d), dtype),       # r,k,v,g,w lerps
        "w_r": _init_normal(ks[0], (d, d), dtype, d ** -0.5),
        "w_k": _init_normal(ks[1], (d, d), dtype, d ** -0.5),
        "w_v": _init_normal(ks[2], (d, d), dtype, d ** -0.5),
        "w_g": _init_normal(ks[3], (d, d), dtype, d ** -0.5),
        "w_o": _init_normal(ks[4], (d, d), dtype, d ** -0.5),
        "w0": jnp.full((d,), -0.6, dtype),          # decay bias
        "w_lora_a": _init_normal(ks[5], (d, LORA_DIM), dtype, d ** -0.5),
        "w_lora_b": _init_normal(ks[6], (LORA_DIM, d), dtype,
                                 LORA_DIM ** -0.5),
        "u": _init_normal(ks[7], (d,), dtype, 0.3),
        "ln_scale": jnp.ones((d,), dtype),          # per-head group norm
    }
    axes = {
        "mu": (None, "embed"),
        "w_r": ("embed", "heads"), "w_k": ("embed", "heads"),
        "w_v": ("embed", "heads"), "w_g": ("embed", "heads"),
        "w_o": ("heads", "embed"),
        "w0": ("heads",), "w_lora_a": ("embed", None),
        "w_lora_b": (None, "heads"), "u": ("heads",),
        "ln_scale": ("heads",),
    }
    return params, axes


def _mix_inputs(params, x, xx):
    """Token-shift lerps for r,k,v,g,w inputs."""
    mu = params["mu"].astype(x.dtype)
    outs = []
    for i in range(5):
        outs.append(x + (xx - x) * mu[i])
    return outs  # r_in, k_in, v_in, g_in, w_in


def _decay(params, w_in):
    lora = jnp.einsum("...d,dl->...l", jnp.tanh(w_in), params["w_lora_a"])
    lora = jnp.einsum("...l,ld->...d", lora, params["w_lora_b"])
    return -jnp.exp(
        jnp.clip(params["w0"].astype(jnp.float32)
                 + lora.astype(jnp.float32), -8.0, 4.0)
    )  # (..., d), strictly negative


def _group_norm(x, scale, eps):
    """Per-head RMS norm: x (..., H, N)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(
        x.dtype) * scale


def time_mix(params: Params, x: jnp.ndarray, cfg: ArchConfig,
             return_state: bool = False):
    """Full-sequence time-mix. x: (B, S, d).

    With ``return_state`` also returns (x_last, wkv_state) to seed the
    decode cache at the end of a serving prefill."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    xx = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]    # token shift
    r_in, k_in, v_in, g_in, w_in = _mix_inputs(params, x, xx)
    r = jnp.einsum("bsd,dh->bsh", r_in, params["w_r"]).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,dh->bsh", k_in, params["w_k"]).reshape(b, s, h, hd)
    v = jnp.einsum("bsd,dh->bsh", v_in, params["w_v"]).reshape(b, s, h, hd)
    g = jax.nn.silu(jnp.einsum("bsd,dh->bsh", g_in, params["w_g"]))
    logw = _decay(params, w_in).reshape(b, s, h, hd)
    u = params["u"].astype(jnp.float32).reshape(h, hd)
    o, state = wkv_chunked(r, k, v, logw, u, cfg.chunk_size)
    o = _group_norm(o, 1.0, cfg.norm_eps).reshape(b, s, d)
    o = o * params["ln_scale"].astype(o.dtype) * g.reshape(b, s, d)
    out = jnp.einsum("bsh,hd->bsd", o, params["w_o"])
    if not return_state:
        return out
    return out, (x[:, -1:], state)


def time_mix_decode(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                    shift_state: jnp.ndarray, wkv_state: jnp.ndarray):
    """One-token decode. x: (B,1,d); shift_state: (B,1,d);
    wkv_state: (B,H,N,N). Returns (out, new_shift, new_wkv)."""
    b, _, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    r_in, k_in, v_in, g_in, w_in = _mix_inputs(params, x, shift_state)
    r = jnp.einsum("bsd,dh->bsh", r_in, params["w_r"]).reshape(b, h, hd)
    k = jnp.einsum("bsd,dh->bsh", k_in, params["w_k"]).reshape(b, h, hd)
    v = jnp.einsum("bsd,dh->bsh", v_in, params["w_v"]).reshape(b, h, hd)
    g = jax.nn.silu(jnp.einsum("bsd,dh->bsh", g_in,
                               params["w_g"])).reshape(b, h, hd)
    logw = _decay(params, w_in).reshape(b, h, hd)
    u = params["u"].astype(jnp.float32).reshape(h, hd)
    o, new_state = wkv_step(r, k, v, logw, u, wkv_state)
    o = _group_norm(o, 1.0, cfg.norm_eps)
    o = (o * params["ln_scale"].astype(o.dtype).reshape(h, hd) * g)
    o = o.reshape(b, 1, d)
    return jnp.einsum("bsh,hd->bsd", o, params["w_o"]), x, new_state


# ---------------------------------------------------------------------------
# Channel-mix block
# ---------------------------------------------------------------------------

def init_channel_mix(key, cfg: ArchConfig, dtype) -> Tuple[Params, Dict]:
    d, ff = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    params: Params = {
        "mu": 0.5 * jnp.ones((2, d), dtype),        # k,r lerps
        "w_k": _init_normal(k1, (d, ff), dtype, d ** -0.5),
        "w_v": _init_normal(k2, (ff, d), dtype, ff ** -0.5),
        "w_r": _init_normal(k3, (d, d), dtype, d ** -0.5),
    }
    axes = {"mu": (None, "embed"), "w_k": ("embed", "ff"),
            "w_v": ("ff", "embed"), "w_r": ("embed", "heads")}
    return params, axes


def channel_mix(params: Params, x: jnp.ndarray,
                shift_state=None) -> jnp.ndarray:
    if shift_state is None:
        xx = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        xx = shift_state
    mu = params["mu"].astype(x.dtype)
    k_in = x + (xx - x) * mu[0]
    r_in = x + (xx - x) * mu[1]
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", k_in,
                                          params["w_k"])))
    kv = jnp.einsum("bsf,fd->bsd", k, params["w_v"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", r_in, params["w_r"]))
    return r * kv
