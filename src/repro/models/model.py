"""Public model API: build any assigned architecture from its config.

``ModelDef`` bundles init / forward (train, prefill) / decode-step /
cache-init for decoder-only families (dense, MoE, RWKV, hybrid) and the
encoder-decoder family (seamless-m4t). All functions are pure and
jit/pjit-compatible; parameters carry a parallel logical-axes pytree for
the sharding layer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ATTN, ArchConfig
from ..sharding.rules import constrain
from . import layers as L
from . import transformer as T

Params = Dict[str, Any]

MOE_AUX_COEF = 0.01


def _axes_with_layers(ax):
    return jax.tree.map(
        lambda a: ("layers",) + a if isinstance(a, tuple) else a, ax,
        is_leaf=lambda a: a is None or isinstance(a, tuple))


@dataclasses.dataclass
class ModelDef:
    cfg: ArchConfig
    dtype: Any = jnp.float32        # params + activations

    # -- init -----------------------------------------------------------------

    def init(self, key) -> Tuple[Params, Dict]:
        cfg = self.cfg
        k_emb, k_stack, k_enc, k_out = jax.random.split(key, 4)
        params: Params = {}
        axes: Dict = {}
        params["embed"], axes["embed"] = L.init_embeddings(
            k_emb, cfg, self.dtype)
        params["stack"], axes["stack"] = T.init_stack(
            k_stack, cfg, self.dtype)
        params["norm_f"] = jnp.ones((cfg.d_model,), self.dtype)
        axes["norm_f"] = ("embed",)
        if cfg.encoder_layers:
            params["encoder"], axes["encoder"] = self._init_encoder(k_enc)
            params["cross"], axes["cross"] = self._init_cross(k_out)
        return params, axes

    def _init_encoder(self, key):
        cfg = self.cfg
        keys = jax.random.split(key, cfg.encoder_layers)

        def init_one(k):
            p, a = T.init_block(k, cfg, ATTN, False, self.dtype)
            return p, a

        stacked = jax.vmap(lambda k: init_one(k)[0])(keys)
        _, ax = init_one(keys[0])
        return ({"blocks": stacked,
                 "norm_f": jnp.ones((cfg.d_model,), self.dtype)},
                {"blocks": _axes_with_layers(ax), "norm_f": ("embed",)})

    def _init_cross(self, key):
        """Per-decoder-layer cross-attention (stacked like the stack)."""
        cfg = self.cfg
        keys = jax.random.split(key, cfg.num_layers)

        def init_one(k):
            p, a = L.init_attention(k, cfg, self.dtype)
            p = {"attn": p, "norm": jnp.ones((cfg.d_model,), self.dtype)}
            a = {"attn": a, "norm": ("embed",)}
            return p, a

        stacked = jax.vmap(lambda k: init_one(k)[0])(keys)
        _, ax = init_one(keys[0])
        return stacked, _axes_with_layers(ax)

    # -- encoder --------------------------------------------------------------

    def encode(self, params: Params, enc_input: jnp.ndarray) -> jnp.ndarray:
        """enc_input: precomputed frame/patch embeddings (B, F, d) --
        the modality frontend is a stub per the brief."""
        cfg = self.cfg
        b, f, _ = enc_input.shape
        positions = jnp.broadcast_to(jnp.arange(f)[None], (b, f))
        x = enc_input.astype(self.dtype)

        def body(x, block_params):
            h = L.rms_norm({"scale": block_params["norm1"]}, x,
                           cfg.norm_eps)
            h = L.attention(block_params["mixer"], h, cfg, positions,
                            causal=False)
            x = x + h
            h = L.rms_norm({"scale": block_params["norm2"]}, x,
                           cfg.norm_eps)
            x = x + L.ffn(block_params["ffn"], h)
            return x, None

        if cfg.remat != "none":
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
        return L.rms_norm({"scale": params["encoder"]["norm_f"]}, x,
                          cfg.norm_eps)

    # -- full-sequence forward (train / prefill) -------------------------------

    def hidden(self, params: Params, tokens: jnp.ndarray,
               enc_input: Optional[jnp.ndarray] = None):
        """tokens (B,S) -> (final hidden states (B,S,d), moe_aux)."""
        cfg = self.cfg
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        x = L.embed_tokens(params["embed"], tokens).astype(self.dtype)
        x = constrain(x, "batch", "seq", "act_embed")

        if cfg.encoder_layers:
            assert enc_input is not None, "enc-dec model needs enc_input"
            enc_out = self.encode(params, enc_input)
            x, aux = self._decoder_with_cross(params, x, enc_out,
                                              positions)
        else:
            x, aux = T.apply_stack(params["stack"], x, cfg, positions)

        x = L.rms_norm({"scale": params["norm_f"]}, x, cfg.norm_eps)
        return x, aux

    def forward(self, params: Params, tokens: jnp.ndarray,
                enc_input: Optional[jnp.ndarray] = None):
        """tokens (B,S) -> (logits (B,S,V), moe_aux)."""
        x, aux = self.hidden(params, tokens, enc_input)
        logits = L.unembed(params["embed"], x, self.cfg)
        logits = constrain(logits, "batch", "seq", "vocab")
        return logits, aux

    def _decoder_with_cross(self, params, x, enc_out, positions):
        """Decoder stack with interleaved cross-attention (enc-dec only).

        The self-attn/FFN stack period must be 1 here (it is, for
        seamless); cross-attention params are stacked per layer."""
        cfg = self.cfg

        def body(x, inp):
            block_params, cross_params = inp
            x, aux = T.apply_block(block_params, x, cfg, ATTN, False,
                                   positions)
            h = L.rms_norm({"scale": cross_params["norm"]}, x,
                           cfg.norm_eps)
            x = x + L.cross_attention(cross_params["attn"], h, enc_out,
                                      cfg)
            return x, aux

        if cfg.remat != "none":
            body = jax.checkpoint(body)
        x, auxs = jax.lax.scan(
            body, x, (params["stack"]["pos0"], params["cross"]))
        return x, jnp.sum(auxs)

    # -- loss ------------------------------------------------------------------

    # sequence-chunked cross-entropy: the fp32 logits buffer is
    # (B, CE_CHUNK, V) instead of (B, S, V) -- at vocab 65K-200K that is
    # the difference between ~1 GB and ~8+ GB of live fp32 per device.
    CE_CHUNK = 512

    def loss(self, params: Params, batch: Dict[str, jnp.ndarray]):
        """Next-token cross-entropy (+ MoE aux). batch: tokens, targets
        (both (B,S)), optional enc_input, optional loss_mask."""
        cfg = self.cfg
        x, aux = self.hidden(params, batch["tokens"],
                             batch.get("enc_input"))
        b, s, d = x.shape
        tgt = batch["targets"]
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones((b, s), jnp.float32)

        chunk = self.CE_CHUNK if s % self.CE_CHUNK == 0 else s
        nc = s // chunk

        def ce(xc, tc, mc):
            logits = L.unembed(params["embed"], xc, cfg)
            logits = constrain(logits, "batch", "seq", "vocab")
            logp = jax.nn.log_softmax(logits.astype(jnp.float32),
                                      axis=-1)
            nll = -jnp.take_along_axis(logp, tc[..., None],
                                       axis=-1)[..., 0]
            return jnp.sum(nll * mc)

        if nc == 1:
            total = ce(x, tgt, mask)
        else:
            xs = (jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0),
                  jnp.moveaxis(tgt.reshape(b, nc, chunk), 1, 0),
                  jnp.moveaxis(mask.reshape(b, nc, chunk), 1, 0))

            def body(acc, inp):
                return acc + ce(*inp), None

            total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                    xs)
        loss = total / jnp.maximum(jnp.sum(mask), 1.0)
        return loss + MOE_AUX_COEF * aux, {"nll": loss, "moe_aux": aux}

    # -- serving prefill --------------------------------------------------------

    def prefill(self, params: Params, tokens: jnp.ndarray,
                enc_input: Optional[jnp.ndarray] = None,
                max_seq: Optional[int] = None):
        """Process the prompt and build the decode cache in one pass.

        Returns (last-position logits (B,1,V), cache). Only the last
        position is unembedded -- a (B,S,V) logits tensor at 32K prefill
        would dwarf every other buffer."""
        cfg = self.cfg
        b, s = tokens.shape
        max_seq = max_seq or s
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        x = L.embed_tokens(params["embed"], tokens).astype(self.dtype)
        x = constrain(x, "batch", "seq", "act_embed")

        if cfg.encoder_layers:
            assert enc_input is not None
            enc_out = self.encode(params, enc_input)

            def body(x, inp):
                block_params, cross_params = inp
                x, cache = T.apply_block_prefill(
                    block_params, x, cfg, "attn", False, positions,
                    max_seq)
                h = L.rms_norm({"scale": cross_params["norm"]}, x,
                               cfg.norm_eps)
                x = x + L.cross_attention(cross_params["attn"], h,
                                          enc_out, cfg)
                return x, cache

            x, pos0 = jax.lax.scan(
                body, x, (params["stack"]["pos0"], params["cross"]))
            cache = {"stack": {"pos0": pos0}}
        else:
            x, stack_cache = T.apply_stack_prefill(
                params["stack"], x, cfg, positions, max_seq)
            cache = {"stack": stack_cache}

        x_last = x[:, -1:]
        x_last = L.rms_norm({"scale": params["norm_f"]}, x_last,
                            cfg.norm_eps)
        logits = L.unembed(params["embed"], x_last, cfg)
        return logits, cache

    # -- decode ----------------------------------------------------------------

    def init_cache(self, batch: int, max_seq: int) -> Tuple[Params, Dict]:
        cache, axes = T.init_stack_cache(self.cfg, batch, max_seq,
                                         self.dtype)
        return {"stack": cache}, {"stack": axes}

    def decode_step(self, params: Params, cache: Params,
                    token: jnp.ndarray, pos: jnp.ndarray,
                    enc_out: Optional[jnp.ndarray] = None):
        """token (B,1) int32, pos () int32 -> (logits (B,1,V), cache)."""
        cfg = self.cfg
        x = L.embed_tokens(params["embed"], token).astype(self.dtype)
        x = constrain(x, "batch", None, "act_embed")
        if cfg.encoder_layers:
            assert enc_out is not None
            x, new_stack = self._decode_with_cross(params, x,
                                                   cache["stack"],
                                                   pos, enc_out)
        else:
            x, new_stack = T.apply_stack_decode(params["stack"], x, cfg,
                                                cache["stack"], pos)
        x = L.rms_norm({"scale": params["norm_f"]}, x, cfg.norm_eps)
        logits = L.unembed(params["embed"], x, cfg)
        return logits, {"stack": new_stack}

    def _decode_with_cross(self, params, x, cache, pos, enc_out):
        cfg = self.cfg

        def body(x, inp):
            block_params, cross_params, block_cache = inp
            x, nc = T.apply_block_decode(block_params, x, cfg, ATTN,
                                         False, block_cache, pos)
            h = L.rms_norm({"scale": cross_params["norm"]}, x,
                           cfg.norm_eps)
            x = x + L.cross_attention(cross_params["attn"], h, enc_out,
                                      cfg)
            return x, nc

        x, new_cache = jax.lax.scan(
            body, x,
            (params["stack"]["pos0"], params["cross"], cache["pos0"]))
        return x, {"pos0": new_cache}


def build_model(cfg: ArchConfig, dtype=jnp.float32) -> ModelDef:
    return ModelDef(cfg=cfg, dtype=dtype)
