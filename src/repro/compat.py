"""Version-compat shims over moving jax APIs.

The repo targets whatever jax the container ships (currently 0.4.37)
while staying forward-compatible with the renames that land in 0.5+:

* ``jax.shard_map`` only exists in newer jax; 0.4.x has
  ``jax.experimental.shard_map.shard_map``, and the replication-check
  kwarg was renamed ``check_rep`` -> ``check_vma`` along the move.
* ``jax.tree.flatten_with_path`` only exists in newer jax; 0.4.x has
  ``jax.tree_util.tree_flatten_with_path``.

Import from here instead of feature-testing at every call site.
"""
from __future__ import annotations

import inspect

import jax

try:  # jax >= 0.5-ish
    _shard_map_impl = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SHARD_MAP_KWARGS = frozenset(
    inspect.signature(_shard_map_impl).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
    """``jax.shard_map`` with the ``check_vma``/``check_rep`` rename
    papered over (callers use the new-style ``check_vma`` name)."""
    if check_vma is not None:
        if "check_vma" in _SHARD_MAP_KWARGS:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in _SHARD_MAP_KWARGS:
            kwargs["check_rep"] = check_vma
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kwargs)


try:  # jax >= 0.4.26 exposes jax.tree.*, but flatten_with_path is newer
    tree_flatten_with_path = jax.tree.flatten_with_path
except AttributeError:
    tree_flatten_with_path = jax.tree_util.tree_flatten_with_path


try:  # top-level alias only exists in newer jax
    enable_x64 = jax.enable_x64
except AttributeError:
    from jax.experimental import enable_x64  # noqa: F401


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized to a flat dict.

    jax <= 0.4.x returns a one-dict-per-computation list; newer jax
    returns the dict directly. Either may be None/empty.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}
