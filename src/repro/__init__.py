"""repro: brTPF (Bindings-Restricted Triple Pattern Fragments) as a
production-grade JAX framework -- query engine, model zoo, distributed
runtime, and TPU Pallas kernels."""
__version__ = "0.1.0"
