"""qwen2-1.5b [dense]: GQA kv=2, QKV bias, tied embeddings.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936
[arXiv:2407.10671]. head_dim=128 (12*128=1536).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-1.5b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    tie_embeddings=True,
))
