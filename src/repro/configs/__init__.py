"""Per-architecture configs (assigned pool) + the shape registry."""
from .base import (ALL_SHAPES, ArchConfig, MoESpec, ShapeSpec, all_archs,
                   get_arch, reduced_for_smoke, register, shapes_for,
                   skipped_shapes_for, TRAIN_4K, PREFILL_32K, DECODE_32K,
                   LONG_500K)

__all__ = ["ALL_SHAPES", "ArchConfig", "MoESpec", "ShapeSpec", "all_archs",
           "get_arch", "reduced_for_smoke", "register", "shapes_for",
           "skipped_shapes_for", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
           "LONG_500K"]
