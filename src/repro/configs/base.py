"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; every workload
shape is a ``ShapeSpec``. ``(arch, shape)`` cells drive the smoke tests,
the multi-pod dry-run, and the roofline table.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

# Block kinds for the per-layer pattern of hybrid models.
ATTN = "attn"
MAMBA = "mamba"
RWKV = "rwkv"


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    experts_per_token: int
    d_ff_expert: int
    every_k_layers: int = 1        # MoE FFN on layers where i % k == k-1
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                 # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int                      # dense FFN width (expert width in MoESpec)
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    moe: Optional[MoESpec] = None
    # per-layer block pattern, tiled to num_layers ('attn' default)
    block_pattern: Tuple[str, ...] = (ATTN,)
    # encoder-decoder (0 = decoder-only)
    encoder_layers: int = 0
    # embedding frontends for [vlm]/[audio] are stubs per the brief
    frontend_stub: bool = False
    rope: bool = True
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # SSM (mamba) dims
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    # RWKV dims
    rwkv_head_dim: int = 64
    # scan chunk for linear-recurrence blocks
    chunk_size: int = 128
    # remat policy for scan-over-layers: 'none' | 'full' | 'dots'
    remat: str = "full"
    # MoE dispatch family: 'einsum' (GShard one-hot) | 'gather' (sort +
    # scatter-add; zero dispatch FLOPs -- the beyond-paper SPerf variant)
    moe_dispatch: str = "einsum"
    # per-arch logical->mesh rule overrides, e.g. FSDP param sharding:
    # (("embed", "data"),) shards every param's embed dim over data and
    # GSPMD all-gathers each layer's weights inside the scan (ZeRO-3)
    sharding_overrides: Tuple[Tuple[str, Any], ...] = ()

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    def layer_kinds(self) -> Tuple[str, ...]:
        pat = self.block_pattern
        reps = -(-self.num_layers // len(pat))
        return (pat * reps)[: self.num_layers]

    @property
    def is_attention_free(self) -> bool:
        return all(k != ATTN for k in self.layer_kinds())

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic archs: any SSM/linear-recurrence layers."""
        return any(k in (MAMBA, RWKV) for k in self.layer_kinds())

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        k = self.moe.every_k_layers
        return i % k == k - 1

    # -- parameter counting (for 6*N*D roofline terms) -----------------------

    def param_count(self, active_only: bool = False) -> int:
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        total = 0
        kinds = self.layer_kinds()
        for i, kind in enumerate(kinds):
            if kind == ATTN:
                q = d * self.num_heads * hd
                kv = 2 * d * self.num_kv_heads * hd
                o = self.num_heads * hd * d
                total += q + kv + o
            elif kind == MAMBA:
                din = self.ssm_expand * d
                total += (d * 2 * din              # in_proj (x and gate)
                          + din * self.ssm_conv_dim
                          + din * (2 * self.ssm_state_dim + 1)  # B,C,dt proj
                          + din                    # A (per-channel) + dt bias
                          + din * d)               # out_proj
            elif kind == RWKV:
                # time-mix: r,k,v,g,o projections + decay lora
                total += 5 * d * d + 2 * d * 64
                # channel-mix: W_k (d,ff), W_v (ff,d), W_r (d,d)
                total += 2 * d * ff + d * d
                total += 2 * d
                continue  # RWKV has its own FFN (channel mix)
            # FFN
            if self.is_moe_layer(i):
                m = self.moe
                total += m.num_experts * 3 * d * m.d_ff_expert
                total += d * m.num_experts       # router
            else:
                total += 3 * d * ff
            total += 2 * d                        # norms
        total += v * d                            # embed in
        if not self.tie_embeddings:
            total += v * d                        # lm head
        if self.encoder_layers:
            # encoder stack (self-attn + ffn) + decoder cross-attn
            enc = self.encoder_layers * (
                (2 + 2) * d * self.num_heads * hd + 3 * d * ff + 2 * d)
            xattn = self.num_layers * (
                (2 + 2) * d * self.num_heads * hd + d)
            total += enc + xattn
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe
        total = self.param_count()
        n_moe = sum(1 for i in range(self.num_layers)
                    if self.is_moe_layer(i))
        inactive = n_moe * (m.num_experts - m.experts_per_token) * (
            3 * d * m.d_ff_expert)
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K,
                                     LONG_500K)


def shapes_for(cfg: ArchConfig) -> Tuple[ShapeSpec, ...]:
    """The runnable shape set for an arch (skips recorded in the table)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.supports_long_context:
        out.append(LONG_500K)
    return tuple(out)


def skipped_shapes_for(cfg: ArchConfig) -> Tuple[Tuple[ShapeSpec, str], ...]:
    if not cfg.supports_long_context:
        return ((LONG_500K, "full attention (quadratic); per-brief skip"),)
    return ()


# Registry -- populated by the per-arch config modules.
_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_archs() -> Dict[str, ArchConfig]:
    if not _REGISTRY:
        _load_all()
    return dict(_REGISTRY)


def _load_all() -> None:
    from . import (chameleon_34b, chatglm3_6b, codeqwen15_7b,  # noqa: F401
                   granite_moe_1b_a400m, jamba_15_large_398b,
                   olmoe_1b_7b, phi4_mini_38b, qwen2_15b, rwkv6_7b,
                   seamless_m4t_medium)


def reduced_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """Same family, tiny dims: used by the per-arch CPU smoke tests.

    Preserves what makes the family distinctive (GQA ratio, MoE routing,
    block pattern period, enc-dec split) while shrinking width/depth."""
    kv_ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
    heads = 4
    moe = None
    if cfg.moe is not None:
        # capacity_factor = num_experts -> capacity == T*k: provably no
        # token drops, so decode-vs-forward equality holds exactly in the
        # numerics tests (production configs keep the real 1.25).
        moe = MoESpec(num_experts=4,
                      experts_per_token=min(2, cfg.moe.experts_per_token),
                      d_ff_expert=64,
                      every_k_layers=cfg.moe.every_k_layers,
                      capacity_factor=4.0)
    pattern = cfg.block_pattern
    layers = max(2, len(pattern)) if len(pattern) > 1 else 2
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=layers,
        d_model=64,
        num_heads=heads if cfg.num_heads else 0,
        num_kv_heads=max(1, heads // kv_ratio) if cfg.num_heads else 0,
        head_dim=16 if cfg.num_heads else 0,
        d_ff=128,
        vocab_size=256,
        moe=moe,
        encoder_layers=2 if cfg.encoder_layers else 0,
        ssm_state_dim=8,
        rwkv_head_dim=16,
        chunk_size=8,
    )
