"""jamba-1.5-large-398b [hybrid]: Mamba+attention 1:7, MoE 16e top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE every other
layer [arXiv:2403.19887]. Block period of 8: one attention layer per 7
Mamba layers (attention at period index 4, as in the Jamba paper); MoE
FFN on odd layers. Runs long_500k (only its 9 attention layers carry a
KV cache; the 63 Mamba layers keep constant-size state).
"""
from .base import ATTN, ArchConfig, MAMBA, MoESpec, register

_PERIOD = (MAMBA, MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA)

CONFIG = register(ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    moe=MoESpec(num_experts=16, experts_per_token=2, d_ff_expert=24576,
                every_k_layers=2),
    block_pattern=_PERIOD,
    rope=False,          # Jamba uses no positional embeddings
    ssm_state_dim=16,
    ssm_expand=2,
    # 398B bf16 over model=16 alone is ~50 GB/chip; FSDP-shard the
    # params' embed dims over data too (ZeRO-3 via GSPMD): ~3.1 GB/chip,
    # with per-layer weight all-gathers inside the scan
    sharding_overrides=(("embed", "data"),),
))
