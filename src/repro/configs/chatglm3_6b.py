"""chatglm3-6b [dense]: RoPE (2d/half-rotary), GQA kv=2.

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024
[arXiv:2406.12793]. ChatGLM applies rotary embeddings to half the head
dims ("2d RoPE"); we implement standard full-dim RoPE -- an FLOP-neutral
simplification recorded in DESIGN.md.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    qkv_bias=True,
))
