"""olmoe-1b-7b [moe]: 64 experts top-8, every layer MoE.

16L d_model=2048 16H (GQA kv=16) d_ff=1024(expert) vocab=50304
[arXiv:2409.02060]. ~7B total / ~1B active.
"""
from .base import ArchConfig, MoESpec, register

CONFIG = register(ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    moe=MoESpec(num_experts=64, experts_per_token=8, d_ff_expert=1024,
                every_k_layers=1),
))
