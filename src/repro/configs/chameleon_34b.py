"""chameleon-34b [vlm]: early-fusion multimodal decoder, VQ image tokens.

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536
[arXiv:2405.09818]. Early fusion means image tokens are ordinary vocab
entries (VQ codes); the VQ tokenizer frontend is a stub per the brief --
``input_specs()`` provides token ids directly.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    head_dim=128,
    frontend_stub=True,
    rope=True,
))
