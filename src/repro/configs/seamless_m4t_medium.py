"""seamless-m4t-medium [audio]: encoder-decoder, multimodal.

12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206
[arXiv:2308.11596]. 12 encoder + 12 decoder layers; the speech frontend
is a stub per the brief: ``input_specs()`` provides precomputed frame
embeddings (B, frames, d_model) to the encoder. Decode shapes run the
autoregressive text decoder with cross-attention to the encoder output.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    encoder_layers=12,
    frontend_stub=True,
    rope=False,          # learned/sinusoidal positions in the original
))
