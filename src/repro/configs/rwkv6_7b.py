"""rwkv6-7b [ssm]: Finch -- attention-free, data-dependent decay.

32L d_model=4096 (attn-free) d_ff=14336 vocab=65536 [arXiv:2404.05892].
Linear recurrence with per-channel data-dependent decay (WKV6); runs the
long_500k shape (constant-size recurrent state instead of a KV cache).
"""
from .base import ArchConfig, RWKV, register

CONFIG = register(ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=(RWKV,),
    rope=False,
    rwkv_head_dim=64,
    # chunked WKV materializes a (B, C, C, H, hd) pairwise-decay tensor;
    # C=16 keeps it ~0.4 GB/device at train_4k (C=128 would be ~100 GB)
    chunk_size=16,
))
