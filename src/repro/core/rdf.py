"""Dictionary-encoded RDF: terms, triple patterns, solution mappings.

The paper's server (HDT backend) operates on dictionary-encoded triples;
we mirror that design: every RDF term (IRI / literal) is interned to an
``int32`` id once, and all engine/device code operates on ids only.

Encoding conventions (used across host numpy code and Pallas kernels):

* constants (IRIs/literals): ids ``>= 0``
* variables in triple patterns: ``encode_var(v) = -(v + 1)`` (i.e. ``< 0``)
* solution mappings: dense ``int32[num_vars]`` rows, ``UNBOUND = -1`` marks
  an unbound variable.

Keeping variables strictly negative and constants non-negative lets a
single sign test distinguish them inside kernels with no extra storage.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

UNBOUND: int = -1

# ---------------------------------------------------------------------------
# Term dictionary
# ---------------------------------------------------------------------------


class TermDictionary:
    """Bidirectional string<->id interning (host side only)."""

    def __init__(self) -> None:
        self._by_term: Dict[str, int] = {}
        self._by_id: List[str] = []

    def intern(self, term: str) -> int:
        tid = self._by_term.get(term)
        if tid is None:
            tid = len(self._by_id)
            self._by_term[term] = tid
            self._by_id.append(term)
        return tid

    def lookup(self, term: str) -> Optional[int]:
        return self._by_term.get(term)

    def term(self, tid: int) -> str:
        return self._by_id[tid]

    def __len__(self) -> int:
        return len(self._by_id)


# ---------------------------------------------------------------------------
# Variables and triple patterns
# ---------------------------------------------------------------------------


def encode_var(var_id: int) -> int:
    """Encode variable ``var_id >= 0`` as a negative pattern component."""
    assert var_id >= 0
    return -(var_id + 1)


def decode_var(component: int) -> int:
    """Inverse of :func:`encode_var`; only valid for ``component < 0``."""
    assert component < 0
    return -component - 1


def is_var(component: int) -> bool:
    return component < 0


@dataclasses.dataclass(frozen=True)
class TriplePattern:
    """A triple pattern ``(s, p, o)`` with constants >= 0 and vars < 0."""

    s: int
    p: int
    o: int

    def as_tuple(self) -> Tuple[int, int, int]:
        return (self.s, self.p, self.o)

    def variables(self) -> Tuple[int, ...]:
        """Distinct variable ids, in s,p,o position order."""
        out: List[int] = []
        for c in self.as_tuple():
            if is_var(c):
                v = decode_var(c)
                if v not in out:
                    out.append(v)
        return tuple(out)

    def num_bound(self) -> int:
        return sum(0 if is_var(c) else 1 for c in self.as_tuple())

    def instantiate(self, mapping: np.ndarray) -> "TriplePattern":
        """Apply a solution mapping (dense row over all query vars)."""
        comps = []
        for c in self.as_tuple():
            if is_var(c):
                v = decode_var(c)
                b = int(mapping[v]) if v < mapping.shape[0] else UNBOUND
                comps.append(c if b == UNBOUND else b)
            else:
                comps.append(c)
        return TriplePattern(*comps)

    def matches_triple(self, t: Sequence[int]) -> bool:
        """Exact per-definition match check (used by test oracles)."""
        binding: Dict[int, int] = {}
        for c, x in zip(self.as_tuple(), t, strict=True):
            if is_var(c):
                v = decode_var(c)
                if v in binding and binding[v] != x:
                    return False
                binding[v] = x
            elif c != x:
                return False
        return True


# ---------------------------------------------------------------------------
# Solution mappings
# ---------------------------------------------------------------------------


def empty_mappings(num_vars: int) -> np.ndarray:
    return np.empty((0, max(num_vars, 1)), dtype=np.int32)


def compatible(mu: np.ndarray, nu: np.ndarray) -> bool:
    """SPARQL compatibility: agree on every variable bound in both."""
    both = (mu != UNBOUND) & (nu != UNBOUND)
    return bool(np.all(mu[both] == nu[both]))


def merge(mu: np.ndarray, nu: np.ndarray) -> np.ndarray:
    """Merge two compatible mappings (mu takes precedence where bound)."""
    out = mu.copy()
    take = (out == UNBOUND) & (nu != UNBOUND)
    out[take] = nu[take]
    return out


def mapping_from_triple(tp: TriplePattern, triple: Sequence[int],
                        num_vars: int) -> Optional[np.ndarray]:
    """The mapping mu with mu(tp) == triple, or None if no match."""
    mu = np.full((num_vars,), UNBOUND, dtype=np.int32)
    for c, x in zip(tp.as_tuple(), triple, strict=True):
        if is_var(c):
            v = decode_var(c)
            if mu[v] != UNBOUND and mu[v] != x:
                return None
            mu[v] = x
        elif c != x:
            return None
    return mu


def dedup_mappings(omega: np.ndarray) -> np.ndarray:
    """Remove duplicate rows, preserving first-occurrence order."""
    if omega.shape[0] == 0:
        return omega
    _, idx = np.unique(omega, axis=0, return_index=True)
    return omega[np.sort(idx)]


def project_mappings(omega: np.ndarray, var_ids: Iterable[int],
                     num_vars: int) -> np.ndarray:
    """Keep only ``var_ids`` bound; other columns become UNBOUND."""
    out = np.full_like(omega, UNBOUND)
    for v in var_ids:
        if v < num_vars:
            out[:, v] = omega[:, v]
    return out
