"""brTPF core: the paper's contribution as a composable library.

Layers: dictionary-encoded RDF (``rdf``), HDT-style store (``store``),
selector functions per Definitions 1-2 (``selectors``), the combined
TPF/brTPF server (``server``), the two client algorithms (``client``),
LRU cache simulation (``cache``), the unified page-granular fragment
store under every cache layer (``fragments``), and request accounting
(``metrics``).
"""
from .batching import (AsyncBrTPFServer, BatchStats, DeadlineExceeded,
                       QueueSaturated, drive_streams, serve_concurrent)
from .bgp import BGP, bgp_from_arrays, evaluate_bgp_reference, parse_bgp
from .cache import LRUCache, request_key
from .client import (AsyncBrTPFClient, BrTPFClient, ExecutionResult,
                     TPFClient, plan_join_order)
from .config import ServerConfig
from .fragments import (ClientFragmentCache, FragmentStore, fragment_key)
from .metrics import (Counters, latency_summary, layer_metrics,
                      metrics_snapshot)
from .wire import (WIRE_VERSION, WireError, fragment_from_wire,
                   fragment_to_wire, request_from_wire, request_to_wire)
from .rdf import (TermDictionary, TriplePattern, UNBOUND, compatible,
                  decode_var, dedup_mappings, encode_var, is_var,
                  mapping_from_triple, merge, project_mappings)
from .selectors import (Fragment, brtpf_cardinality, brtpf_select,
                        brtpf_select_with_cnt, instantiate_patterns,
                        tpf_select)
from .server import (BrTPFServer, MaxMprExceeded, Request,
                     DEFAULT_MAX_MPR, DEFAULT_PAGE_SIZE)
from .store import (CandidateRange, SpanGroup, SubRanges, TripleStore,
                    merge_spans, store_from_ntriples)

# KernelSelector/LaunchRecord are intentionally NOT imported here:
# core stays importable without jax; server.py imports them lazily for
# selector_backend="kernel", and direct users import
# repro.core.kernel_selectors explicitly.
__all__ = [
    "AsyncBrTPFClient", "AsyncBrTPFServer", "BatchStats",
    "BGP", "BrTPFClient", "BrTPFServer", "CandidateRange",
    "DeadlineExceeded", "QueueSaturated",
    "ClientFragmentCache", "Counters",
    "ExecutionResult",
    "Fragment", "FragmentStore", "LRUCache",
    "MaxMprExceeded", "Request", "TPFClient",
    "fragment_key", "layer_metrics", "metrics_snapshot",
    "latency_summary", "ServerConfig",
    "WIRE_VERSION", "WireError", "fragment_from_wire", "fragment_to_wire",
    "request_from_wire", "request_to_wire",
    "drive_streams", "plan_join_order", "serve_concurrent",
    "TermDictionary", "TriplePattern", "TripleStore", "UNBOUND",
    "bgp_from_arrays", "brtpf_cardinality", "brtpf_select", "brtpf_select_with_cnt", "compatible",
    "decode_var", "dedup_mappings", "encode_var", "evaluate_bgp_reference",
    "instantiate_patterns", "is_var", "mapping_from_triple", "merge",
    "merge_spans", "parse_bgp", "project_mappings", "request_key",
    "store_from_ntriples", "tpf_select",
    "SpanGroup", "SubRanges",
    "DEFAULT_MAX_MPR", "DEFAULT_PAGE_SIZE",
]
