"""Distributed brTPF: the triple store sharded over the mesh.

The paper (section 2.2) notes that TPF-style interfaces compose into
federations of servers. Here the federation *is* the mesh: the dataset is
partitioned across the ``data`` axis (one shard per device = one "brTPF
server"), a request -- (triple pattern, attached mappings) -- is broadcast
to every shard, each shard evaluates the bindings-restricted selector
locally with the Pallas ``bindjoin`` kernel, and the fixed-capacity local
pages are all-gathered back to the requesting client.

This is the paper's thesis expressed in mesh terms: the bindings (a few
KB) travel to the data, instead of the data (the full TPF fragment)
traveling to the client. The dry-run rooflines in EXPERIMENTS.md quantify
exactly this collective-byte saving.

Since PR 3 the *windowed* request step is the default: each shard
binary-searches its sorted keys for the pattern's bound-prefix range and
streams only a fixed ``window`` of it per launch, so per-request device
work scales with the window -- never with the range or the shard size.
:class:`ShardedSelector` packages this as a first-class selector backend
for :class:`~repro.core.server.BrTPFServer` (``selector_backend=
"sharded"``), byte-identical to ``selectors.brtpf_select_with_cnt`` and
sharing the grouped multi-request geometry (G same-pattern requests =
one sharded launch) and :class:`~repro.core.kernel_selectors.LaunchRecord`
accounting surface with the single-host kernel path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import enable_x64, shard_map
from ..kernels import ops as kops
from .fragments import FragmentStore
from .kernel_selectors import (LaunchRecord, consult_fragments,
                               marshal_pattern_grid, record_fragments,
                               select_block_numpy, stream_order)
from .rdf import TriplePattern, is_var
from .selectors import instantiate_patterns

# Default per-shard window: one launch streams this many candidate rows
# per device. 8 * 128 VPU sublane*lane tiles; small enough that a page-0
# probe of a selective pattern costs a fraction of a shard pass, large
# enough that WatDiv-scale ranges need a handful of windows.
DEFAULT_SHARD_WINDOW = 1024


def _local_brtpf(cand: jnp.ndarray, patterns: jnp.ndarray,
                 pat_valid: jnp.ndarray, base_vec: jnp.ndarray,
                 cand_valid: jnp.ndarray, capacity: int):
    """Per-shard selector: Definition 1 on the local partition.

    ``base_vec`` carries the original pattern's repeated-variable equality
    flags (the instantiated-pattern grid alone cannot express them).
    Returns a fixed-shape local page (capacity, 3) padded with -1 + count.
    """
    keep, _ = kops.bindjoin(cand, patterns, pat_valid)
    keep &= kops.tpf_match(cand, base_vec)
    keep &= cand_valid
    idx, count = kops.compact_mask(keep, capacity)
    page = jnp.take(cand, jnp.maximum(idx, 0), axis=0)
    page = jnp.where((idx >= 0)[:, None], page, -1)
    return page, count


@dataclasses.dataclass
class ShardIndex:
    """One component order's per-shard sorted mirror of the partition.

    ``host_keys`` keeps a host-side copy of the per-shard sorted keys:
    the request planner (:meth:`FederatedStore.plan_windows`) uses it to
    binary-search shard-local ranges and Omega sub-ranges *before*
    launching, so windows provably disjoint from every sub-range are
    never dispatched. (The device step re-derives the same bounds with
    an on-device searchsorted -- the host copy only steers which pages
    launch, it never feeds result data.)
    """

    name: str                # "spo" | "pos" | "osp"
    triples: jax.Array       # int32 [shards * shard_n, 3], per-shard sorted
    valid: jax.Array         # bool  [shards * shard_n]
    keys: jax.Array          # int64 [shards * shard_n]
    host_keys: np.ndarray    # int64 [shards, shard_n] (same values)


@dataclasses.dataclass
class WindowPlan:
    """Host-side launch plan for one (grouped) windowed request.

    ``pages`` lists the window indexes that can contain join-relevant
    rows on at least one shard; everything else is skipped. Unpruned
    plans list every page of the pattern's bound-prefix range under
    ``order``; pruned plans keep only pages intersecting some
    per-binding sub-range. ``candidate_rows`` is the total (cross-shard)
    row count inside the relevant sub-ranges -- the small-work fast
    path's decision quantity.
    """

    order: str
    lo_key: int
    hi_key: int
    pages: List[int]
    range_rows: int          # sum over shards of the base range length
    candidate_rows: int      # rows inside relevant sub-ranges (<= above)
    pruned: bool
    pages_total: int         # pages an unpruned plan would launch


@dataclasses.dataclass
class FederatedStore:
    """Triple store sharded over one mesh axis (one shard = one server).

    Each shard keeps its partition sorted with packed int64 keys in all
    three component orders -- SPO plus the POS/OSP mirrors (every
    federation member is an HDT-style server with HDT's three indexes).
    The mirrors are what let unbound-subject patterns (``(?s, p, ?o)``,
    ``(?s, ?p, o)``) binary-search a narrow shard-local range instead of
    scanning the whole shard, and the *windowed* request path (the
    default since PR 3) streams only a fixed window of the chosen
    order's range per launch.
    """

    mesh: Mesh
    axis: str
    triples: jax.Array       # SPO mirror (compat alias of indexes["spo"])
    valid: jax.Array
    keys: jax.Array
    shard_n: int
    indexes: Dict[str, ShardIndex] = dataclasses.field(
        default_factory=dict, repr=False)
    # jit-cache for the windowed request steps, keyed on the static
    # launch geometry (window, groups, pattern slots, projection).
    _steps: Dict[tuple, object] = dataclasses.field(
        default_factory=dict, repr=False)

    @property
    def shards(self) -> int:
        return self.mesh.shape[self.axis]

    @classmethod
    def build(cls, triples_np: np.ndarray, mesh: Mesh,
              axis: str = "data") -> "FederatedStore":
        from .store import _ORDERS, _pack
        shards = mesh.shape[axis]
        n = triples_np.shape[0]
        shard_n = max(1, -(-n // shards))
        total = shard_n * shards
        base = np.full((total, 3), -1, dtype=np.int32)
        base[:n] = triples_np
        base_valid = np.zeros((total,), dtype=bool)
        base_valid[:n] = True
        sharding = NamedSharding(mesh, P(axis, None))
        vsharding = NamedSharding(mesh, P(axis))
        indexes: Dict[str, ShardIndex] = {}
        for name, comp_order in _ORDERS.items():
            padded = base.copy()
            valid = base_valid.copy()
            # per-shard sort under this order's packed key (padding rows
            # key to +inf -> sort last). int64 keys need the x64 context
            # (off by default in jax).
            keys = np.where(
                valid,
                _pack(padded[:, comp_order[0]], padded[:, comp_order[1]],
                      padded[:, comp_order[2]]),
                np.iinfo(np.int64).max)
            for s in range(shards):
                sl = slice(s * shard_n, (s + 1) * shard_n)
                order = np.argsort(keys[sl], kind="stable")
                padded[sl] = padded[sl][order]
                valid[sl] = valid[sl][order]
                keys[sl] = keys[sl][order]
            with enable_x64(True):
                keys_dev = jax.device_put(keys, vsharding)
            indexes[name] = ShardIndex(
                name=name,
                triples=jax.device_put(padded, sharding),
                valid=jax.device_put(valid, vsharding),
                keys=keys_dev,
                host_keys=keys.reshape(shards, shard_n))
        spo = indexes["spo"]
        return cls(mesh=mesh, axis=axis,
                   triples=spo.triples, valid=spo.valid, keys=spo.keys,
                   shard_n=shard_n, indexes=indexes)

    # -- host-side request marshalling ---------------------------------------

    def request_arrays(self, tp: TriplePattern,
                       omega: Optional[np.ndarray],
                       max_mpr: int) -> Tuple[np.ndarray, np.ndarray,
                                              np.ndarray]:
        """Host-side request marshalling: instantiate + dedup (server
        algorithm steps 1-3) and pad to the interface's maxMpR."""
        insts = instantiate_patterns(tp, omega)
        if len(insts) > max_mpr:
            raise ValueError(f"{len(insts)} instantiations > maxMpR")
        pats = np.full((max_mpr, 3), -1, dtype=np.int32)
        valid = np.zeros((max_mpr,), dtype=np.int32)
        for i, p in enumerate(insts):
            pats[i] = [c if not is_var(c) else -1 for c in p.as_tuple()]
            valid[i] = 1
        comps = tp.as_tuple()
        base_vec = kops.pattern_vec_from(
            tuple(-1 if is_var(c) else c for c in comps),
            eq_sp=int(is_var(comps[0]) and comps[0] == comps[1]),
            eq_so=int(is_var(comps[0]) and comps[0] == comps[2]),
            eq_po=int(is_var(comps[1]) and comps[1] == comps[2]),
        )
        return pats, valid, base_vec

    @staticmethod
    def prefix_keys(tp: TriplePattern,
                    order_name: str = "spo") -> Tuple[int, int]:
        """(lo_key, hi_key) of the pattern's bound prefix under the
        given index order -- the host-computed range bounds every shard
        binary-searches (the client computing a page URL, in mesh
        terms). Defaults to the SPO mirror for compatibility with the
        single-request windowed path."""
        from .store import _MAX_ID, _ORDERS, _pack
        comp_order = _ORDERS[order_name]
        comps = tp.as_tuple()
        prefix = []
        for pos in comp_order:
            if is_var(comps[pos]):
                break
            prefix.append(comps[pos])
        lo_vals = prefix + [0] * (3 - len(prefix))
        hi_vals = prefix + [_MAX_ID] * (3 - len(prefix))
        lo = int(_pack(np.int64(lo_vals[0]), np.int64(lo_vals[1]),
                       np.int64(lo_vals[2])))
        hi = int(_pack(np.int64(hi_vals[0]), np.int64(hi_vals[1]),
                       np.int64(hi_vals[2])))
        return lo, hi

    # -- host-side launch planning (Omega-restricted window skip) ------------

    def plan_windows(self, tp: TriplePattern,
                     insts: Sequence[TriplePattern],
                     window: int) -> WindowPlan:
        """Plan the window launches for one (grouped) request.

        Index choice: when every instantiated pattern shares one shape
        whose best index binds a longer prefix than the base pattern
        does under that index, the launch streams THAT order and the
        per-binding sub-ranges become host-computable window filters;
        otherwise the base pattern's own best index is used (the
        POS/OSP mirrors are what make this a real choice -- an
        unbound-subject pattern no longer scans whole shards).

        Window skip: the per-binding ``(lo, hi)`` key intervals are
        batch-searchsorted against every shard's host key copy; a window
        page whose owned span intersects no sub-range on any shard is
        provably match-free (every triple matching instantiation ``p_j``
        has its key inside ``p_j``'s interval) and is dropped from
        ``pages``. Skipping whole pages never reorders or duplicates
        anything, so parity is untouched.
        """
        from .store import (TripleStore, _ORDERS, merge_spans,
                            prefix_interval_keys)
        window = max(1, min(int(window), self.shard_n))

        def base_plan(order_name: str) -> WindowPlan:
            lo, hi = self.prefix_keys(tp, order_name)
            hk = self.indexes[order_name].host_keys
            starts = np.array([np.searchsorted(hk[s], lo, side="left")
                               for s in range(hk.shape[0])])
            ends = np.array([np.searchsorted(hk[s], hi, side="right")
                             for s in range(hk.shape[0])])
            range_rows = int((ends - starts).sum())
            pages_total = int(max(
                (-(-int(e - s) // window)
                 for s, e in zip(starts, ends, strict=True)), default=0))
            return WindowPlan(order=order_name, lo_key=lo, hi_key=hi,
                              pages=list(range(pages_total)),
                              range_rows=range_rows,
                              candidate_rows=range_rows, pruned=False,
                              pages_total=pages_total)

        bname, _ = TripleStore._choose_index(tp)
        unpruned = base_plan(bname)
        shapes = {tuple(is_var(c) for c in p.as_tuple()) for p in insts}
        if len(shapes) != 1 or not insts:
            return unpruned
        iname, iplen = TripleStore._choose_index(insts[0])
        # prefix the BASE pattern binds under the instantiations' best
        # index: pruning pays only if instantiations bind more
        comp_order = _ORDERS[iname]
        base_plen = 0
        for pos in comp_order:
            if is_var(tp.as_tuple()[pos]):
                break
            base_plen += 1
        if iplen <= base_plen:
            return unpruned
        comps = np.asarray([p.as_tuple() for p in insts], dtype=np.int64)
        lo_keys, hi_keys = prefix_interval_keys(comps, comp_order, iplen)
        # base range under the insts' index (already computed when the
        # instantiations' best order is the base pattern's own)
        shell = unpruned if iname == bname else base_plan(iname)
        hk = self.indexes[iname].host_keys
        pages: set = set()
        candidate_rows = 0
        for s in range(hk.shape[0]):
            start = int(np.searchsorted(hk[s], shell.lo_key,
                                        side="left"))
            end = int(np.searchsorted(hk[s], shell.hi_key,
                                      side="right"))
            if end <= start:
                continue
            a = np.searchsorted(hk[s], lo_keys, side="left")
            b = np.searchsorted(hk[s], hi_keys, side="right")
            spans = merge_spans(np.stack([a, b], axis=1))
            for slo, shi in spans:
                # instantiation intervals are sub-intervals of the base
                # range under the same order, but clip defensively
                slo = max(int(slo), start)
                shi = min(int(shi), end)
                if shi <= slo:
                    continue
                candidate_rows += shi - slo
                pages.update(range((slo - start) // window,
                                   (shi - 1 - start) // window + 1))
        pruned = WindowPlan(order=iname, lo_key=shell.lo_key,
                            hi_key=shell.hi_key, pages=sorted(pages),
                            range_rows=shell.range_rows,
                            candidate_rows=candidate_rows, pruned=True,
                            pages_total=shell.pages_total)
        # the base pattern's own index may beat sub-range skipping under
        # the instantiations' index (fewer actual window dispatches win)
        return pruned if len(pruned.pages) <= len(unpruned.pages) \
            else unpruned

    # -- the request path ----------------------------------------------------

    def execute(self, tp: TriplePattern, omega: Optional[np.ndarray],
                max_mpr: int, capacity: int) -> np.ndarray:
        """Run one distributed brTPF request; returns matching triples.

        Routed through the windowed step (the default request path):
        per-shard device work is bounded by the window, and -- unlike
        :meth:`execute_full` -- the result can never be truncated by an
        undersized ``capacity`` (each window's page capacity is the
        window itself).
        """
        return self.execute_windowed(tp, omega, max_mpr, capacity,
                                     window=min(capacity, self.shard_n))

    def execute_full(self, tp: TriplePattern, omega: Optional[np.ndarray],
                     max_mpr: int, capacity: int) -> np.ndarray:
        """The paper-faithful baseline: every shard streams its whole
        partition through the bind-join kernel in one launch. Kept for
        the dry-run roofline comparison; ``capacity`` bounds the local
        page (matches beyond it are silently dropped)."""
        pats, valid, base_vec = self.request_arrays(tp, omega, max_mpr)
        pages, counts = self.lowerable(capacity)(
            self.triples, self.valid, jnp.asarray(pats),
            jnp.asarray(valid), jnp.asarray(base_vec))
        pages = np.asarray(pages).reshape(-1, 3)
        keep = pages[:, 0] >= 0  # -1-padded rows are invalid
        return pages[keep]

    def lowerable(self, capacity: int):
        """The jitted full-shard-stream request step (also used by the
        dry-run: ``.lower(...).compile()`` proves the collective
        schedule of the baseline variant)."""
        mesh, axis = self.mesh, self.axis

        def step(triples, valid, pats, pat_valid, base_vec):
            def shard_fn(cand, cand_valid, p, pv, bv):
                page, count = _local_brtpf(
                    cand, p, pv, bv, cand_valid, capacity)
                # Return per-shard pages; the all-gather back to the
                # client is the response wire transfer.
                page = jax.lax.all_gather(page, axis)
                count = jax.lax.all_gather(count, axis)
                return page, count

            fn = shard_map(
                shard_fn, mesh=mesh,
                in_specs=(P(axis, None), P(axis), P(), P(), P()),
                out_specs=(P(), P()),
                # pallas_call emits ShapeDtypeStructs without vma metadata
                check_vma=False,
            )
            return fn(triples, valid, pats, pat_valid, base_vec)

        return jax.jit(step)

    # -- the windowed request path (default) ---------------------------------

    def lowerable_windowed(self, capacity: int, window: int,
                           wild_cols: tuple = (0, 1, 2)):
        """Single-request windowed step (see EXPERIMENTS.md §Perf(D)):

        1. *windowed scan*: each shard binary-searches its sorted keys
           for the pattern's bound-prefix range and runs the bind-join
           kernel over a fixed ``window`` starting there, not the whole
           shard -- compute/memory per request drops shard_n/window x
           for selective patterns;
        2. *column projection*: only the pattern's unbound components
           (``wild_cols``) are all-gathered back -- the bound
           components are implied by the request, cutting response
           bytes by (3 - len(wild_cols))/3.

        Inputs add (lo_key, hi_key) int64 scalars (host-computed from
        the pattern prefix, identical on every shard). Page windows are
        *disjoint* spans of the range (a span near the shard edge is
        masked, not shifted), so paging never double-reports a triple.
        """
        mesh, axis = self.mesh, self.axis
        window = max(1, min(window, self.shard_n))

        def step(triples, valid, keys, pats, pat_valid, base_vec,
                 lo_key, hi_key, page_idx):
            def shard_fn(cand, cand_valid, k, p, pv, bv, lo, hi, pi):
                start = jnp.searchsorted(k, lo, side="left")
                end = jnp.searchsorted(k, hi, side="right")
                range_len = end - start                 # page metadata
                win, win_valid, in_span = _window_slice(
                    cand, cand_valid, start, end, pi, window)
                page, count = _local_brtpf(
                    win, p, pv, bv, win_valid & in_span, capacity)
                page = page[:, list(wild_cols)]
                page = jax.lax.all_gather(page, axis)
                count = jax.lax.all_gather(count, axis)
                range_len = jax.lax.all_gather(range_len, axis)
                return page, count, range_len

            fn = shard_map(
                shard_fn, mesh=mesh,
                in_specs=(P(axis, None), P(axis), P(axis), P(), P(),
                          P(), P(), P(), P()),
                out_specs=(P(), P(), P()),
                check_vma=False,
            )
            return fn(triples, valid, keys, pats, pat_valid, base_vec,
                      lo_key, hi_key, page_idx)

        return jax.jit(step)

    def lowerable_windowed_grouped(self, window: int, groups: int,
                                   wild_cols: tuple = (0, 1, 2)):
        """Grouped windowed step: G same-pattern requests, one launch.

        The sharded twin of ``kops.bindjoin_grouped``'s geometry: every
        shard streams ONE window of its bound-prefix range and evaluates
        all G requests' instantiated-pattern sets against it, so
        coalesced batches (``BrTPFServer.handle_batch`` /
        ``AsyncBrTPFServer``) cost one sharded launch per window instead
        of G. Per (shard, group) the step emits a fixed-shape page of
        compacted kept rows (capacity = window, so a window's matches
        always fit), the first-matching-pattern index per kept row (the
        stream id the ordering epilogue needs), the kept-row count, and
        the group's Definition-2 ``cnt`` contribution (sum of per-row
        matching-pattern counts); plus the shard's range length for
        paging. Jitted steps are cached per static geometry on the
        store (``_steps``).

        Returns arrays shaped (shards, G, window[, C]) / (shards, G) /
        (shards,) after the all-gather.
        """
        # clamp before building the cache key, so raw windows that
        # clamp to the same effective value share one traced step
        window = max(1, min(window, self.shard_n))
        key = ("grouped", window, groups, wild_cols)
        fn = self._steps.get(key)
        if fn is not None:
            return fn
        mesh, axis = self.mesh, self.axis

        def step(triples, valid, keys, pats, pat_valid, base_vec,
                 lo_key, hi_key, page_idx):
            def shard_fn(cand, cand_valid, k, p, pv, bv, lo, hi, pi):
                start = jnp.searchsorted(k, lo, side="left")
                end = jnp.searchsorted(k, hi, side="right")
                range_len = end - start
                win, win_valid, in_span = _window_slice(
                    cand, cand_valid, start, end, pi, window)
                keep, idx, nmatch = kops.bindjoin_grouped(win, p, pv)
                base = kops.tpf_match(win, bv)
                mask = (keep & base[:, None]
                        & (win_valid & in_span)[:, None])        # (W, G)
                cnts = jnp.sum(jnp.where(mask, nmatch, 0), axis=0)
                rows, counts = jax.vmap(
                    lambda m: kops.compact_mask(m, window),
                    in_axes=1, out_axes=0)(mask)          # (G, W), (G,)
                safe = jnp.maximum(rows, 0)
                page = jnp.take(win, safe, axis=0)        # (G, W, 3)
                first = jax.vmap(lambda r, col: col[r],
                                 in_axes=(0, 1))(safe, idx)   # (G, W)
                page = page[:, :, list(wild_cols)]
                page = jnp.where((rows >= 0)[:, :, None], page, -1)
                first = jnp.where(rows >= 0, first, -1)
                page = jax.lax.all_gather(page, axis)
                first = jax.lax.all_gather(first, axis)
                counts = jax.lax.all_gather(counts, axis)
                cnts = jax.lax.all_gather(cnts, axis)
                range_len = jax.lax.all_gather(range_len, axis)
                return page, first, counts, cnts, range_len

            fn = shard_map(
                shard_fn, mesh=mesh,
                in_specs=(P(axis, None), P(axis), P(axis), P(), P(),
                          P(), P(), P(), P()),
                out_specs=(P(), P(), P(), P(), P()),
                check_vma=False,
            )
            return fn(triples, valid, keys, pats, pat_valid, base_vec,
                      lo_key, hi_key, page_idx)

        fn = jax.jit(step)
        self._steps[key] = fn
        return fn

    def execute_windowed(self, tp: TriplePattern,
                         omega: Optional[np.ndarray], max_mpr: int,
                         capacity: int, window: int) -> np.ndarray:
        """Run the windowed path end-to-end: disjoint window pages until
        every shard's bound-prefix range is covered (the first response
        carries each shard's range length -- the cnt metadata of
        Definition 2), with client-side reconstruction of projected
        columns.

        Returns the fragment's data-triple sequence byte-identical
        (values AND order) to ``selectors.brtpf_select_with_cnt``.
        ``capacity`` is accepted for interface symmetry with
        :meth:`execute_full` but the per-window page capacity is the
        window itself, so results are never truncated.
        """
        del capacity  # windowed pages are capacity-safe by construction
        insts = instantiate_patterns(tp, omega)
        if len(insts) > max_mpr:
            raise ValueError(f"{len(insts)} instantiations > maxMpR")
        selector = ShardedSelector(self, window=window)
        data, _cnt = selector.select_with_cnt(tp, omega, insts)
        return data


def _window_slice(cand, cand_valid, start, end, pi, window: int):
    """Slice window ``pi`` of the shard-local range [start, end).

    The span ``[start + pi*window, min(start + (pi+1)*window, end))`` is
    what this page *owns*; the physical slice start is clamped into the
    array so ``dynamic_slice`` never clips, and ``in_span`` masks the
    slice back to the owned span -- spans are disjoint across pages and
    exactly tile the range, so no triple is reported twice and none is
    skipped.
    """
    shard_n = cand.shape[0]
    span_lo = start + pi.astype(start.dtype) * window
    slice_start = jnp.clip(span_lo, 0, max(shard_n - window, 0))
    win = jax.lax.dynamic_slice_in_dim(
        cand, slice_start.astype(jnp.int32), window, axis=0)
    win_valid = jax.lax.dynamic_slice_in_dim(
        cand_valid, slice_start.astype(jnp.int32), window, axis=0)
    pos = jnp.arange(window, dtype=jnp.int64) + slice_start
    in_span = (pos >= span_lo) & (pos < jnp.minimum(span_lo + window,
                                                    end))
    return win, win_valid, in_span


def _pow2(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


class ShardedSelector:
    """Mesh-sharded windowed selector with the KernelSelector contract.

    Serves the bindings-restricted selector from a
    :class:`FederatedStore` without ever materializing a candidate
    range: each launch streams one ``window`` per shard, G same-pattern
    requests share the launch (grouped geometry), and the host epilogue
    (:func:`~repro.core.kernel_selectors.stream_order` over the
    all-gathered kept rows + first-match indices) makes the returned
    data-triple sequence and Definition-2 ``cnt`` byte-identical to
    ``selectors.brtpf_select_with_cnt``.

    Why parity holds across shards: the store partitions the triples,
    so every triple is evaluated on exactly one shard, and page spans
    are disjoint within a shard -- each matching triple is kept exactly
    once, with the same first-matching-pattern stream id the single-host
    kernel computes; the epilogue's (stream, packed-key) sort is a total
    order, so concatenation order across shards/windows is irrelevant.
    ``cnt`` sums the per-row matching-pattern counts over all shards,
    which equals the oracle's sum of per-instantiation stream sizes.

    ``launches`` records one :class:`LaunchRecord` per window launch
    with ``cand_streamed = window`` -- the rows ONE device streams --
    so the accounting surface (and the budgets gated on it) is shared
    with the single-host kernel path.

    Omega-restricted pruning (docs/pruning.md): every request is
    launched from a host-side :class:`WindowPlan` -- the POS/OSP
    mirrors let the plan pick the order with the longest bound prefix
    (unbound-subject patterns stop scanning whole shards), and window
    pages disjoint from every per-binding sub-range are skipped
    outright. With ``store`` connected and ``fast_path_rows`` > 0,
    plans whose relevant row count falls below the threshold are served
    by the numpy block evaluation instead of launching windows.
    """

    def __init__(self, fed: FederatedStore,
                 window: int = DEFAULT_SHARD_WINDOW,
                 fragments: Optional[FragmentStore] = None,
                 store=None, fast_path_rows: int = 0) -> None:
        self.fed = fed
        self.window = max(1, min(int(window), fed.shard_n))
        self.fragments = fragments
        self.store = store
        self.fast_path_rows = int(fast_path_rows)
        self.launches: List[LaunchRecord] = []

    # -- public API (same contract as KernelSelector) ------------------------

    def select_with_cnt(
        self, tp: TriplePattern, omega: Optional[np.ndarray],
        insts: Optional[List[TriplePattern]] = None,
    ) -> Tuple[np.ndarray, int]:
        """Sharded ``brtpf_select_with_cnt`` (byte-identical)."""
        return self.select_same_pattern(
            tp, [omega], None if insts is None else [insts])[0]

    def select_same_pattern(
        self, tp: TriplePattern, omegas: Sequence[Optional[np.ndarray]],
        patterns: Optional[List[List[TriplePattern]]] = None,
    ) -> List[Tuple[np.ndarray, int]]:
        """Serve G same-pattern requests from one sharded launch per
        window page. Returns per-request (data sequence, cnt), each
        identical to ``brtpf_select_with_cnt(store, tp, omega_g)``.

        Groups resident in the connected fragment store never launch a
        window: their share is recorded as skipped (same contract as
        :class:`~repro.core.kernel_selectors.KernelSelector`)."""
        if patterns is None:
            patterns = [instantiate_patterns(tp, om) for om in omegas]
        results, live = consult_fragments(self.fragments, tp, omegas,
                                          self.launches)
        if live:
            live_omegas = [omegas[i] for i in live]
            fresh = self._launch_groups(tp, live_omegas,
                                        [patterns[i] for i in live])
            record_fragments(self.fragments, tp, live_omegas, fresh)
            for i, res in zip(live, fresh, strict=True):
                results[i] = res
        return results

    def _launch_groups(
        self, tp: TriplePattern, omegas: Sequence[Optional[np.ndarray]],
        patterns: List[List[TriplePattern]],
    ) -> List[Tuple[np.ndarray, int]]:
        """Windowed sharded launches over the store-miss groups."""
        g = len(omegas)
        m = max(len(p) for p in patterns)
        window = self.window
        all_insts = [p for group in patterns for p in group]
        plan = self.fed.plan_windows(tp, all_insts, window)
        empty = np.empty((0, 3), dtype=np.int32)
        if not plan.pages:
            # no window can contain a match on any shard (empty range,
            # or every sub-range empty): zero launches, cnt = 0
            return [(empty, 0)] * g

        # Small-work fast path: the plan's relevant rows cannot pay for
        # window dispatches -- evaluate the groups over the pruned block
        # gathered from the (host) oracle store instead.
        if (self.store is not None
                and 0 < plan.candidate_rows <= self.fast_path_rows):
            sr = self.store.subranges(tp, insts=all_insts)
            if sr is not None and sr.rows < len(
                    self.store.candidate_range(tp)):
                block = self.store.gather_subranges(sr)
            else:
                block = self.store.candidate_range(tp).triples
            self.launches.append(LaunchRecord(
                cand_streamed=int(block.shape[0]), pat_slots=0, groups=g,
                pruned=plan.pruned, cand_full=plan.range_rows,
                fast_path=True))
            return select_block_numpy(block, tp, patterns)

        # pad the grid to bucketed static shapes (bounded jit cache):
        # groups to a power of two, pattern slots to the kernel m-tile.
        gpad = _pow2(g)
        mp = kops.padded_pattern_slots(m)
        pats, valid, base_vec = marshal_pattern_grid(tp, patterns,
                                                     gpad, mp)
        comps = tp.as_tuple()
        wild = [i for i, c in enumerate(comps) if is_var(c)]
        wild_cols = tuple(wild) or (0,)  # dummy column when fully bound
        idx = self.fed.indexes[plan.order]
        fn = self.fed.lowerable_windowed_grouped(window, gpad,
                                                 wild_cols=wild_cols)

        kept: List[List[np.ndarray]] = [[] for _ in range(g)]
        firsts: List[List[np.ndarray]] = [[] for _ in range(g)]
        cnt_total = np.zeros((g,), dtype=np.int64)
        with enable_x64(True):
            lo_dev = jnp.asarray(plan.lo_key, jnp.int64)
            hi_dev = jnp.asarray(plan.hi_key, jnp.int64)
            pats_dev = jnp.asarray(pats)
            valid_dev = jnp.asarray(valid)
            bv_dev = jnp.asarray(base_vec)
            for page_idx in plan.pages:
                pages, first, counts, cnts, _range_len = fn(
                    idx.triples, idx.valid, idx.keys,
                    pats_dev, valid_dev, bv_dev, lo_dev, hi_dev,
                    jnp.asarray(page_idx, jnp.int32))
                pages = np.asarray(pages)
                first = np.asarray(first)
                counts = np.asarray(counts)
                cnt_total += np.asarray(cnts)[:, :g].sum(axis=0)
                self.launches.append(LaunchRecord(
                    cand_streamed=window, pat_slots=gpad * mp, groups=g,
                    pruned=plan.pruned, cand_full=window))
                for s in range(pages.shape[0]):
                    for gi in range(g):
                        n = int(counts[s, gi])
                        if n:
                            kept[gi].append(pages[s, gi, :n])
                            firsts[gi].append(first[s, gi, :n])

        out: List[Tuple[np.ndarray, int]] = []
        for gi in range(g):
            if not kept[gi]:
                out.append((empty, int(cnt_total[gi])))
                continue
            proj = np.concatenate(kept[gi], axis=0)
            first_g = np.concatenate(firsts[gi], axis=0)
            # reconstruct full triples from the request's bound
            # components (the wire carried only unbound columns)
            full = np.empty((proj.shape[0], 3), dtype=np.int32)
            for i, c in enumerate(comps):
                if is_var(c):
                    full[:, i] = proj[:, wild.index(i)]
                else:
                    full[:, i] = c
            out.append((stream_order(full, first_g, patterns[gi]),
                        int(cnt_total[gi])))
        return out
