"""Distributed brTPF: the triple store sharded over the mesh.

The paper (section 2.2) notes that TPF-style interfaces compose into
federations of servers. Here the federation *is* the mesh: the dataset is
partitioned across the ``data`` axis (one shard per device = one "brTPF
server"), a request -- (triple pattern, attached mappings) -- is broadcast
to every shard, each shard evaluates the bindings-restricted selector
locally with the Pallas ``bindjoin`` kernel, and the fixed-capacity local
pages are all-gathered back to the requesting client.

This is the paper's thesis expressed in mesh terms: the bindings (a few
KB) travel to the data, instead of the data (the full TPF fragment)
traveling to the client. The dry-run rooflines in EXPERIMENTS.md quantify
exactly this collective-byte saving.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import enable_x64, shard_map
from ..kernels import ops as kops
from .rdf import TriplePattern, is_var
from .selectors import instantiate_patterns


def _local_brtpf(cand: jnp.ndarray, patterns: jnp.ndarray,
                 pat_valid: jnp.ndarray, base_vec: jnp.ndarray,
                 cand_valid: jnp.ndarray, capacity: int):
    """Per-shard selector: Definition 1 on the local partition.

    ``base_vec`` carries the original pattern's repeated-variable equality
    flags (the instantiated-pattern grid alone cannot express them).
    Returns a fixed-shape local page (capacity, 3) padded with -1 + count.
    """
    keep, _ = kops.bindjoin(cand, patterns, pat_valid)
    keep &= kops.tpf_match(cand, base_vec)
    keep &= cand_valid
    idx, count = kops.compact_mask(keep, capacity)
    page = jnp.take(cand, jnp.maximum(idx, 0), axis=0)
    page = jnp.where((idx >= 0)[:, None], page, -1)
    return page, count


@dataclasses.dataclass
class FederatedStore:
    """Triple store sharded over one mesh axis (one shard = one server).

    Each shard keeps its partition SPO-sorted with packed int64 keys
    (every federation member is an HDT-style server), which enables the
    beyond-paper *windowed* request path: a bound-prefix pattern binary-
    searches the shard-local range and scans only a fixed window of it,
    instead of streaming the whole shard through the bind-join kernel.
    """

    mesh: Mesh
    axis: str
    triples: jax.Array       # int32 [shards * shard_n, 3], shard-padded
    valid: jax.Array         # bool  [shards * shard_n]
    keys: jax.Array          # int64 [shards * shard_n], per-shard sorted
    shard_n: int

    @classmethod
    def build(cls, triples_np: np.ndarray, mesh: Mesh,
              axis: str = "data") -> "FederatedStore":
        from .store import _pack
        shards = mesh.shape[axis]
        n = triples_np.shape[0]
        shard_n = max(1, -(-n // shards))
        total = shard_n * shards
        padded = np.full((total, 3), -1, dtype=np.int32)
        padded[:n] = triples_np
        valid = np.zeros((total,), dtype=bool)
        valid[:n] = True
        # per-shard SPO sort (padding rows key to +inf -> sort last).
        # int64 keys need the x64 context (off by default in jax)
        keys = np.where(
            valid,
            _pack(padded[:, 0], padded[:, 1], padded[:, 2]),
            np.iinfo(np.int64).max)
        for s in range(shards):
            sl = slice(s * shard_n, (s + 1) * shard_n)
            order = np.argsort(keys[sl], kind="stable")
            padded[sl] = padded[sl][order]
            valid[sl] = valid[sl][order]
            keys[sl] = keys[sl][order]
        sharding = NamedSharding(mesh, P(axis, None))
        vsharding = NamedSharding(mesh, P(axis))
        with enable_x64(True):
            keys_dev = jax.device_put(keys, vsharding)
        return cls(mesh=mesh, axis=axis,
                   triples=jax.device_put(padded, sharding),
                   valid=jax.device_put(valid, vsharding),
                   keys=keys_dev,
                   shard_n=shard_n)

    # -- the request path ----------------------------------------------------

    def request_arrays(self, tp: TriplePattern,
                       omega: Optional[np.ndarray],
                       max_mpr: int) -> Tuple[np.ndarray, np.ndarray,
                                              np.ndarray]:
        """Host-side request marshalling: instantiate + dedup (server
        algorithm steps 1-3) and pad to the interface's maxMpR."""
        insts = instantiate_patterns(tp, omega)
        if len(insts) > max_mpr:
            raise ValueError(f"{len(insts)} instantiations > maxMpR")
        pats = np.full((max_mpr, 3), -1, dtype=np.int32)
        valid = np.zeros((max_mpr,), dtype=np.int32)
        for i, p in enumerate(insts):
            pats[i] = [c if not is_var(c) else -1 for c in p.as_tuple()]
            valid[i] = 1
        comps = tp.as_tuple()
        base_vec = kops.pattern_vec_from(
            tuple(-1 if is_var(c) else c for c in comps),
            eq_sp=int(is_var(comps[0]) and comps[0] == comps[1]),
            eq_so=int(is_var(comps[0]) and comps[0] == comps[2]),
            eq_po=int(is_var(comps[1]) and comps[1] == comps[2]),
        )
        return pats, valid, base_vec

    def execute(self, tp: TriplePattern, omega: Optional[np.ndarray],
                max_mpr: int, capacity: int) -> np.ndarray:
        """Run one distributed brTPF request; returns matching triples."""
        pats, valid, base_vec = self.request_arrays(tp, omega, max_mpr)
        pages, counts = self.lowerable(capacity)(
            self.triples, self.valid, jnp.asarray(pats),
            jnp.asarray(valid), jnp.asarray(base_vec))
        pages = np.asarray(pages).reshape(-1, 3)
        keep = pages[:, 0] >= 0  # -1-padded rows are invalid
        return pages[keep]

    def lowerable(self, capacity: int):
        """The jitted distributed request step (also used by the dry-run:
        ``.lower(...).compile()`` proves the collective schedule)."""
        mesh, axis, shard_n = self.mesh, self.axis, self.shard_n

        def step(triples, valid, pats, pat_valid, base_vec):
            def shard_fn(cand, cand_valid, p, pv, bv):
                page, count = _local_brtpf(
                    cand, p, pv, bv, cand_valid, capacity)
                # Return per-shard pages; the all-gather back to the
                # client is the response wire transfer.
                page = jax.lax.all_gather(page, axis)
                count = jax.lax.all_gather(count, axis)
                return page, count

            fn = shard_map(
                shard_fn, mesh=mesh,
                in_specs=(P(axis, None), P(axis), P(), P(), P()),
                out_specs=(P(), P()),
                # pallas_call emits ShapeDtypeStructs without vma metadata
                check_vma=False,
            )
            return fn(triples, valid, pats, pat_valid, base_vec)

        return jax.jit(step)

    # -- beyond-paper optimized request path ----------------------------------

    def lowerable_windowed(self, capacity: int, window: int,
                           wild_cols: tuple = (0, 1, 2)):
        """Optimized request step (see EXPERIMENTS.md §Perf(D)):

        1. *windowed scan*: each shard binary-searches its sorted keys
           for the pattern's bound-prefix range and runs the bind-join
           kernel over a fixed ``window`` starting there, not the whole
           shard -- compute/memory per request drops shard_n/window x
           for selective patterns;
        2. *column projection*: only the pattern's unbound components
           (``wild_cols``) are all-gathered back -- the bound
           components are implied by the request, cutting response
           bytes by (3 - len(wild_cols))/3.

        Inputs add (lo_key, hi_key) int64 scalars (host-computed from
        the pattern prefix, identical on every shard).
        """
        mesh, axis = self.mesh, self.axis

        def step(triples, valid, keys, pats, pat_valid, base_vec,
                 lo_key, hi_key, page_idx):
            def shard_fn(cand, cand_valid, k, p, pv, bv, lo, hi, pi):
                start = jnp.searchsorted(k, lo, side="left")
                end = jnp.searchsorted(k, hi, side="right")
                range_len = end - start                 # page metadata
                start = start + pi.astype(start.dtype) * window
                start = jnp.minimum(start,
                                    jnp.asarray(max(k.shape[0] - window,
                                                    0), start.dtype))
                win = jax.lax.dynamic_slice_in_dim(
                    cand, start.astype(jnp.int32), window, axis=0)
                win_valid = jax.lax.dynamic_slice_in_dim(
                    cand_valid, start.astype(jnp.int32), window, axis=0)
                idx_in_range = (jnp.arange(window, dtype=start.dtype)
                                + start) < end
                page, count = _local_brtpf(
                    win, p, pv, bv, win_valid & idx_in_range, capacity)
                page = page[:, list(wild_cols)]
                page = jax.lax.all_gather(page, axis)
                count = jax.lax.all_gather(count, axis)
                range_len = jax.lax.all_gather(range_len, axis)
                return page, count, range_len

            fn = shard_map(
                shard_fn, mesh=mesh,
                in_specs=(P(axis, None), P(axis), P(axis), P(), P(),
                          P(), P(), P(), P()),
                out_specs=(P(), P(), P()),
                check_vma=False,
            )
            return fn(triples, valid, keys, pats, pat_valid, base_vec,
                      lo_key, hi_key, page_idx)

        return jax.jit(step)

    def execute_windowed(self, tp: TriplePattern,
                         omega: Optional[np.ndarray], max_mpr: int,
                         capacity: int, window: int) -> np.ndarray:
        """Run the optimized path end-to-end: window paging until every
        shard\'s bound-prefix range is covered (the first response carries
        each shard\'s range length -- the cnt metadata of Definition 2),
        with client-side reconstruction of projected columns."""
        from .store import _pack, _MAX_ID
        pats, valid, base_vec = self.request_arrays(tp, omega, max_mpr)
        comps = tp.as_tuple()
        # bound-prefix range in SPO order (host side, like the client
        # computing a page URL)
        prefix = []
        for c in comps:
            if is_var(c):
                break
            prefix.append(c)
        lo_vals = prefix + [0] * (3 - len(prefix))
        hi_vals = prefix + [_MAX_ID] * (3 - len(prefix))
        lo = int(_pack(np.int64(lo_vals[0]), np.int64(lo_vals[1]),
                       np.int64(lo_vals[2])))
        hi = int(_pack(np.int64(hi_vals[0]), np.int64(hi_vals[1]),
                       np.int64(hi_vals[2])))
        wild = [i for i, c in enumerate(comps) if is_var(c)]
        fn = self.lowerable_windowed(capacity, window,
                                     wild_cols=tuple(wild) or (0,))
        all_pages = []
        with enable_x64(True):
            page_idx = 0
            while True:
                pages, counts, range_len = fn(
                    self.triples, self.valid, self.keys,
                    jnp.asarray(pats), jnp.asarray(valid),
                    jnp.asarray(base_vec),
                    jnp.asarray(lo, jnp.int64),
                    jnp.asarray(hi, jnp.int64),
                    jnp.asarray(page_idx, jnp.int32))
                all_pages.append(np.asarray(pages))
                max_range = int(np.asarray(range_len).max())
                page_idx += 1
                if page_idx * window >= max_range:
                    break
        pages = np.concatenate(all_pages).reshape(-1, max(len(wild), 1))
        keep = pages[:, 0] >= 0
        pages = pages[keep]
        # reconstruct full triples from the request's bound components
        out = np.empty((pages.shape[0], 3), np.int32)
        wi = 0
        for i, c in enumerate(comps):
            if is_var(c):
                out[:, i] = pages[:, wild.index(i)]
            else:
                out[:, i] = c
        return np.unique(out, axis=0) if out.shape[0] else out
