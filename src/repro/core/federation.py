"""Distributed brTPF: the triple store sharded over the mesh.

The paper (section 2.2) notes that TPF-style interfaces compose into
federations of servers. Here the federation *is* the mesh: the dataset is
partitioned across the ``data`` axis (one shard per device = one "brTPF
server"), a request -- (triple pattern, attached mappings) -- is broadcast
to every shard, each shard evaluates the bindings-restricted selector
locally with the Pallas ``bindjoin`` kernel, and the fixed-capacity local
pages are all-gathered back to the requesting client.

This is the paper's thesis expressed in mesh terms: the bindings (a few
KB) travel to the data, instead of the data (the full TPF fragment)
traveling to the client. The dry-run rooflines in EXPERIMENTS.md quantify
exactly this collective-byte saving.

Since PR 3 the *windowed* request step is the default: each shard
binary-searches its sorted keys for the pattern's bound-prefix range and
streams only a fixed ``window`` of it per launch, so per-request device
work scales with the window -- never with the range or the shard size.
:class:`ShardedSelector` packages this as a first-class selector backend
for :class:`~repro.core.server.BrTPFServer` (``selector_backend=
"sharded"``), byte-identical to ``selectors.brtpf_select_with_cnt`` and
sharing the grouped multi-request geometry (G same-pattern requests =
one sharded launch) and :class:`~repro.core.kernel_selectors.LaunchRecord`
accounting surface with the single-host kernel path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import enable_x64, shard_map
from ..kernels import ops as kops
from .fragments import FragmentStore, fragment_key
from .placement import HeatLog, Placement
from .kernel_selectors import (_EMPTY, FUSED_BT, FusedSegment,
                               LaunchRecord, _fused_base_mask,
                               consult_fragments, consult_segment,
                               finish_segment, fusion_legality,
                               marshal_pattern_grid, record_fragments,
                               select_block_numpy, stream_order)
from .rdf import TriplePattern, is_var
from .selectors import instantiate_patterns

# Default per-shard window: one launch streams this many candidate rows
# per device. 8 * 128 VPU sublane*lane tiles; small enough that a page-0
# probe of a selective pattern costs a fraction of a shard pass, large
# enough that WatDiv-scale ranges need a handful of windows.
DEFAULT_SHARD_WINDOW = 1024


def _local_brtpf(cand: jnp.ndarray, patterns: jnp.ndarray,
                 pat_valid: jnp.ndarray, base_vec: jnp.ndarray,
                 cand_valid: jnp.ndarray, capacity: int):
    """Per-shard selector: Definition 1 on the local partition.

    ``base_vec`` carries the original pattern's repeated-variable equality
    flags (the instantiated-pattern grid alone cannot express them).
    Returns a fixed-shape local page (capacity, 3) padded with -1 + count.
    """
    keep, _ = kops.bindjoin(cand, patterns, pat_valid)
    keep &= kops.tpf_match(cand, base_vec)
    keep &= cand_valid
    idx, count = kops.compact_mask(keep, capacity)
    page = jnp.take(cand, jnp.maximum(idx, 0), axis=0)
    page = jnp.where((idx >= 0)[:, None], page, -1)
    return page, count


@dataclasses.dataclass
class ShardIndex:
    """One component order's per-shard sorted mirror of the partition.

    ``host_keys`` keeps a host-side copy of the per-shard sorted keys:
    the request planner (:meth:`FederatedStore.plan_windows`) uses it to
    binary-search shard-local ranges and Omega sub-ranges *before*
    launching, so windows provably disjoint from every sub-range are
    never dispatched. (The device step re-derives the same bounds with
    an on-device searchsorted -- the host copy only steers which pages
    launch, it never feeds result data.)
    """

    name: str                # "spo" | "pos" | "osp"
    triples: jax.Array       # int32 [shards * shard_n, 3], per-shard sorted
    valid: jax.Array         # bool  [shards * shard_n]
    keys: jax.Array          # int64 [shards * shard_n]
    host_keys: np.ndarray    # int64 [shards, shard_n] (same values)


@dataclasses.dataclass
class WindowPlan:
    """Host-side launch plan for one (grouped) windowed request.

    ``pages`` lists the window indexes that can contain join-relevant
    rows on at least one shard; everything else is skipped. Unpruned
    plans list every page of the pattern's bound-prefix range under
    ``order``; pruned plans keep only pages intersecting some
    per-binding sub-range. ``candidate_rows`` is the total (cross-shard)
    row count inside the relevant sub-ranges -- the small-work fast
    path's decision quantity.
    """

    order: str
    lo_key: int
    hi_key: int
    pages: List[int]
    range_rows: int          # sum over shards of the base range length
    candidate_rows: int      # rows inside relevant sub-ranges (<= above)
    pruned: bool
    pages_total: int         # pages an unpruned plan would launch
    # Per shard the base range bounds [start, end) -- absolute
    # shard-local positions. Set on every plan (per-shard attribution
    # and replica routing need it); ``shard_spans`` additionally carries
    # the merged live sub-range spans that sub-window compaction needs,
    # and stays None when unpruned.
    shard_bounds: Optional[List[Tuple[int, int]]] = None
    shard_spans: Optional[List[np.ndarray]] = None


@dataclasses.dataclass
class FederatedStore:
    """Triple store sharded over one mesh axis (one shard = one server).

    Each shard keeps its partition sorted with packed int64 keys in all
    three component orders -- SPO plus the POS/OSP mirrors (every
    federation member is an HDT-style server with HDT's three indexes).
    The mirrors are what let unbound-subject patterns (``(?s, p, ?o)``,
    ``(?s, ?p, o)``) binary-search a narrow shard-local range instead of
    scanning the whole shard, and the *windowed* request path (the
    default since PR 3) streams only a fixed window of the chosen
    order's range per launch.
    """

    mesh: Mesh
    axis: str
    triples: jax.Array       # SPO mirror (compat alias of indexes["spo"])
    valid: jax.Array
    keys: jax.Array
    shard_n: int
    indexes: Dict[str, ShardIndex] = dataclasses.field(
        default_factory=dict, repr=False)
    # jit-cache for the windowed request steps, keyed on the static
    # launch geometry (window, groups, pattern slots, projection).
    _steps: Dict[tuple, object] = dataclasses.field(
        default_factory=dict, repr=False)
    # Workload-aware placement (docs/federation.md, "Placement"): when
    # set, shard boundaries follow the heat-weighted quantiles instead
    # of the equal split, and ``placement.replicas`` ranges are held by
    # several shards (the routed launch path dedups them to one owner).
    placement: Optional[Placement] = None
    # Host copy of the unsharded dataset, kept so ``repartition`` can
    # rebuild under new boundaries without a device gather.
    host_triples: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False)

    @property
    def shards(self) -> int:
        return self.mesh.shape[self.axis]

    @classmethod
    def build(cls, triples_np: np.ndarray, mesh: Mesh,
              axis: str = "data",
              placement: Optional[Placement] = None) -> "FederatedStore":
        from .store import _ORDERS, _pack
        shards = mesh.shape[axis]
        n = triples_np.shape[0]
        if placement is not None:
            return cls._build_placed(triples_np, mesh, axis, placement)
        shard_n = max(1, -(-n // shards))
        total = shard_n * shards
        base = np.full((total, 3), -1, dtype=np.int32)
        base[:n] = triples_np
        base_valid = np.zeros((total,), dtype=bool)
        base_valid[:n] = True
        sharding = NamedSharding(mesh, P(axis, None))
        vsharding = NamedSharding(mesh, P(axis))
        indexes: Dict[str, ShardIndex] = {}
        for name, comp_order in _ORDERS.items():
            padded = base.copy()
            valid = base_valid.copy()
            # per-shard sort under this order's packed key (padding rows
            # key to +inf -> sort last). int64 keys need the x64 context
            # (off by default in jax).
            keys = np.where(
                valid,
                _pack(padded[:, comp_order[0]], padded[:, comp_order[1]],
                      padded[:, comp_order[2]]),
                np.iinfo(np.int64).max)
            for s in range(shards):
                sl = slice(s * shard_n, (s + 1) * shard_n)
                order = np.argsort(keys[sl], kind="stable")
                padded[sl] = padded[sl][order]
                valid[sl] = valid[sl][order]
                keys[sl] = keys[sl][order]
            with enable_x64(True):
                keys_dev = jax.device_put(keys, vsharding)
            indexes[name] = ShardIndex(
                name=name,
                triples=jax.device_put(padded, sharding),
                valid=jax.device_put(valid, vsharding),
                keys=keys_dev,
                host_keys=keys.reshape(shards, shard_n))
        spo = indexes["spo"]
        return cls(mesh=mesh, axis=axis,
                   triples=spo.triples, valid=spo.valid, keys=spo.keys,
                   shard_n=shard_n, indexes=indexes,
                   host_triples=np.asarray(triples_np))

    @classmethod
    def _build_placed(cls, triples_np: np.ndarray, mesh: Mesh,
                      axis: str, placement: Placement) -> "FederatedStore":
        """Build under workload-aware boundaries + replicated ranges.

        Per order, each triple's packed key is assigned to the shard
        whose boundary span owns it (``Placement.shard_of``; orders
        without boundaries fall back to an equal-count contiguous
        split), then every :class:`~repro.core.placement.ReplicaRange`'s
        rows are *additionally* copied onto its replica shards.  Each
        shard's partition stays a contiguous key range plus whole
        replicated sub-ranges, sorted -- which is what lets the routed
        launch path subtract a replica range from non-owners by a pair
        of binary searches.
        """
        from .store import _ORDERS, _pack
        shards = mesh.shape[axis]
        per_order_rows: Dict[str, List[np.ndarray]] = {}
        for name, comp_order in _ORDERS.items():
            keys = _pack(triples_np[:, comp_order[0]],
                         triples_np[:, comp_order[1]],
                         triples_np[:, comp_order[2]])
            bounds = placement.boundaries.get(name)
            if bounds is not None and len(bounds) == shards - 1:
                assign = np.searchsorted(
                    np.asarray(bounds, dtype=np.int64), keys, side="right")
            else:
                # equal-count contiguous fallback over this order's
                # sorted keys (still a contiguous key partition)
                order = np.argsort(keys, kind="stable")
                assign = np.empty(keys.shape, dtype=np.int64)
                cutpos = np.arange(1, shards) * keys.size // shards
                assign[order] = np.searchsorted(
                    cutpos, np.arange(keys.size), side="right")
            rows = [triples_np[assign == s] for s in range(shards)]
            for rr in placement.replicas.get(name, ()):
                sel = (keys >= rr.lo_key) & (keys <= rr.hi_key)
                block = triples_np[sel]
                if block.shape[0] == 0:
                    continue
                for rs in rr.replicas:
                    if rs != rr.home:
                        rows[rs] = np.concatenate([rows[rs], block],
                                                  axis=0)
            per_order_rows[name] = rows
        shard_n = max(1, max(r.shape[0] for rows in per_order_rows.values()
                             for r in rows))
        total = shard_n * shards
        sharding = NamedSharding(mesh, P(axis, None))
        vsharding = NamedSharding(mesh, P(axis))
        indexes: Dict[str, ShardIndex] = {}
        for name, comp_order in _ORDERS.items():
            padded = np.full((total, 3), -1, dtype=np.int32)
            valid = np.zeros((total,), dtype=bool)
            keys = np.full((total,), np.iinfo(np.int64).max,
                           dtype=np.int64)
            for s, block in enumerate(per_order_rows[name]):
                m = block.shape[0]
                k = _pack(block[:, comp_order[0]], block[:, comp_order[1]],
                          block[:, comp_order[2]])
                order = np.argsort(k, kind="stable")
                sl = slice(s * shard_n, s * shard_n + m)
                padded[sl] = block[order]
                valid[sl] = True
                keys[sl] = k[order]
            with enable_x64(True):
                keys_dev = jax.device_put(keys, vsharding)
            indexes[name] = ShardIndex(
                name=name,
                triples=jax.device_put(padded, sharding),
                valid=jax.device_put(valid, vsharding),
                keys=keys_dev,
                host_keys=keys.reshape(shards, shard_n))
        spo = indexes["spo"]
        return cls(mesh=mesh, axis=axis,
                   triples=spo.triples, valid=spo.valid, keys=spo.keys,
                   shard_n=shard_n, indexes=indexes,
                   placement=placement,
                   host_triples=np.asarray(triples_np))

    def repartition(self, heat: HeatLog, **plan_kwargs) -> "FederatedStore":
        """Rebuild with workload-aware boundaries planned from ``heat``.

        Returns a NEW store (rebuild-with-cutover: the caller swaps it in
        atomically and must invalidate any :class:`FragmentStore` pages
        planned against the old partitioning -- repro-lint CC003 enforces
        that every ``.federated`` swap site reaches an invalidation).
        """
        from .placement import dataset_keys, plan_placement
        if self.host_triples is None:
            raise ValueError(
                "host triples unavailable; the store was not built via "
                "FederatedStore.build")
        placement = plan_placement(
            heat, dataset_keys(self.host_triples), self.shards,
            **plan_kwargs)
        return FederatedStore.build(self.host_triples, self.mesh,
                                    axis=self.axis, placement=placement)

    # -- host-side request marshalling ---------------------------------------

    def request_arrays(self, tp: TriplePattern,
                       omega: Optional[np.ndarray],
                       max_mpr: int) -> Tuple[np.ndarray, np.ndarray,
                                              np.ndarray]:
        """Host-side request marshalling: instantiate + dedup (server
        algorithm steps 1-3) and pad to the interface's maxMpR."""
        insts = instantiate_patterns(tp, omega)
        if len(insts) > max_mpr:
            raise ValueError(f"{len(insts)} instantiations > maxMpR")
        pats = np.full((max_mpr, 3), -1, dtype=np.int32)
        valid = np.zeros((max_mpr,), dtype=np.int32)
        for i, p in enumerate(insts):
            pats[i] = [c if not is_var(c) else -1 for c in p.as_tuple()]
            valid[i] = 1
        comps = tp.as_tuple()
        base_vec = kops.pattern_vec_from(
            tuple(-1 if is_var(c) else c for c in comps),
            eq_sp=int(is_var(comps[0]) and comps[0] == comps[1]),
            eq_so=int(is_var(comps[0]) and comps[0] == comps[2]),
            eq_po=int(is_var(comps[1]) and comps[1] == comps[2]),
        )
        return pats, valid, base_vec

    @staticmethod
    def prefix_keys(tp: TriplePattern,
                    order_name: str = "spo") -> Tuple[int, int]:
        """(lo_key, hi_key) of the pattern's bound prefix under the
        given index order -- the host-computed range bounds every shard
        binary-searches (the client computing a page URL, in mesh
        terms). Defaults to the SPO mirror for compatibility with the
        single-request windowed path."""
        from .store import _MAX_ID, _ORDERS, _pack
        comp_order = _ORDERS[order_name]
        comps = tp.as_tuple()
        prefix = []
        for pos in comp_order:
            if is_var(comps[pos]):
                break
            prefix.append(comps[pos])
        lo_vals = prefix + [0] * (3 - len(prefix))
        hi_vals = prefix + [_MAX_ID] * (3 - len(prefix))
        lo = int(_pack(np.int64(lo_vals[0]), np.int64(lo_vals[1]),
                       np.int64(lo_vals[2])))
        hi = int(_pack(np.int64(hi_vals[0]), np.int64(hi_vals[1]),
                       np.int64(hi_vals[2])))
        return lo, hi

    # -- host-side launch planning (Omega-restricted window skip) ------------

    def plan_windows(self, tp: TriplePattern,
                     insts: Sequence[TriplePattern],
                     window: int) -> WindowPlan:
        """Plan the window launches for one (grouped) request.

        Index choice: when every instantiated pattern shares one shape
        whose best index binds a longer prefix than the base pattern
        does under that index, the launch streams THAT order and the
        per-binding sub-ranges become host-computable window filters;
        otherwise the base pattern's own best index is used (the
        POS/OSP mirrors are what make this a real choice -- an
        unbound-subject pattern no longer scans whole shards).

        Window skip: the per-binding ``(lo, hi)`` key intervals are
        batch-searchsorted against every shard's host key copy; a window
        page whose owned span intersects no sub-range on any shard is
        provably match-free (every triple matching instantiation ``p_j``
        has its key inside ``p_j``'s interval) and is dropped from
        ``pages``. Skipping whole pages never reorders or duplicates
        anything, so parity is untouched.
        """
        from .store import (TripleStore, _ORDERS, merge_spans,
                            prefix_interval_keys)
        window = max(1, min(int(window), self.shard_n))

        def base_plan(order_name: str) -> WindowPlan:
            lo, hi = self.prefix_keys(tp, order_name)
            hk = self.indexes[order_name].host_keys
            starts = np.array([np.searchsorted(hk[s], lo, side="left")
                               for s in range(hk.shape[0])])
            ends = np.array([np.searchsorted(hk[s], hi, side="right")
                             for s in range(hk.shape[0])])
            range_rows = int((ends - starts).sum())
            pages_total = int(max(
                (-(-int(e - s) // window)
                 for s, e in zip(starts, ends, strict=True)), default=0))
            return WindowPlan(order=order_name, lo_key=lo, hi_key=hi,
                              pages=list(range(pages_total)),
                              range_rows=range_rows,
                              candidate_rows=range_rows, pruned=False,
                              pages_total=pages_total,
                              shard_bounds=[
                                  (int(s), int(e)) for s, e in
                                  zip(starts, ends, strict=True)])

        bname, _ = TripleStore._choose_index(tp)
        unpruned = base_plan(bname)
        shapes = {tuple(is_var(c) for c in p.as_tuple()) for p in insts}
        if len(shapes) != 1 or not insts:
            return unpruned
        iname, iplen = TripleStore._choose_index(insts[0])
        # prefix the BASE pattern binds under the instantiations' best
        # index: pruning pays only if instantiations bind more
        comp_order = _ORDERS[iname]
        base_plen = 0
        for pos in comp_order:
            if is_var(tp.as_tuple()[pos]):
                break
            base_plen += 1
        if iplen <= base_plen:
            return unpruned
        comps = np.asarray([p.as_tuple() for p in insts], dtype=np.int64)
        lo_keys, hi_keys = prefix_interval_keys(comps, comp_order, iplen)
        # base range under the insts' index (already computed when the
        # instantiations' best order is the base pattern's own)
        shell = unpruned if iname == bname else base_plan(iname)
        hk = self.indexes[iname].host_keys
        pages: set = set()
        candidate_rows = 0
        shard_bounds: List[Tuple[int, int]] = []
        shard_spans: List[np.ndarray] = []
        for s in range(hk.shape[0]):
            start = int(np.searchsorted(hk[s], shell.lo_key,
                                        side="left"))
            end = int(np.searchsorted(hk[s], shell.hi_key,
                                      side="right"))
            shard_bounds.append((start, end))
            if end <= start:
                shard_spans.append(np.empty((0, 2), dtype=np.int64))
                continue
            a = np.searchsorted(hk[s], lo_keys, side="left")
            b = np.searchsorted(hk[s], hi_keys, side="right")
            spans = merge_spans(np.stack([a, b], axis=1))
            clipped: List[Tuple[int, int]] = []
            for slo, shi in spans:
                # instantiation intervals are sub-intervals of the base
                # range under the same order, but clip defensively
                slo = max(int(slo), start)
                shi = min(int(shi), end)
                if shi <= slo:
                    continue
                candidate_rows += shi - slo
                clipped.append((slo, shi))
                pages.update(range((slo - start) // window,
                                   (shi - 1 - start) // window + 1))
            shard_spans.append(
                np.asarray(clipped, dtype=np.int64).reshape(-1, 2))
        pruned = WindowPlan(order=iname, lo_key=shell.lo_key,
                            hi_key=shell.hi_key, pages=sorted(pages),
                            range_rows=shell.range_rows,
                            candidate_rows=candidate_rows, pruned=True,
                            pages_total=shell.pages_total,
                            shard_bounds=shard_bounds,
                            shard_spans=shard_spans)
        # the base pattern's own index may beat sub-range skipping under
        # the instantiations' index (fewer actual window dispatches win)
        return pruned if len(pruned.pages) <= len(unpruned.pages) \
            else unpruned

    # -- the request path ----------------------------------------------------

    def execute(self, tp: TriplePattern, omega: Optional[np.ndarray],
                max_mpr: int, capacity: int) -> np.ndarray:
        """Run one distributed brTPF request; returns matching triples.

        Routed through the windowed step (the default request path):
        per-shard device work is bounded by the window, and -- unlike
        :meth:`execute_full` -- the result can never be truncated by an
        undersized ``capacity`` (each window's page capacity is the
        window itself).
        """
        return self.execute_windowed(tp, omega, max_mpr, capacity,
                                     window=min(capacity, self.shard_n))

    def execute_full(self, tp: TriplePattern, omega: Optional[np.ndarray],
                     max_mpr: int, capacity: int) -> np.ndarray:
        """The paper-faithful baseline: every shard streams its whole
        partition through the bind-join kernel in one launch. Kept for
        the dry-run roofline comparison; ``capacity`` bounds the local
        page (matches beyond it are silently dropped)."""
        if self.placement is not None and self.placement.has_replicas:
            raise RuntimeError(
                "execute_full cannot serve a replicated placement: the "
                "full-shard stream would report replicated ranges once "
                "per holder -- use the windowed (routed) path")
        pats, valid, base_vec = self.request_arrays(tp, omega, max_mpr)
        pages, counts = self.lowerable(capacity)(
            self.triples, self.valid, jnp.asarray(pats),
            jnp.asarray(valid), jnp.asarray(base_vec))
        pages = np.asarray(pages).reshape(-1, 3)
        keep = pages[:, 0] >= 0  # -1-padded rows are invalid
        return pages[keep]

    def lowerable(self, capacity: int):
        """The jitted full-shard-stream request step (also used by the
        dry-run: ``.lower(...).compile()`` proves the collective
        schedule of the baseline variant)."""
        mesh, axis = self.mesh, self.axis

        def step(triples, valid, pats, pat_valid, base_vec):
            def shard_fn(cand, cand_valid, p, pv, bv):
                page, count = _local_brtpf(
                    cand, p, pv, bv, cand_valid, capacity)
                # Return per-shard pages; the all-gather back to the
                # client is the response wire transfer.
                page = jax.lax.all_gather(page, axis)
                count = jax.lax.all_gather(count, axis)
                return page, count

            fn = shard_map(
                shard_fn, mesh=mesh,
                in_specs=(P(axis, None), P(axis), P(), P(), P()),
                out_specs=(P(), P()),
                # pallas_call emits ShapeDtypeStructs without vma metadata
                check_vma=False,
            )
            return fn(triples, valid, pats, pat_valid, base_vec)

        return jax.jit(step)

    # -- the windowed request path (default) ---------------------------------

    def lowerable_windowed(self, capacity: int, window: int,
                           wild_cols: tuple = (0, 1, 2)):
        """Single-request windowed step (see EXPERIMENTS.md §Perf(D)):

        1. *windowed scan*: each shard binary-searches its sorted keys
           for the pattern's bound-prefix range and runs the bind-join
           kernel over a fixed ``window`` starting there, not the whole
           shard -- compute/memory per request drops shard_n/window x
           for selective patterns;
        2. *column projection*: only the pattern's unbound components
           (``wild_cols``) are all-gathered back -- the bound
           components are implied by the request, cutting response
           bytes by (3 - len(wild_cols))/3.

        Inputs add (lo_key, hi_key) int64 scalars (host-computed from
        the pattern prefix, identical on every shard). Page windows are
        *disjoint* spans of the range (a span near the shard edge is
        masked, not shifted), so paging never double-reports a triple.
        """
        mesh, axis = self.mesh, self.axis
        window = max(1, min(window, self.shard_n))

        def step(triples, valid, keys, pats, pat_valid, base_vec,
                 lo_key, hi_key, page_idx):
            def shard_fn(cand, cand_valid, k, p, pv, bv, lo, hi, pi):
                start = jnp.searchsorted(k, lo, side="left")
                end = jnp.searchsorted(k, hi, side="right")
                range_len = end - start                 # page metadata
                win, win_valid, in_span = _window_slice(
                    cand, cand_valid, start, end, pi, window)
                page, count = _local_brtpf(
                    win, p, pv, bv, win_valid & in_span, capacity)
                page = page[:, list(wild_cols)]
                page = jax.lax.all_gather(page, axis)
                count = jax.lax.all_gather(count, axis)
                range_len = jax.lax.all_gather(range_len, axis)
                return page, count, range_len

            fn = shard_map(
                shard_fn, mesh=mesh,
                in_specs=(P(axis, None), P(axis), P(axis), P(), P(),
                          P(), P(), P(), P()),
                out_specs=(P(), P(), P()),
                check_vma=False,
            )
            return fn(triples, valid, keys, pats, pat_valid, base_vec,
                      lo_key, hi_key, page_idx)

        return jax.jit(step)

    def lowerable_windowed_grouped(self, window: int, groups: int,
                                   wild_cols: tuple = (0, 1, 2)):
        """Grouped windowed step: G same-pattern requests, one launch.

        The sharded twin of ``kops.bindjoin_grouped``'s geometry: every
        shard streams ONE window of its bound-prefix range and evaluates
        all G requests' instantiated-pattern sets against it, so
        coalesced batches (``BrTPFServer.handle_batch`` /
        ``AsyncBrTPFServer``) cost one sharded launch per window instead
        of G. Per (shard, group) the step emits a fixed-shape page of
        compacted kept rows (capacity = window, so a window's matches
        always fit), the first-matching-pattern index per kept row (the
        stream id the ordering epilogue needs), the kept-row count, and
        the group's Definition-2 ``cnt`` contribution (sum of per-row
        matching-pattern counts); plus the shard's range length for
        paging. Jitted steps are cached per static geometry on the
        store (``_steps``).

        Returns arrays shaped (shards, G, window[, C]) / (shards, G) /
        (shards,) after the all-gather.
        """
        # clamp before building the cache key, so raw windows that
        # clamp to the same effective value share one traced step
        window = max(1, min(window, self.shard_n))
        key = ("grouped", window, groups, wild_cols)
        fn = self._steps.get(key)
        if fn is not None:
            return fn
        mesh, axis = self.mesh, self.axis

        def step(triples, valid, keys, pats, pat_valid, base_vec,
                 lo_key, hi_key, page_idx):
            def shard_fn(cand, cand_valid, k, p, pv, bv, lo, hi, pi):
                start = jnp.searchsorted(k, lo, side="left")
                end = jnp.searchsorted(k, hi, side="right")
                range_len = end - start
                win, win_valid, in_span = _window_slice(
                    cand, cand_valid, start, end, pi, window)
                keep, idx, nmatch = kops.bindjoin_grouped(win, p, pv)
                base = kops.tpf_match(win, bv)
                mask = (keep & base[:, None]
                        & (win_valid & in_span)[:, None])        # (W, G)
                cnts = jnp.sum(jnp.where(mask, nmatch, 0), axis=0)
                rows, counts = jax.vmap(
                    lambda m: kops.compact_mask(m, window),
                    in_axes=1, out_axes=0)(mask)          # (G, W), (G,)
                safe = jnp.maximum(rows, 0)
                page = jnp.take(win, safe, axis=0)        # (G, W, 3)
                first = jax.vmap(lambda r, col: col[r],
                                 in_axes=(0, 1))(safe, idx)   # (G, W)
                page = page[:, :, list(wild_cols)]
                page = jnp.where((rows >= 0)[:, :, None], page, -1)
                first = jnp.where(rows >= 0, first, -1)
                page = jax.lax.all_gather(page, axis)
                first = jax.lax.all_gather(first, axis)
                counts = jax.lax.all_gather(counts, axis)
                cnts = jax.lax.all_gather(cnts, axis)
                range_len = jax.lax.all_gather(range_len, axis)
                return page, first, counts, cnts, range_len

            fn = shard_map(
                shard_fn, mesh=mesh,
                in_specs=(P(axis, None), P(axis), P(axis), P(), P(),
                          P(), P(), P(), P()),
                out_specs=(P(), P(), P(), P(), P()),
                check_vma=False,
            )
            return fn(triples, valid, keys, pats, pat_valid, base_vec,
                      lo_key, hi_key, page_idx)

        fn = jax.jit(step)
        self._steps[key] = fn
        return fn

    def lowerable_windowed_routed(self, window: int, groups: int,
                                  wild_cols: tuple = (0, 1, 2)):
        """Routed grouped step (docs/federation.md, "Placement").

        Same grouped geometry as :meth:`lowerable_windowed_grouped`, but
        the shard-local span to stream arrives host-computed as explicit
        ``(span_lo, span_hi)`` int32 [shards] position vectors instead of
        being re-derived from ``(lo_key, hi_key)`` on device.  The host
        planner needs that control under a workload-aware placement: it
        has already chosen each replicated range's least-loaded owner and
        subtracted the range from every other holder's span, so a
        replicated triple is streamed by exactly one shard per request
        (dedup at merge) and per-shard spans can differ in length.  A
        shard with no work this round sends ``(0, 0)``.  Each round
        streams at most ``window`` rows per shard (the planner chops
        longer spans into window-sized chunks).
        """
        window = max(1, min(window, self.shard_n))
        key = ("routed", window, groups, wild_cols)
        fn = self._steps.get(key)
        if fn is not None:
            return fn
        mesh, axis = self.mesh, self.axis

        def step(triples, valid, pats, pat_valid, base_vec,
                 span_lo, span_hi):
            def shard_fn(cand, cand_valid, p, pv, bv, lo, hi):
                lo = lo[0]
                hi = hi[0]
                shard_rows = cand.shape[0]
                slice_start = jnp.clip(lo, 0, max(shard_rows - window, 0))
                win = jax.lax.dynamic_slice_in_dim(
                    cand, slice_start, window, axis=0)
                win_valid = jax.lax.dynamic_slice_in_dim(
                    cand_valid, slice_start, window, axis=0)
                pos = jnp.arange(window, dtype=jnp.int32) + slice_start
                in_span = (pos >= lo) & (pos < jnp.minimum(
                    lo + window, hi))
                keep, idx, nmatch = kops.bindjoin_grouped(win, p, pv)
                base = kops.tpf_match(win, bv)
                mask = (keep & base[:, None]
                        & (win_valid & in_span)[:, None])        # (W, G)
                cnts = jnp.sum(jnp.where(mask, nmatch, 0), axis=0)
                rows, counts = jax.vmap(
                    lambda m: kops.compact_mask(m, window),
                    in_axes=1, out_axes=0)(mask)          # (G, W), (G,)
                safe = jnp.maximum(rows, 0)
                page = jnp.take(win, safe, axis=0)        # (G, W, 3)
                first = jax.vmap(lambda r, col: col[r],
                                 in_axes=(0, 1))(safe, idx)   # (G, W)
                page = page[:, :, list(wild_cols)]
                page = jnp.where((rows >= 0)[:, :, None], page, -1)
                first = jnp.where(rows >= 0, first, -1)
                page = jax.lax.all_gather(page, axis)
                first = jax.lax.all_gather(first, axis)
                counts = jax.lax.all_gather(counts, axis)
                cnts = jax.lax.all_gather(cnts, axis)
                return page, first, counts, cnts

            fn = shard_map(
                shard_fn, mesh=mesh,
                in_specs=(P(axis, None), P(axis), P(), P(), P(),
                          P(axis), P(axis)),
                out_specs=(P(), P(), P(), P()),
                check_vma=False,
            )
            return fn(triples, valid, pats, pat_valid, base_vec,
                      span_lo, span_hi)

        fn = jax.jit(step)
        self._steps[key] = fn
        return fn

    def lowerable_windowed_grouped_compact(self, wc: int, groups: int,
                                           wild_cols: tuple = (0, 1, 2)):
        """Sub-window compacted grouped step (docs/fusion.md).

        Instead of streaming a contiguous window, each shard gathers an
        explicit row-index vector of capacity ``wc`` (< window),
        host-computed from the ``merge_spans`` live spans inside the
        page's span -- the PR 5 leftover: when sub-ranges leave large
        dead gaps *inside* a window, the gather skips them at row
        granularity rather than only skipping whole disjoint pages.
        Rows outside every per-binding sub-range are provably match-free
        (each instantiation's matches lie inside its own key interval),
        so dropping them cannot change the response; the caller records
        the reclaimed rows on the :class:`LaunchRecord`.

        ``wc`` is a power of two (bounded jit cache; the caller only
        compacts when ``wc <= window // 2``, so the gather pays for
        itself). Index -1 marks padding slots.
        """
        key = ("compact", wc, groups, wild_cols)
        fn = self._steps.get(key)
        if fn is not None:
            return fn
        mesh, axis = self.mesh, self.axis
        bt = min(kops.DEFAULT_BT, wc)

        def step(triples, valid, pats, pat_valid, base_vec, row_sel):
            def shard_fn(cand, cand_valid, p, pv, bv, rs):
                rs = rs.reshape(wc)
                safe = jnp.maximum(rs, 0)
                win = jnp.take(cand, safe, axis=0)        # (wc, 3)
                wv = jnp.take(cand_valid, safe, axis=0) & (rs >= 0)
                keep, idx, nmatch = kops.bindjoin_grouped(win, p, pv,
                                                          bt=bt)
                base = kops.tpf_match(win, bv)
                mask = keep & base[:, None] & wv[:, None]      # (wc, G)
                cnts = jnp.sum(jnp.where(mask, nmatch, 0), axis=0)
                rows, counts = jax.vmap(
                    lambda m: kops.compact_mask(m, wc),
                    in_axes=1, out_axes=0)(mask)       # (G, wc), (G,)
                safe2 = jnp.maximum(rows, 0)
                page = jnp.take(win, safe2, axis=0)        # (G, wc, 3)
                first = jax.vmap(lambda r, col: col[r],
                                 in_axes=(0, 1))(safe2, idx)   # (G, wc)
                page = page[:, :, list(wild_cols)]
                page = jnp.where((rows >= 0)[:, :, None], page, -1)
                first = jnp.where(rows >= 0, first, -1)
                page = jax.lax.all_gather(page, axis)
                first = jax.lax.all_gather(first, axis)
                counts = jax.lax.all_gather(counts, axis)
                cnts = jax.lax.all_gather(cnts, axis)
                return page, first, counts, cnts

            fn = shard_map(
                shard_fn, mesh=mesh,
                in_specs=(P(axis, None), P(axis), P(), P(), P(),
                          P(axis, None)),
                out_specs=(P(), P(), P(), P()),
                check_vma=False,
            )
            return fn(triples, valid, pats, pat_valid, base_vec, row_sel)

        fn = jax.jit(step)
        self._steps[key] = fn
        return fn

    def lowerable_windowed_fused(self, window: int, segs: int,
                                 groups: int):
        """Cross-pattern fused windowed step: S segments, one launch.

        The sharded twin of ``kops.bindjoin_fused`` (docs/fusion.md):
        per round, every shard slices ONE window of *each* segment's
        bound-prefix range under this step's index order, concatenates
        the S windows into one tile-aligned stream, and the fused kernel
        resolves each tile's segment from its program id. Per-segment
        ``(lo, hi)`` keys and page indexes arrive as int64/int32 [S]
        vectors; a page index of -1 deactivates its segment for the
        round (its rows are masked out of every group), which is how
        segments with fewer planned pages ride along. Windows are padded
        to the next power of two so the fused tile evenly divides every
        segment's extent.

        Returns (page, first, counts, cnts) shaped
        (shards, S, G, Wp[, 3]) / (shards, S, G) after the all-gather --
        no column projection: segments bind different components, so the
        full triples travel back.
        """
        window = max(1, min(window, self.shard_n))
        wp = _pow2(window)
        key = ("fused", window, segs, groups)
        fn = self._steps.get(key)
        if fn is not None:
            return fn
        mesh, axis = self.mesh, self.axis
        bt = min(FUSED_BT, wp)
        tiles_per_seg = wp // bt

        def step(triples, valid, keys, pats, pat_valid, base_vecs,
                 lo_keys, hi_keys, page_idx):
            def shard_fn(cand, cand_valid, k, p, pv, bvs, lo, hi, pi):
                wins, valids = [], []
                for si in range(segs):
                    start = jnp.searchsorted(k, lo[si], side="left")
                    end = jnp.searchsorted(k, hi[si], side="right")
                    win, wv, ins = _window_slice(
                        cand, cand_valid, start, end, pi[si], window)
                    ok = wv & ins & (pi[si] >= 0)
                    if wp > window:
                        win = jnp.concatenate(
                            [win, jnp.zeros((wp - window, 3), win.dtype)])
                        ok = jnp.concatenate(
                            [ok, jnp.zeros((wp - window,), bool)])
                    wins.append(win)
                    valids.append(ok)
                stream = jnp.concatenate(wins, axis=0)   # (S * Wp, 3)
                svalid = jnp.concatenate(valids, axis=0)
                seg_of_tile = jnp.repeat(
                    jnp.arange(segs, dtype=jnp.int32), tiles_per_seg)
                keep, idx, nmatch = kops.bindjoin_fused(
                    stream, seg_of_tile, p, pv, bt=bt)
                seg_of_row = jnp.repeat(seg_of_tile, bt)
                base = _fused_base_mask(stream, seg_of_row, bvs)
                mask = keep & base[:, None] & svalid[:, None]
                mm = mask.reshape(segs, wp, groups)
                cnts = jnp.where(mask, nmatch, 0).reshape(
                    segs, wp, groups).sum(axis=1)        # (S, G)
                rows, counts = jax.vmap(jax.vmap(
                    lambda m: kops.compact_mask(m, wp),
                    in_axes=1, out_axes=0))(mm)   # (S, G, Wp), (S, G)
                safe = jnp.maximum(rows, 0)
                win_all = stream.reshape(segs, wp, 3)
                page = jax.vmap(
                    lambda w, r: jnp.take(w, r, axis=0))(win_all, safe)
                idxr = idx.reshape(segs, wp, groups)
                first = jax.vmap(
                    lambda ix, r: jax.vmap(lambda rg, col: col[rg],
                                           in_axes=(0, 1))(r, ix)
                )(idxr, safe)                            # (S, G, Wp)
                page = jnp.where((rows >= 0)[..., None], page, -1)
                first = jnp.where(rows >= 0, first, -1)
                page = jax.lax.all_gather(page, axis)
                first = jax.lax.all_gather(first, axis)
                counts = jax.lax.all_gather(counts, axis)
                cnts = jax.lax.all_gather(cnts, axis)
                return page, first, counts, cnts

            fn = shard_map(
                shard_fn, mesh=mesh,
                in_specs=(P(axis, None), P(axis), P(axis), P(), P(),
                          P(), P(), P(), P()),
                out_specs=(P(), P(), P(), P()),
                check_vma=False,
            )
            return fn(triples, valid, keys, pats, pat_valid, base_vecs,
                      lo_keys, hi_keys, page_idx)

        fn = jax.jit(step)
        self._steps[key] = fn
        return fn

    def execute_windowed(self, tp: TriplePattern,
                         omega: Optional[np.ndarray], max_mpr: int,
                         capacity: int, window: int) -> np.ndarray:
        """Run the windowed path end-to-end: disjoint window pages until
        every shard's bound-prefix range is covered (the first response
        carries each shard's range length -- the cnt metadata of
        Definition 2), with client-side reconstruction of projected
        columns.

        Returns the fragment's data-triple sequence byte-identical
        (values AND order) to ``selectors.brtpf_select_with_cnt``.
        ``capacity`` is accepted for interface symmetry with
        :meth:`execute_full` but the per-window page capacity is the
        window itself, so results are never truncated.
        """
        del capacity  # windowed pages are capacity-safe by construction
        insts = instantiate_patterns(tp, omega)
        if len(insts) > max_mpr:
            raise ValueError(f"{len(insts)} instantiations > maxMpR")
        selector = ShardedSelector(self, window=window)
        data, _cnt = selector.select_with_cnt(tp, omega, insts)
        return data


def _window_slice(cand, cand_valid, start, end, pi, window: int):
    """Slice window ``pi`` of the shard-local range [start, end).

    The span ``[start + pi*window, min(start + (pi+1)*window, end))`` is
    what this page *owns*; the physical slice start is clamped into the
    array so ``dynamic_slice`` never clips, and ``in_span`` masks the
    slice back to the owned span -- spans are disjoint across pages and
    exactly tile the range, so no triple is reported twice and none is
    skipped.
    """
    shard_n = cand.shape[0]
    span_lo = start + pi.astype(start.dtype) * window
    slice_start = jnp.clip(span_lo, 0, max(shard_n - window, 0))
    win = jax.lax.dynamic_slice_in_dim(
        cand, slice_start.astype(jnp.int32), window, axis=0)
    win_valid = jax.lax.dynamic_slice_in_dim(
        cand_valid, slice_start.astype(jnp.int32), window, axis=0)
    pos = jnp.arange(window, dtype=jnp.int64) + slice_start
    in_span = (pos >= span_lo) & (pos < jnp.minimum(span_lo + window,
                                                    end))
    return win, win_valid, in_span


def _pow2(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


def _subtract_interval(spans: List[Tuple[int, int]], a: int,
                       b: int) -> List[Tuple[int, int]]:
    """Remove [a, b) from a sorted list of disjoint [lo, hi) spans."""
    out: List[Tuple[int, int]] = []
    for lo, hi in spans:
        if hi <= a or lo >= b:
            out.append((lo, hi))
            continue
        if lo < a:
            out.append((lo, a))
        if hi > b:
            out.append((b, hi))
    return out


def _chop_spans(spans: List[List[Tuple[int, int]]],
                window: int) -> Tuple[List[List[Tuple[int, int]]], int]:
    """Chop each shard's spans into window-sized chunks; returns the
    per-shard chunk lists and the number of launch rounds (the longest
    shard's chunk count -- shards with fewer chunks idle in later
    rounds)."""
    chunks: List[List[Tuple[int, int]]] = []
    for shard_spans in spans:
        cs: List[Tuple[int, int]] = []
        for lo, hi in shard_spans:
            p = lo
            while p < hi:
                q = min(p + window, hi)
                cs.append((p, q))
                p = q
        chunks.append(cs)
    rounds = max((len(c) for c in chunks), default=0)
    return chunks, rounds


class ShardedSelector:
    """Mesh-sharded windowed selector with the KernelSelector contract.

    Serves the bindings-restricted selector from a
    :class:`FederatedStore` without ever materializing a candidate
    range: each launch streams one ``window`` per shard, G same-pattern
    requests share the launch (grouped geometry), and the host epilogue
    (:func:`~repro.core.kernel_selectors.stream_order` over the
    all-gathered kept rows + first-match indices) makes the returned
    data-triple sequence and Definition-2 ``cnt`` byte-identical to
    ``selectors.brtpf_select_with_cnt``.

    Why parity holds across shards: the store partitions the triples,
    so every triple is evaluated on exactly one shard, and page spans
    are disjoint within a shard -- each matching triple is kept exactly
    once, with the same first-matching-pattern stream id the single-host
    kernel computes; the epilogue's (stream, packed-key) sort is a total
    order, so concatenation order across shards/windows is irrelevant.
    ``cnt`` sums the per-row matching-pattern counts over all shards,
    which equals the oracle's sum of per-instantiation stream sizes.

    ``launches`` records one :class:`LaunchRecord` per window launch
    with ``cand_streamed = window`` -- the rows ONE device streams --
    so the accounting surface (and the budgets gated on it) is shared
    with the single-host kernel path.

    Omega-restricted pruning (docs/pruning.md): every request is
    launched from a host-side :class:`WindowPlan` -- the POS/OSP
    mirrors let the plan pick the order with the longest bound prefix
    (unbound-subject patterns stop scanning whole shards), and window
    pages disjoint from every per-binding sub-range are skipped
    outright. With ``store`` connected and ``fast_path_rows`` > 0,
    plans whose relevant row count falls below the threshold are served
    by the numpy block evaluation instead of launching windows.
    """

    def __init__(self, fed: FederatedStore,
                 window: int = DEFAULT_SHARD_WINDOW,
                 fragments: Optional[FragmentStore] = None,
                 store=None, fast_path_rows: int = 0,
                 heat: Optional[HeatLog] = None) -> None:
        self.fed = fed
        self.window = max(1, min(int(window), fed.shard_n))
        self.fragments = fragments
        self.store = store
        self.fast_path_rows = int(fast_path_rows)
        self.launches: List[LaunchRecord] = []
        # Placement surfaces (docs/federation.md, "Placement"): the
        # bounded heat log the re-partitioner consumes, and per-shard
        # attribution counters -- launches a shard had work in, candidate
        # rows it streamed, and planned window pages it owned.
        self.heat = heat
        self.shard_launches = np.zeros((fed.shards,), dtype=np.int64)
        self.shard_rows = np.zeros((fed.shards,), dtype=np.int64)
        self.shard_pages = np.zeros((fed.shards,), dtype=np.int64)

    # -- placement surfaces (docs/federation.md, "Placement") ---------------

    def shard_balance(self) -> dict:
        """JSON-safe per-shard balance snapshot (metrics ``shards``)."""
        from .metrics import shard_balance
        return shard_balance(self.shard_launches.tolist(),
                             self.shard_rows.tolist(),
                             self.shard_pages.tolist())

    def reset_shard_counters(self) -> None:
        self.shard_launches[:] = 0
        self.shard_rows[:] = 0
        self.shard_pages[:] = 0

    def rebind(self, fed: FederatedStore) -> None:
        """Cutover to a repartitioned store: swap the federation, clamp
        the window to the new shard size, and restart the per-shard
        attribution (old counts were measured against old boundaries).
        The heat log is kept -- it describes the workload, not the
        partitioning."""
        self.fed = fed
        self.window = max(1, min(self.window, fed.shard_n))
        self.shard_launches = np.zeros((fed.shards,), dtype=np.int64)
        self.shard_rows = np.zeros((fed.shards,), dtype=np.int64)
        self.shard_pages = np.zeros((fed.shards,), dtype=np.int64)

    def _charge_shard_page(self, plan: WindowPlan, window: int,
                           page_idx: int,
                           row_sel: Optional[np.ndarray] = None) -> None:
        """Attribute one window page to the shards that had work in it."""
        if plan.shard_bounds is None:
            return
        for s, (start, end) in enumerate(plan.shard_bounds):
            plo = start + page_idx * window
            phi = min(plo + window, end)
            if phi <= plo:
                continue
            if row_sel is not None:
                rows = int((row_sel[s] >= 0).sum())
                if rows == 0:
                    continue
            elif plan.pruned and plan.shard_spans is not None:
                rows = 0
                for lo, hi in np.asarray(
                        plan.shard_spans[s]).reshape(-1, 2):
                    rows += max(0, min(int(hi), phi) - max(int(lo), plo))
                if rows == 0:
                    continue
            else:
                rows = phi - plo
            self.shard_launches[s] += 1
            self.shard_pages[s] += 1
            self.shard_rows[s] += rows

    def _routed_spans(self, plan: WindowPlan) -> List[List[Tuple[int, int]]]:
        """Per-shard live [lo, hi) position spans for the routed path,
        with every overlapping replica range deduped to its least-loaded
        owner (the other holders get the range subtracted -- a pair of
        binary searches, since each holder's copy is sorted)."""
        fed = self.fed
        hk = fed.indexes[plan.order].host_keys
        shards = hk.shape[0]
        spans: List[List[Tuple[int, int]]] = []
        if plan.pruned and plan.shard_spans is not None:
            for sp in plan.shard_spans:
                spans.append([(int(a), int(b)) for a, b in
                              np.asarray(sp).reshape(-1, 2) if b > a])
        elif plan.shard_bounds is not None:
            spans = [[(int(a), int(b))] if b > a else []
                     for a, b in plan.shard_bounds]
        else:
            for s in range(shards):
                a = int(np.searchsorted(hk[s], plan.lo_key, side="left"))
                b = int(np.searchsorted(hk[s], plan.hi_key, side="right"))
                spans.append([(a, b)] if b > a else [])
        placement = fed.placement
        if placement is None:
            return spans
        for rr in placement.replicas.get(plan.order, ()):
            if rr.hi_key < plan.lo_key or rr.lo_key > plan.hi_key:
                continue
            holders = rr.holders
            owner = min(holders,
                        key=lambda s: (int(self.shard_pages[s]), s))
            for s in holders:
                if s == owner:
                    continue
                a = int(np.searchsorted(hk[s], rr.lo_key, side="left"))
                b = int(np.searchsorted(hk[s], rr.hi_key, side="right"))
                if b > a:
                    spans[s] = _subtract_interval(spans[s], a, b)
        return spans

    # -- public API (same contract as KernelSelector) ------------------------

    def select_with_cnt(
        self, tp: TriplePattern, omega: Optional[np.ndarray],
        insts: Optional[List[TriplePattern]] = None,
    ) -> Tuple[np.ndarray, int]:
        """Sharded ``brtpf_select_with_cnt`` (byte-identical)."""
        return self.select_same_pattern(
            tp, [omega], None if insts is None else [insts])[0]

    def select_same_pattern(
        self, tp: TriplePattern, omegas: Sequence[Optional[np.ndarray]],
        patterns: Optional[List[List[TriplePattern]]] = None,
    ) -> List[Tuple[np.ndarray, int]]:
        """Serve G same-pattern requests from one sharded launch per
        window page. Returns per-request (data sequence, cnt), each
        identical to ``brtpf_select_with_cnt(store, tp, omega_g)``.

        Groups resident in the connected fragment store never launch a
        window: their share is recorded as skipped (same contract as
        :class:`~repro.core.kernel_selectors.KernelSelector`)."""
        if patterns is None:
            patterns = [instantiate_patterns(tp, om) for om in omegas]
        results, live = consult_fragments(self.fragments, tp, omegas,
                                          self.launches)
        if live:
            live_omegas = [omegas[i] for i in live]
            fresh = self._launch_groups(tp, live_omegas,
                                        [patterns[i] for i in live])
            record_fragments(self.fragments, tp, live_omegas, fresh)
            for i, res in zip(live, fresh, strict=True):
                results[i] = res
        return results

    def select_count(self, tp: TriplePattern, omega: Optional[np.ndarray],
                     insts: Optional[List[TriplePattern]] = None) -> int:
        """Count-only sharded selection: Definition-2 ``cnt``, no row
        gather, no all-gathered pages consumed (docs/fusion.md)."""
        if self.fragments is not None:
            got = self.fragments.peek_data(
                fragment_key(tp.as_tuple(), omega), touch=True)
            if got is not None:
                self.fragments.note_skip()
                self.launches.append(LaunchRecord(
                    cand_streamed=0, pat_slots=0, groups=1, skipped=True))
                return int(got[1])
        patterns = [insts if insts is not None
                    else instantiate_patterns(tp, omega)]
        return self._launch_groups(tp, [omega], patterns,
                                   count_only=True)[0][1]

    def _launch_groups(
        self, tp: TriplePattern, omegas: Sequence[Optional[np.ndarray]],
        patterns: List[List[TriplePattern]],
        count_only: bool = False,
    ) -> List[Tuple[np.ndarray, int]]:
        """Windowed sharded launches over the store-miss groups."""
        all_insts = [p for group in patterns for p in group]
        plan = self.fed.plan_windows(tp, all_insts, self.window)
        return self._launch_plan(tp, patterns, plan,
                                 count_only=count_only)

    def _gather_fast_block(self, tp: TriplePattern,
                           all_insts: List[TriplePattern]) -> np.ndarray:
        """Host-side pruned candidate block for the small-work path."""
        sr = self.store.subranges(tp, insts=all_insts)
        if sr is not None and sr.rows < len(
                self.store.candidate_range(tp)):
            return self.store.gather_subranges(sr)
        return self.store.candidate_range(tp).triples

    def _page_row_sel(self, plan: WindowPlan, window: int,
                      page: int) -> Optional[np.ndarray]:
        """Sub-window compaction plan for one page (docs/fusion.md).

        Intersects each shard's live ``merge_spans`` sub-ranges with the
        page's owned span; if the widest shard's live row count, padded
        to a power of two, is at most half the window, returns the
        int32 [shards, wc] gather-index table (-1 padding) for
        ``lowerable_windowed_grouped_compact``. Otherwise (dead gaps too
        small to pay for the gather) returns None and the page streams
        contiguously as before.
        """
        if not plan.pruned or plan.shard_spans is None \
                or plan.shard_bounds is None:
            return None
        per_shard: List[np.ndarray] = []
        need = 0
        for (start, end), spans in zip(plan.shard_bounds,
                                       plan.shard_spans, strict=True):
            plo = start + page * window
            phi = min(plo + window, end)
            segs = [np.arange(max(int(lo), plo), min(int(hi), phi),
                              dtype=np.int64)
                    for lo, hi in spans]
            segs = [a for a in segs if a.size]
            live = np.concatenate(segs) if segs \
                else np.empty((0,), dtype=np.int64)
            per_shard.append(live)
            need = max(need, int(live.size))
        wc = _pow2(max(need, 1))
        if wc > window // 2:
            return None
        sel = np.full((len(per_shard), wc), -1, dtype=np.int32)
        for s, live in enumerate(per_shard):
            sel[s, :live.size] = live.astype(np.int32)
        return sel

    def _launch_plan(
        self, tp: TriplePattern, patterns: List[List[TriplePattern]],
        plan: WindowPlan, count_only: bool = False,
    ) -> List[Tuple[np.ndarray, int]]:
        """Execute one planned (grouped) request: fast path or windows."""
        g = len(patterns)
        m = max(len(p) for p in patterns)
        window = self.window
        if not plan.pages:
            # no window can contain a match on any shard (empty range,
            # or every sub-range empty): zero launches, cnt = 0
            return [(_EMPTY, 0)] * g

        # Small-work fast path: the plan's relevant rows cannot pay for
        # window dispatches -- evaluate the groups over the pruned block
        # gathered from the (host) oracle store instead.
        if (self.store is not None
                and 0 < plan.candidate_rows <= self.fast_path_rows):
            block = self._gather_fast_block(
                tp, [p for group in patterns for p in group])
            self.launches.append(LaunchRecord(
                cand_streamed=int(block.shape[0]), pat_slots=0, groups=g,
                pruned=plan.pruned, cand_full=plan.range_rows,
                fast_path=True))
            return select_block_numpy(block, tp, patterns,
                                      count_only=count_only)

        # pad the grid to bucketed static shapes (bounded jit cache):
        # groups to a power of two, pattern slots to the kernel m-tile.
        gpad = _pow2(g)
        mp = kops.padded_pattern_slots(m)
        pats, valid, base_vec = marshal_pattern_grid(tp, patterns,
                                                     gpad, mp)
        comps = tp.as_tuple()
        wild = [i for i, c in enumerate(comps) if is_var(c)]
        wild_cols = tuple(wild) or (0,)  # dummy column when fully bound
        idx = self.fed.indexes[plan.order]
        routed = self.fed.placement is not None
        fn = None if routed else self.fed.lowerable_windowed_grouped(
            window, gpad, wild_cols=wild_cols)

        kept: List[List[np.ndarray]] = [[] for _ in range(g)]
        firsts: List[List[np.ndarray]] = [[] for _ in range(g)]
        cnt_total = np.zeros((g,), dtype=np.int64)
        n_launched = 0
        with enable_x64(True):
            lo_dev = jnp.asarray(plan.lo_key, jnp.int64)
            hi_dev = jnp.asarray(plan.hi_key, jnp.int64)
            pats_dev = jnp.asarray(pats)
            valid_dev = jnp.asarray(valid)
            bv_dev = jnp.asarray(base_vec)
            if routed:
                # workload-aware placement: explicit per-shard spans
                # with replica ranges routed to one owner each
                spans = self._routed_spans(plan)
                chunks, rounds = _chop_spans(spans, window)
                rfn = self.fed.lowerable_windowed_routed(
                    window, gpad, wild_cols=wild_cols)
                page_rounds = []
                for r in range(rounds):
                    span_lo = np.zeros((len(chunks),), dtype=np.int32)
                    span_hi = np.zeros((len(chunks),), dtype=np.int32)
                    for s, cs in enumerate(chunks):
                        if r < len(cs):
                            span_lo[s], span_hi[s] = cs[r]
                    page_rounds.append(rfn(
                        idx.triples, idx.valid, pats_dev, valid_dev,
                        bv_dev, jnp.asarray(span_lo),
                        jnp.asarray(span_hi)))
                    self.launches.append(LaunchRecord(
                        cand_streamed=window, pat_slots=gpad * mp,
                        groups=g, pruned=plan.pruned, cand_full=window))
                    n_launched += 1
                    for s, cs in enumerate(chunks):
                        if r < len(cs):
                            a, b = cs[r]
                            self.shard_launches[s] += 1
                            self.shard_pages[s] += 1
                            self.shard_rows[s] += b - a
                for pages, first, counts, cnts in page_rounds:
                    counts = np.asarray(counts)
                    cnt_total += np.asarray(cnts)[:, :g].sum(axis=0)
                    if count_only:
                        continue
                    pages = np.asarray(pages)
                    first = np.asarray(first)
                    for s in range(pages.shape[0]):
                        for gi in range(g):
                            n = int(counts[s, gi])
                            if n:
                                kept[gi].append(pages[s, gi, :n])
                                firsts[gi].append(first[s, gi, :n])
            else:
                for page_idx in plan.pages:
                    row_sel = self._page_row_sel(plan, window, page_idx)
                    if row_sel is not None:
                        # sub-window compaction: gather only the live rows
                        wc = row_sel.shape[1]
                        cfn = self.fed.lowerable_windowed_grouped_compact(
                            wc, gpad, wild_cols=wild_cols)
                        pages, first, counts, cnts = cfn(
                            idx.triples, idx.valid, pats_dev, valid_dev,
                            bv_dev, jnp.asarray(row_sel))
                        self.launches.append(LaunchRecord(
                            cand_streamed=wc, pat_slots=gpad * mp, groups=g,
                            pruned=True, cand_full=window,
                            reclaimed_rows=window - wc))
                    else:
                        pages, first, counts, cnts, _range_len = fn(
                            idx.triples, idx.valid, idx.keys,
                            pats_dev, valid_dev, bv_dev, lo_dev, hi_dev,
                            jnp.asarray(page_idx, jnp.int32))
                        self.launches.append(LaunchRecord(
                            cand_streamed=window, pat_slots=gpad * mp,
                            groups=g, pruned=plan.pruned, cand_full=window))
                    n_launched += 1
                    self._charge_shard_page(plan, window, page_idx,
                                            row_sel=row_sel)
                    counts = np.asarray(counts)
                    cnt_total += np.asarray(cnts)[:, :g].sum(axis=0)
                    if count_only:
                        continue   # cnt-only: skip the gather epilogue
                    pages = np.asarray(pages)
                    first = np.asarray(first)
                    for s in range(pages.shape[0]):
                        for gi in range(g):
                            n = int(counts[s, gi])
                            if n:
                                kept[gi].append(pages[s, gi, :n])
                                firsts[gi].append(first[s, gi, :n])
        if self.heat is not None and n_launched:
            self.heat.record(plan.order, plan.lo_key, plan.hi_key,
                             launches=n_launched,
                             rows=plan.candidate_rows,
                             pages=len(plan.pages))

        out: List[Tuple[np.ndarray, int]] = []
        for gi in range(g):
            if count_only or not kept[gi]:
                out.append((_EMPTY, int(cnt_total[gi])))
                continue
            proj = np.concatenate(kept[gi], axis=0)
            first_g = np.concatenate(firsts[gi], axis=0)
            # reconstruct full triples from the request's bound
            # components (the wire carried only unbound columns)
            full = np.empty((proj.shape[0], 3), dtype=np.int32)
            for i, c in enumerate(comps):
                if is_var(c):
                    full[:, i] = proj[:, wild.index(i)]
                else:
                    full[:, i] = c
            out.append((stream_order(full, first_g, patterns[gi]),
                        int(cnt_total[gi])))
        return out

    # -- cross-pattern fusion (docs/fusion.md) -------------------------------

    def select_fused(self, segments: Sequence[FusedSegment]
                     ) -> List[List[Tuple[np.ndarray, int]]]:
        """Serve S heterogeneous segments with fused windowed launches.

        The sharded twin of ``KernelSelector.select_fused``: segments
        are planned individually (residency skips, ``plan_windows``
        page skipping, and the small-work fast path behave exactly as
        unfused), then the launch-worthy segments are grouped BY INDEX
        ORDER -- only same-order segments can share a window slice pass
        -- and each order group runs ``lowerable_windowed_fused``: per
        round, one launch streams one window of every active segment.
        Segments with fewer planned pages go inactive (page index -1)
        in later rounds. ``fusion_legality`` refusals and singleton
        order groups fall back to per-segment ``_launch_plan`` on the
        already-computed plans.
        """
        results: List[List[Optional[Tuple[np.ndarray, int]]]] = [
            [None] * len(seg.omegas) for seg in segments]
        work: List[Tuple[int, List[List[TriplePattern]],
                         List[Optional[np.ndarray]], List[int],
                         WindowPlan]] = []
        for si, seg in enumerate(segments):
            patterns = seg.patterns
            if patterns is None:
                patterns = [instantiate_patterns(seg.tp, om)
                            for om in seg.omegas]
            live = consult_segment(self.fragments, seg, results[si],
                                   self.launches)
            if not live:
                continue
            omegas_live = [seg.omegas[i] for i in live]
            pats_live = [patterns[i] for i in live]
            all_insts = [p for group in pats_live for p in group]
            plan = self.fed.plan_windows(seg.tp, all_insts, self.window)
            if not plan.pages:
                finish_segment(self.fragments, seg, omegas_live,
                               [(_EMPTY, 0)] * len(live), results[si],
                               live)
                continue
            if (self.store is not None
                    and 0 < plan.candidate_rows <= self.fast_path_rows):
                block = self._gather_fast_block(seg.tp, all_insts)
                self.launches.append(LaunchRecord(
                    cand_streamed=int(block.shape[0]), pat_slots=0,
                    groups=len(live), pruned=plan.pruned,
                    cand_full=plan.range_rows, fast_path=True))
                fresh = select_block_numpy(block, seg.tp, pats_live,
                                           count_only=seg.count_only)
                finish_segment(self.fragments, seg, omegas_live, fresh,
                               results[si], live)
                continue
            work.append((si, pats_live, omegas_live, live, plan))
        if not work:
            return results

        # Legality: declared dependencies refuse the whole batch
        # (conservative -- DaCe-style fusion only for independent
        # states); geometry ceilings are checked per order group below.
        dep_reason = fusion_legality(
            [segments[w[0]] for w in work], stream_rows=0, slot_table=0)

        by_order: Dict[str, List] = {}
        for item in work:
            by_order.setdefault(item[4].order, []).append(item)
        wp = _pow2(self.window)
        for items in by_order.values():
            s_pad = _pow2(len(items))
            g_pad = _pow2(max(len(w[3]) for w in items))
            m_max = max(max(len(p) for p in w[1]) for w in items)
            mp = kops.padded_pattern_slots(m_max)
            reason = dep_reason or fusion_legality(
                [segments[w[0]] for w in items],
                stream_rows=s_pad * wp,
                slot_table=s_pad * g_pad * mp)
            if (len(items) == 1 or reason is not None
                    or self.fed.placement is not None):
                # documented fallback: per-segment grouped launches on
                # the plans already in hand (no re-probe, no re-plan).
                # A workload-aware placement always falls back: the
                # fused step derives spans on device from (lo, hi) keys
                # and cannot honor per-shard replica routing.
                for si, pats_live, omegas_live, live, plan in items:
                    seg = segments[si]
                    fresh = self._launch_plan(seg.tp, pats_live, plan,
                                              count_only=seg.count_only)
                    finish_segment(self.fragments, seg, omegas_live,
                                   fresh, results[si], live)
                continue
            self._launch_fused_order(items, segments, results,
                                     s_pad, g_pad, mp)
        return results

    def _launch_fused_order(self, items, segments, results,
                            s_pad: int, g_pad: int, mp: int) -> None:
        """Run one order group's fused windowed rounds + epilogue."""
        window = self.window
        wp = _pow2(window)
        s = len(items)
        order = items[0][4].order
        idx = self.fed.indexes[order]
        pats_all = np.full((s_pad, g_pad, mp, 3), -1, dtype=np.int32)
        valid_all = np.zeros((s_pad, g_pad, mp), dtype=np.int32)
        base_vecs = np.zeros((s_pad, 8), dtype=np.int32)
        lo = np.zeros((s_pad,), dtype=np.int64)
        hi = np.full((s_pad,), -1, dtype=np.int64)  # empty range for pads
        for wi, (si, pats_live, _om, _live, plan) in enumerate(items):
            p_grid, v_grid, bv = marshal_pattern_grid(
                segments[si].tp, pats_live, g_pad, mp)
            pats_all[wi], valid_all[wi], base_vecs[wi] = p_grid, v_grid, bv
            lo[wi], hi[wi] = plan.lo_key, plan.hi_key
        fn = self.fed.lowerable_windowed_fused(window, s_pad, g_pad)
        rounds = max(len(w[4].pages) for w in items)
        for _si, _pl, _om, _live, plan in items:
            if self.heat is not None and plan.pages:
                self.heat.record(plan.order, plan.lo_key, plan.hi_key,
                                 launches=len(plan.pages),
                                 rows=plan.candidate_rows,
                                 pages=len(plan.pages))
            for page_idx in plan.pages:
                self._charge_shard_page(plan, window, page_idx)

        kept: Dict[Tuple[int, int], List[np.ndarray]] = {}
        firsts: Dict[Tuple[int, int], List[np.ndarray]] = {}
        cnt_total = np.zeros((s, g_pad), dtype=np.int64)
        with enable_x64(True):
            lo_dev = jnp.asarray(lo, jnp.int64)
            hi_dev = jnp.asarray(hi, jnp.int64)
            pats_dev = jnp.asarray(pats_all)
            valid_dev = jnp.asarray(valid_all)
            bvs_dev = jnp.asarray(base_vecs)
            for r in range(rounds):
                pi = np.full((s_pad,), -1, dtype=np.int32)
                for wi, item in enumerate(items):
                    pages = item[4].pages
                    if r < len(pages):
                        pi[wi] = pages[r]
                active = [wi for wi in range(s) if pi[wi] >= 0]
                page, first, counts, cnts = fn(
                    idx.triples, idx.valid, idx.keys, pats_dev,
                    valid_dev, bvs_dev, lo_dev, hi_dev, jnp.asarray(pi))
                counts = np.asarray(counts)
                cnt_total += np.asarray(cnts).sum(axis=0)[:s]
                self.launches.append(LaunchRecord(
                    cand_streamed=len(active) * wp,
                    pat_slots=g_pad * mp,
                    groups=sum(len(items[wi][3]) for wi in active),
                    pruned=any(items[wi][4].pruned for wi in active),
                    cand_full=len(active) * wp,
                    segments=len(active)))
                page = np.asarray(page)
                first = np.asarray(first)
                for wi in active:
                    if segments[items[wi][0]].count_only:
                        continue   # cnt-only segment: no row gather
                    for sh in range(page.shape[0]):
                        for gi in range(len(items[wi][3])):
                            n = int(counts[sh, wi, gi])
                            if n:
                                kept.setdefault((wi, gi), []).append(
                                    page[sh, wi, gi, :n])
                                firsts.setdefault((wi, gi), []).append(
                                    first[sh, wi, gi, :n])

        for wi, (si, pats_live, omegas_live, live, _plan) in \
                enumerate(items):
            seg = segments[si]
            fresh: List[Tuple[np.ndarray, int]] = []
            for gi in range(len(live)):
                cnt = int(cnt_total[wi, gi])
                rows = kept.get((wi, gi))
                if seg.count_only or not rows:
                    fresh.append((_EMPTY, cnt))
                    continue
                full = np.concatenate(rows, axis=0)
                first_g = np.concatenate(firsts[(wi, gi)], axis=0)
                fresh.append((stream_order(full, first_g,
                                           pats_live[gi]), cnt))
            finish_segment(self.fragments, seg, omegas_live, fresh,
                           results[si], live)
