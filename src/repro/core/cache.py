"""LRU HTTP-cache simulation (paper section 7).

The paper instruments the combined TPF/brTPF server to count the cache
hits an HTTP proxy (nginx) *would* achieve, for an unlimited cache or an
LRU cache bounded to a number of distinct requests. A request's cache key
is its full URL, i.e. (pattern, Omega sequence, page) -- brTPF requests
with different attached mappings are distinct cache entries, which is why
brTPF's hit potential is structurally lower (section 7.1).

Since the unified fragment store (``core/fragments.py``) an
:class:`LRUCache` handed to :class:`~repro.core.server.BrTPFServer` is
*bound* to the server's :class:`~repro.core.fragments.FragmentStore`:
this object keeps the section-7 accounting surface (``hits`` /
``misses`` / ``hit_rate``) and the capacity policy, while the pages
themselves live in the store's page layer -- the same entries the
selector memo slices, so eviction is coherent across layers and a
resident page skips its kernel/window launch regardless of which path
populated it. Unbound, the class behaves exactly as before (the
discrete-event simulation replays its shared proxy with a standalone
instance).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Hashable, Optional, Tuple


class LRUCache:
    """Counting LRU cache over hashable request keys.

    ``capacity=None`` simulates the unlimited cache of section 7.1.
    """

    def __init__(self, capacity: Optional[int] = None) -> None:
        self.capacity = capacity
        self.hits = 0
        self.misses = 0
        self._entries: "OrderedDict[Hashable, object]" = OrderedDict()
        self._store = None   # optional FragmentStore backing (bind())

    def bind(self, store) -> None:
        """Become a view over ``store``'s page layer: keys and page
        values live there (one copy, coherent with the selector memo),
        this object keeps the hit/miss accounting and the capacity. The
        server calls this at construction; entries cached before
        binding are discarded."""
        self._store = store
        self._entries.clear()
        store.page_capacity = self.capacity

    def get(self, key: Hashable):
        if self._store is not None:
            val = self._store.http_get(key)
            if val is None:
                self.misses += 1
            else:
                self.hits += 1
            return val
        if key in self._entries:
            self.hits += 1
            self._entries.move_to_end(key)
            return self._entries[key]
        self.misses += 1
        return None

    def contains(self, key: Hashable) -> bool:
        """Non-counting peek (no hit/miss accounting, no LRU bump) --
        used by the server's batch planner, which must not distort the
        cache metrics the paper reports."""
        if self._store is not None:
            return self._store.http_contains(key)
        return key in self._entries

    def put(self, key: Hashable, value: object) -> None:
        if self._store is not None:
            # track capacity live in case a caller resized it
            self._store.page_capacity = self.capacity
            self._store.http_put(key, value)
            return
        self._entries[key] = value
        self._entries.move_to_end(key)
        if self.capacity is not None and len(self._entries) > self.capacity:
            self._entries.popitem(last=False)

    def __len__(self) -> int:
        if self._store is not None:
            return self._store.num_pages
        return len(self._entries)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


def request_key(pattern_tuple: Tuple[int, int, int],
                omega_rows: Optional[tuple],
                page: int) -> Hashable:
    """Canonical cache key: the request 'URL'.

    ``omega_rows`` must be a tuple of row-tuples in *sequence order* --
    two requests with the same mappings in different order are different
    URLs, exactly as for the paper's GET-parameter encoding.
    """
    return (pattern_tuple, omega_rows, page)
