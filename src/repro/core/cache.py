"""LRU HTTP-cache simulation (paper section 7).

The paper instruments the combined TPF/brTPF server to count the cache
hits an HTTP proxy (nginx) *would* achieve, for an unlimited cache or an
LRU cache bounded to a number of distinct requests. A request's cache key
is its full URL, i.e. (pattern, Omega sequence, page) -- brTPF requests
with different attached mappings are distinct cache entries, which is why
brTPF's hit potential is structurally lower (section 7.1).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Hashable, Optional, Tuple


class LRUCache:
    """Counting LRU cache over hashable request keys.

    ``capacity=None`` simulates the unlimited cache of section 7.1.
    """

    def __init__(self, capacity: Optional[int] = None) -> None:
        self.capacity = capacity
        self.hits = 0
        self.misses = 0
        self._entries: "OrderedDict[Hashable, object]" = OrderedDict()

    def get(self, key: Hashable):
        if key in self._entries:
            self.hits += 1
            self._entries.move_to_end(key)
            return self._entries[key]
        self.misses += 1
        return None

    def contains(self, key: Hashable) -> bool:
        """Non-counting peek (no hit/miss accounting, no LRU bump) --
        used by the server's batch planner, which must not distort the
        cache metrics the paper reports."""
        return key in self._entries

    def put(self, key: Hashable, value: object) -> None:
        self._entries[key] = value
        self._entries.move_to_end(key)
        if self.capacity is not None and len(self._entries) > self.capacity:
            self._entries.popitem(last=False)

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


def request_key(pattern_tuple: Tuple[int, int, int],
                omega_rows: Optional[tuple],
                page: int) -> Hashable:
    """Canonical cache key: the request 'URL'.

    ``omega_rows`` must be a tuple of row-tuples in *sequence order* --
    two requests with the same mappings in different order are different
    URLs, exactly as for the paper's GET-parameter encoding.
    """
    return (pattern_tuple, omega_rows, page)
