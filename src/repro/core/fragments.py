"""Unified fragment store: one cache-coherent page layer for every cache.

Section 7 of the paper shows HTTP caching is where brTPF structurally
pays (distinct Omega attachments make distinct URLs, so proxy hit rates
drop versus TPF) -- which makes every *other* reuse layer matter more.
Before this module the repo had four independent caches that could not
see each other: the server's HTTP :class:`~repro.core.cache.LRUCache`,
the server's inline selector memo, the store's candidate-range memo and
the two copy-pasted client-side GET caches. :class:`FragmentStore`
replaces all of their hand-rolled OrderedDicts with one page-granular
store, one eviction policy and one accounting surface, so a kernel or
sharded window launch is skipped whenever the requested page is already
resident -- regardless of which path populated it -- and eviction is
coherent across layers instead of accidental.

A fragment is identified by its page-independent key ``(pattern_tuple,
omega_rows)`` (:func:`fragment_key`; a request URL minus the page
number). Each entry can hold two kinds of residency:

* **data** -- the fragment's full selector result (the selector-memo
  layer; for the triple store's range memo the payload is a lazy
  :class:`~repro.core.store.CandidateRange` instead). Any page of a
  data-resident fragment can be served by slicing, without a kernel or
  window launch.
* **pages** -- individual rendered page objects (the HTTP-cache layer;
  also the client-side GET cache). A page stays servable after the full
  data was evicted.

Eviction is coherent by construction: the page a bound HTTP cache
serves *is* the entry's page (evicting the HTTP entry drops the memo's
page and vice versa -- :meth:`FragmentStore.evict` drops both layers),
and when an entry's last resource goes the per-pattern refcount drops,
firing ``on_release(pattern_tuple)`` so the server can evict the
store's candidate range for a pattern no fragment is streaming anymore.

Accounting surfaces (the section-7 caveat): ``hits``/``misses`` count
*data-layer* (memo) lookups and ``page_hits``/``page_misses`` count
*page-layer* (HTTP) lookups, separately -- memo-only traffic must not
distort the HTTP hit accounting the paper reports, and the page layer
only ever serves pages that were explicitly registered through the HTTP
path, never pages merely derivable from memo data.
``launches_skipped`` counts origin computations avoided by residency on
an accelerated selector backend.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Hashable, Optional, Tuple

import numpy as np

DEFAULT_MEMO_CAPACITY = 256


def fragment_key(pattern_tuple: Tuple[int, int, int],
                 omega: Optional[np.ndarray]) -> Tuple:
    """Page-independent fragment identity: (pattern, Omega sequence).

    Matches the first two components of :func:`~repro.core.cache.
    request_key`, so ``request_key(p, om, page)[:2] == fragment_key(p,
    omega)`` -- the server, the selectors and the clients all address
    the same entry for the same fragment.
    """
    om = None
    if omega is not None:
        om = tuple(map(tuple, np.asarray(omega).tolist()))
    return (pattern_tuple, om)


@dataclasses.dataclass
class FragmentEntry:
    """One fragment's residency: optional full data + rendered pages."""

    key: Tuple
    data: object = None                    # full selector result payload
    pages: "OrderedDict[int, object]" = dataclasses.field(
        default_factory=OrderedDict)

    @property
    def empty(self) -> bool:
        return self.data is None and not self.pages


class FragmentStore:
    """Page-granular LRU fragment store with two coherent layers.

    ``memo_capacity`` bounds data-resident entries (LRU over data
    residency). ``page_capacity`` bounds pages (LRU over pages; ``None``
    = unlimited, the section-7.1 unlimited cache). ``weigh(payload)``
    optionally bounds total payload weight by ``max_rows`` (the
    candidate-range memo's retained-row bound; the newest entry is
    always kept). ``on_release(pattern_tuple)`` fires when the last
    entry for a pattern is removed from both layers.
    """

    def __init__(self, memo_capacity: int = DEFAULT_MEMO_CAPACITY,
                 page_capacity: Optional[int] = None,
                 max_rows: Optional[int] = None,
                 weigh: Optional[Callable[[object], int]] = None,
                 on_release: Optional[Callable[[Tuple], object]] = None,
                 ) -> None:
        self.memo_capacity = int(memo_capacity)
        self.page_capacity = page_capacity
        self.max_rows = max_rows
        self.weigh = weigh
        self.on_release = on_release
        self._entries: dict = {}
        self._data_lru: "OrderedDict[Tuple, None]" = OrderedDict()
        self._page_lru: "OrderedDict[Tuple, None]" = OrderedDict()
        self._pattern_refs: dict = {}
        self.hits = 0            # data-layer (memo) lookups
        self.misses = 0
        self.page_hits = 0       # page-layer (HTTP) lookups
        self.page_misses = 0
        self.launches_skipped = 0

    # -- data layer (selector memo / range memo) -----------------------------

    def get_data(self, key: Tuple, count_miss: bool = True):
        """Counting data lookup: payload or None; bumps LRU, re-trims
        the weight bound (payloads can grow lazily after insert).

        ``count_miss=False`` is the probe variant: a present payload is
        still a (counted) hit, but an absent one charges nothing --
        probe traffic that will not populate the entry must not distort
        the miss accounting of the layers that do."""
        entry = self._entries.get(key)
        if entry is None or entry.data is None:
            if count_miss:
                self.misses += 1
            return None
        self.hits += 1
        self._data_lru.move_to_end(key)
        if self.weigh is not None:
            self._trim_data()
        return entry.data

    def peek_data(self, key: Tuple, touch: bool = False):
        """Non-counting data lookup (no hit/miss accounting); ``touch``
        bumps the LRU position -- used by selectors consulting the store
        before a launch, which must not double-count the server's own
        memo accounting for the same request."""
        entry = self._entries.get(key)
        if entry is None or entry.data is None:
            return None
        if touch:
            self._data_lru.move_to_end(key)
        return entry.data

    def contains_data(self, key: Tuple) -> bool:
        """Non-counting, non-bumping residency peek (batch planner)."""
        entry = self._entries.get(key)
        return entry is not None and entry.data is not None

    def put_data(self, key: Tuple, payload: object) -> None:
        entry = self._require(key)
        if entry.data is None:
            self._data_lru[key] = None
        entry.data = payload
        self._data_lru.move_to_end(key)
        self._trim_data()

    # -- page layer (HTTP cache view / client GET cache) ---------------------

    @staticmethod
    def _split(request_key: Tuple) -> Tuple[Tuple, Hashable]:
        """(pattern, omega, page) request key -> (fragment key, page)."""
        return request_key[:2], request_key[2]

    def http_get(self, request_key: Tuple):
        """Counting page lookup. Only pages registered via
        :meth:`http_put` are served -- a page merely derivable from
        resident memo data is a *miss* here, exactly as for the paper's
        proxy (memo traffic must not inflate HTTP hit counts)."""
        key, page = self._split(request_key)
        entry = self._entries.get(key)
        if entry is None or page not in entry.pages:
            self.page_misses += 1
            return None
        self.page_hits += 1
        self._page_lru.move_to_end((key, page))
        return entry.pages[page]

    def http_contains(self, request_key: Tuple) -> bool:
        """Non-counting peek (no hit/miss accounting, no LRU bump)."""
        key, page = self._split(request_key)
        entry = self._entries.get(key)
        return entry is not None and page in entry.pages

    def http_put(self, request_key: Tuple, value: object) -> None:
        key, page = self._split(request_key)
        entry = self._require(key)
        entry.pages[page] = value
        self._page_lru[(key, page)] = None
        self._page_lru.move_to_end((key, page))
        self._trim_pages()

    @property
    def num_pages(self) -> int:
        return len(self._page_lru)

    # -- residency / skip accounting ------------------------------------------

    def page_resident(self, request_key: Tuple) -> bool:
        """Can this page be served without origin selector work, from
        ANY layer (full data or a registered page)? Non-counting."""
        key, page = self._split(request_key)
        entry = self._entries.get(key)
        if entry is None:
            return False
        return entry.data is not None or page in entry.pages

    def note_skip(self) -> None:
        """Record one kernel/window launch avoided by residency."""
        self.launches_skipped += 1

    # -- eviction --------------------------------------------------------------

    def evict(self, key: Tuple) -> bool:
        """Coherently drop a whole fragment entry: its memo data AND
        every page (the HTTP view loses the pages too -- single
        storage). Returns True if anything was present."""
        entry = self._entries.get(key)
        if entry is None:
            return False
        if entry.data is not None:
            entry.data = None
            self._data_lru.pop(key, None)
        for page in list(entry.pages):
            self._page_lru.pop((key, page), None)
        entry.pages.clear()
        self._remove_if_empty(key, entry)
        return True

    def evict_page(self, request_key: Tuple) -> bool:
        key, page = self._split(request_key)
        entry = self._entries.get(key)
        if entry is None or page not in entry.pages:
            return False
        del entry.pages[page]
        self._page_lru.pop((key, page), None)
        self._remove_if_empty(key, entry)
        return True

    def trim(self) -> None:
        """Re-enforce both capacity bounds (after a temporary widening,
        e.g. the server's batch-lifetime memo extension)."""
        self._trim_data()
        self._trim_pages()

    def clear(self) -> None:
        """Drop everything without firing ``on_release`` (a client
        cache reset between executions, not coherent eviction)."""
        self._entries.clear()
        self._data_lru.clear()
        self._page_lru.clear()
        self._pattern_refs.clear()

    def reset_counters(self) -> None:
        self.hits = self.misses = 0
        self.page_hits = self.page_misses = 0
        self.launches_skipped = 0

    # -- introspection ---------------------------------------------------------

    @property
    def data_entries(self) -> int:
        return len(self._data_lru)

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    @property
    def page_hit_rate(self) -> float:
        total = self.page_hits + self.page_misses
        return self.page_hits / total if total else 0.0

    def data_payloads(self) -> dict:
        """{fragment key -> payload} view of the data layer."""
        return {k: self._entries[k].data for k in self._data_lru}

    # -- internals -------------------------------------------------------------

    def _require(self, key: Tuple) -> FragmentEntry:
        entry = self._entries.get(key)
        if entry is None:
            entry = FragmentEntry(key=key)
            self._entries[key] = entry
            pattern = key[0]
            self._pattern_refs[pattern] = \
                self._pattern_refs.get(pattern, 0) + 1
        return entry

    def _remove_if_empty(self, key: Tuple, entry: FragmentEntry) -> None:
        if not entry.empty:
            return
        del self._entries[key]
        pattern = key[0]
        refs = self._pattern_refs.get(pattern, 1) - 1
        if refs:  # another live fragment still streams this pattern
            self._pattern_refs[pattern] = refs
            return
        self._pattern_refs.pop(pattern, None)
        if self.on_release is not None:
            self.on_release(pattern)

    def _drop_data(self, key: Tuple) -> None:
        entry = self._entries[key]
        entry.data = None
        del self._data_lru[key]
        self._remove_if_empty(key, entry)

    def _trim_data(self) -> None:
        if self.weigh is not None:
            # Payloads pin weight lazily (a consumer may have
            # materialized since insert), so retained weight is
            # recounted here; the newest entry is always kept.
            weight = sum(self.weigh(self._entries[k].data)
                         for k in self._data_lru)
            while len(self._data_lru) > 1 and (
                    len(self._data_lru) > self.memo_capacity
                    or (self.max_rows is not None
                        and weight > self.max_rows)):
                oldest = next(iter(self._data_lru))
                weight -= self.weigh(self._entries[oldest].data)
                self._drop_data(oldest)
            return
        while len(self._data_lru) > self.memo_capacity:
            self._drop_data(next(iter(self._data_lru)))

    def _trim_pages(self) -> None:
        if self.page_capacity is None:
            return
        while len(self._page_lru) > self.page_capacity:
            (key, page), _ = self._page_lru.popitem(last=False)
            entry = self._entries.get(key)
            if entry is None:
                continue
            entry.pages.pop(page, None)
            self._remove_if_empty(key, entry)


class ClientFragmentCache:
    """The per-execution client-side GET cache, shared by the sync and
    async clients (formerly two copy-pasted ``_client_cache`` dicts).

    Built on :class:`FragmentStore`'s page layer: one rendered page per
    request key, unlimited capacity, cleared per ``execute()`` (the
    paper restarts the client process between query executions). The
    Node.js ldf-client caches GET responses the same way; without it the
    TPF algorithm's repeated first-page probes would dominate #req.
    """

    def __init__(self, enabled: bool = True) -> None:
        self.enabled = enabled
        self.store = FragmentStore(page_capacity=None)

    def get(self, request_key: Tuple):
        if not self.enabled:
            return None
        return self.store.http_get(request_key)

    def put(self, request_key: Tuple, fragment: object) -> None:
        if self.enabled:
            self.store.http_put(request_key, fragment)

    def clear(self) -> None:
        self.store.clear()

    @property
    def hits(self) -> int:
        return self.store.page_hits

    @property
    def misses(self) -> int:
        return self.store.page_misses

    def __len__(self) -> int:
        return self.store.num_pages
