"""Client-side query execution: the TPF client and the brTPF client.

``TPFClient`` follows the originally proposed TPF algorithm (Verborgh et
al. [19], paper section 4.2): recursively decompose the BGP, always
executing the (instantiated) triple pattern with the smallest result-size
estimate first; every intermediate solution re-instantiates the remaining
patterns and triggers fresh first-page requests for all of them. This is
where TPF's request explosion comes from.

``BrTPFClient`` follows paper section 4.3: a *deliberately simple* fixed
left-deep pipeline ordered by first-page cardinality estimates; each
iterator consumes chunks of at most ``maxMpR`` solution mappings, attaches
them to a brTPF request, and joins the returned triples with the chunk.

Both clients talk to the same :class:`~repro.core.server.BrTPFServer`
through the same ``handle`` boundary so every metric is comparable.
"""
from __future__ import annotations

import asyncio
import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from .bgp import BGP
from .fragments import ClientFragmentCache
from .rdf import (UNBOUND, TriplePattern, is_var, decode_var,
                  mapping_from_triple)
from .server import BrTPFServer, Request


class RequestBudgetExceeded(RuntimeError):
    """Raised when a query execution exceeds its request budget (the
    evaluation-harness analogue of the paper's 5-minute timeout)."""


@dataclasses.dataclass
class ExecutionResult:
    solutions: np.ndarray          # int32 [R, V]
    num_requests: int
    data_received: int
    timed_out: bool = False


class _ClientBase:
    """Shared client machinery.

    Includes a per-execution client-side HTTP cache (the Node.js
    ldf-client caches GET responses): the TPF algorithm re-requests the
    first page of every remaining (often identical, still-unbound)
    pattern at each recursion node, and without local caching those
    repeats would dominate #req/dataRecv and make them grow with page
    size -- which the paper's measurements rule out (section 5.3).
    The cache is cleared per execute() (the paper restarts the client
    process between query executions). Both the sync clients here and
    :class:`AsyncBrTPFClient` share one implementation --
    :class:`~repro.core.fragments.ClientFragmentCache`, a page layer of
    the same :class:`~repro.core.fragments.FragmentStore` class the
    server's unified cache is built on."""

    def __init__(self, server: BrTPFServer,
                 request_budget: Optional[int] = None,
                 tick: Optional[Callable[[str, int], None]] = None,
                 client_cache: bool = True) -> None:
        self.server = server
        self.request_budget = request_budget
        self._requests_used = 0
        self.client_cache = ClientFragmentCache(client_cache)
        # tick(kind, units) lets the throughput simulator charge time for
        # client-side work ("join") and network round trips ("request").
        self._tick = tick or (lambda kind, units: None)

    # -- HTTP boundary -------------------------------------------------------

    def _fetch(self, pattern: TriplePattern,
               omega: Optional[np.ndarray], page: int,
               count_only: bool = False):
        req = Request(pattern, omega, page, count_only)
        cached = self.client_cache.get(req.key())
        if cached is not None:
            return cached  # local hit: nothing on the wire
        if (self.request_budget is not None
                and self._requests_used >= self.request_budget):
            raise RequestBudgetExceeded()
        self._requests_used += 1
        if omega is not None:
            self.server.counters.mappings_sent += int(omega.shape[0])
        before = self.server.counters.snapshot()
        shard_snap = getattr(self.server, "shard_launch_snapshot", None)
        before_shards = shard_snap() if shard_snap is not None else None
        frag = self.server.handle(req)
        after = self.server.counters
        # Structured per-request record: feeds the multi-client
        # throughput simulation (trace replay; see core/sim.py). The
        # kernel-launch geometry (candidates streamed / pattern slots)
        # lets the replay re-cost the request under cross-request
        # batching: same-pattern requests share one candidate stream.
        self._tick("http", {
            "key": req.key(),
            "lookups": after.server_lookups - before.server_lookups,
            "scanned": (after.server_triples_scanned
                        - before.server_triples_scanned),
            "recv": frag.triples_received,
            "pattern_key": pattern.as_tuple(),
            "cand": (after.kernel_cand_streamed
                     - before.kernel_cand_streamed),
            "cand_rows": (after.kernel_cand_rows
                          - before.kernel_cand_rows),
            "cand_full_rows": (after.kernel_cand_full_rows
                               - before.kernel_cand_full_rows),
            "pats": after.kernel_pat_slots - before.kernel_pat_slots,
            "launches": (after.kernel_launches
                         - before.kernel_launches),
            # per-shard planned-page delta (sharded backend; empty
            # otherwise) -- feeds the sim's shard-heat model
            "shard_pages": (
                tuple((shard_snap() - before_shards).astype(int).tolist())
                if before_shards is not None and before_shards.size
                else ()),
        })
        self.client_cache.put(req.key(), frag)
        return frag

    def _fetch_all_pages(self, pattern: TriplePattern,
                         omega: Optional[np.ndarray] = None,
                         first: Optional[object] = None) -> np.ndarray:
        """Fetch every page of a fragment; ``first`` may be a pre-fetched
        page-0 fragment (cardinality probe reuse)."""
        pages: List[np.ndarray] = []
        page = 0
        frag = first
        if frag is None:
            frag = self._fetch(pattern, omega, 0)
        pages.append(frag.data)
        while frag.has_next:
            page += 1
            frag = self._fetch(pattern, omega, page)
            pages.append(frag.data)
        if len(pages) == 1:
            return pages[0]
        return np.concatenate(pages, axis=0)


# ---------------------------------------------------------------------------
# TPF client (Verborgh et al. algorithm)
# ---------------------------------------------------------------------------


class TPFClient(_ClientBase):
    def execute(self, bgp: BGP) -> ExecutionResult:
        self._requests_used = 0
        self.client_cache.clear()
        base = self.server.counters.snapshot()
        timed_out = False
        acc: List[np.ndarray] = []
        root = np.full((bgp.num_vars,), UNBOUND, dtype=np.int32)
        try:
            self._recurse(list(bgp.patterns), root, bgp.num_vars, acc)
        except RequestBudgetExceeded:
            timed_out = True
        if acc:
            sols = np.unique(np.stack(acc).astype(np.int32), axis=0)
        else:
            sols = np.empty((0, bgp.num_vars), dtype=np.int32)
        snap = self.server.counters
        return ExecutionResult(
            solutions=sols,
            num_requests=snap.num_requests - base.num_requests,
            data_received=snap.data_received - base.data_received,
            timed_out=timed_out,
        )

    def _recurse(self, patterns: List[TriplePattern], mu: np.ndarray,
                 num_vars: int, acc: List[np.ndarray]) -> None:
        if not patterns:
            acc.append(mu)
            return
        # Probe page 0 of every remaining (instantiated) pattern to get
        # fresh cardinality estimates -- one request each, per [19].
        insts = [tp.instantiate(mu) for tp in patterns]
        frags = []
        for inst in insts:
            frag = self._fetch(inst, None, 0)
            frags.append(frag)
            if frag.cnt == 0:
                return  # some pattern cannot match: prune this branch
        best = min(range(len(insts)), key=lambda i: frags[i].cnt)
        rest = patterns[:best] + patterns[best + 1:]
        triples = self._fetch_all_pages(insts[best], None, frags[best])
        self._tick("join", int(triples.shape[0]))
        for t in triples:
            m = mapping_from_triple(insts[best], t, num_vars)
            if m is None:
                continue
            merged = mu.copy()
            bind = (merged == UNBOUND) & (m != UNBOUND)
            merged[bind] = m[bind]
            self._recurse(rest, merged, num_vars, acc)


def plan_join_order(bgp: BGP, cnts: Sequence[int]) -> List[int]:
    """Fixed left-deep join order (paper section 4.3): smallest first-page
    cardinality estimate first, then greedily the cheapest pattern
    *connected* to the already-bound variables (a bind join against a
    pattern sharing no variable restricts nothing). Shared by the sync
    and async brTPF clients."""
    remaining = set(range(len(bgp)))
    first = min(remaining, key=lambda i: (cnts[i], i))
    order = [first]
    remaining.discard(first)
    bound = set(bgp.patterns[first].variables())
    while remaining:
        connected = [i for i in remaining
                     if bound & set(bgp.patterns[i].variables())]
        pool = connected or sorted(remaining)
        nxt = min(pool, key=lambda i: (cnts[i], i))
        order.append(nxt)
        remaining.discard(nxt)
        bound |= set(bgp.patterns[nxt].variables())
    return order


# ---------------------------------------------------------------------------
# brTPF client (paper section 4.3)
# ---------------------------------------------------------------------------


class BrTPFClient(_ClientBase):
    """``count_probes=True`` issues the upfront cardinality probes as
    count-only requests (docs/fusion.md): the server answers with the
    Definition-2 ``cnt`` and an empty data page, never materializing
    (or shipping) rows the planner only needed an estimate from. The
    most selective pattern's first data page is then fetched normally
    (the classic probe doubles as page 0; a count probe cannot)."""

    def __init__(self, server: BrTPFServer, max_mpr: Optional[int] = None,
                 request_budget: Optional[int] = None,
                 tick=None, count_probes: bool = False) -> None:
        super().__init__(server, request_budget, tick)
        self.max_mpr = max_mpr if max_mpr is not None else server.max_mpr
        self.count_probes = bool(count_probes)

    def execute(self, bgp: BGP) -> ExecutionResult:
        self._requests_used = 0
        self.client_cache.clear()
        base = self.server.counters.snapshot()
        timed_out = False
        sols = np.empty((0, bgp.num_vars), dtype=np.int32)
        try:
            sols = self._run_pipeline(bgp)
        except RequestBudgetExceeded:
            timed_out = True
        snap = self.server.counters
        return ExecutionResult(
            solutions=sols,
            num_requests=snap.num_requests - base.num_requests,
            data_received=snap.data_received - base.data_received,
            timed_out=timed_out,
        )

    # -- fixed left-deep plan ------------------------------------------------

    def _run_pipeline(self, bgp: BGP) -> np.ndarray:
        nv = bgp.num_vars
        # Upfront plan: first TPF page of each pattern -> cnt estimates
        # ("These estimates can be obtained from the server by requesting
        # the first TPF page for each of the triple patterns", sec 4.3).
        # Left-deep join order: smallest-cardinality first, then greedily
        # the cheapest pattern *connected* to the already-bound variables
        # (avoiding cartesian products -- a bind join against a pattern
        # sharing no variable restricts nothing).
        probes = [self._fetch(tp, None, 0, count_only=self.count_probes)
                  for tp in bgp.patterns]
        if min(p.cnt for p in probes) == 0:
            return np.empty((0, nv), dtype=np.int32)
        order = plan_join_order(bgp, [p.cnt for p in probes])

        # Iterator 1: plain TPF over the most selective pattern. A count
        # probe carries no data page to reuse as page 0.
        first_idx = order[0]
        first_tp = bgp.patterns[first_idx]
        first_frag = None if self.count_probes else probes[first_idx]
        triples = self._fetch_all_pages(first_tp, None, first_frag)
        solutions = _mappings_from_matches(first_tp, triples, nv)
        self._tick("join", int(triples.shape[0]))

        # Iterators 2..n: bind-join via brTPF requests in maxMpR chunks.
        for idx in order[1:]:
            tp = bgp.patterns[idx]
            if solutions.shape[0] == 0:
                return solutions
            next_rounds: List[np.ndarray] = []
            for lo in range(0, solutions.shape[0], self.max_mpr):
                chunk = solutions[lo : lo + self.max_mpr]
                data = self._fetch_all_pages(tp, chunk)
                joined = _bind_join(tp, data, chunk, nv)
                self._tick("join", int(data.shape[0]) * 1)
                if joined.shape[0]:
                    next_rounds.append(joined)
            solutions = (np.concatenate(next_rounds, axis=0)
                         if next_rounds
                         else np.empty((0, nv), dtype=np.int32))
        return np.unique(solutions, axis=0) if solutions.shape[0] \
            else solutions


# ---------------------------------------------------------------------------
# Async brTPF client (concurrent BGP driver over the batching front end)
# ---------------------------------------------------------------------------


class AsyncBrTPFClient:
    """Concurrent BGP driver for :class:`~repro.core.batching.AsyncBrTPFServer`.

    Runs the same fixed left-deep plan as :class:`BrTPFClient`
    (``plan_join_order``), but issues the independent pieces of each
    stage concurrently: the upfront cardinality probes go out together,
    and at every bind-join iterator the per-``maxMpR``-chunk page
    sequences are *all in flight at once* (each chunk still pages
    sequentially -- page ``n+1`` depends on page ``n``'s ``has_next``).
    Same-pattern chunk requests therefore land inside one batching
    window and coalesce into grouped kernel launches on the server --
    the client-visible results are identical to the sequential client's
    (both end in ``np.unique``; chunk arrival order doesn't matter).
    """

    def __init__(self, front, max_mpr: Optional[int] = None,
                 request_budget: Optional[int] = None,
                 client_cache: bool = True,
                 count_probes: bool = False,
                 deadline_ms: Optional[float] = None) -> None:
        # ``front`` is anything with ``async handle(Request) -> Fragment``
        # and a ``max_mpr`` bound: an AsyncBrTPFServer (in-process) or a
        # Transport (repro.serving.transport -- loopback or HTTP). Only
        # the in-process path exposes the origin server itself.
        self.front = front
        self.server: Optional[BrTPFServer] = getattr(front, "server", None)
        if max_mpr is None:
            max_mpr = getattr(front, "max_mpr", None)
        if max_mpr is None:
            raise ValueError("front exposes no max_mpr; pass max_mpr=")
        self.max_mpr = max_mpr
        self.request_budget = request_budget
        self._requests_used = 0
        self._received = 0
        self.client_cache = ClientFragmentCache(client_cache)
        # count-only cardinality probes (docs/fusion.md): with a
        # heterogeneous BGP the concurrent probes land in one batching
        # window and fuse into cnt-only segments of one launch.
        self.count_probes = bool(count_probes)
        # Per-request deadline budget (docs/resilience.md), stamped onto
        # every outgoing Request as ``timeout_ms``. A ResilientTransport
        # below decrements it across retry attempts; a bare transport
        # simply bounds its await on it. None = unbounded (pre-PR-10
        # behavior, byte-identical wire bodies).
        if deadline_ms is not None and deadline_ms <= 0:
            raise ValueError("deadline_ms must be > 0 (or None)")
        self.deadline_ms = deadline_ms

    # -- HTTP boundary (async) ----------------------------------------------

    async def _fetch(self, pattern: TriplePattern,
                     omega: Optional[np.ndarray], page: int,
                     count_only: bool = False):
        req = Request(pattern, omega, page, count_only,
                      timeout_ms=self.deadline_ms)
        cached = self.client_cache.get(req.key())
        if cached is not None:
            return cached
        if (self.request_budget is not None
                and self._requests_used >= self.request_budget):
            raise RequestBudgetExceeded()
        self._requests_used += 1
        # In-process accounting only: over a transport the wire boundary
        # charges mappings_sent itself (Transport/ASGI note_mappings).
        if omega is not None and self.server is not None:
            self.server.counters.mappings_sent += int(omega.shape[0])
        frag = await self.front.handle(req)
        self._received += frag.triples_received
        self.client_cache.put(req.key(), frag)
        return frag

    async def _fetch_all_pages(self, pattern: TriplePattern,
                               omega: Optional[np.ndarray] = None,
                               first: Optional[object] = None) -> np.ndarray:
        pages: List[np.ndarray] = []
        page = 0
        frag = first
        if frag is None:
            frag = await self._fetch(pattern, omega, 0)
        pages.append(frag.data)
        while frag.has_next:
            page += 1
            frag = await self._fetch(pattern, omega, page)
            pages.append(frag.data)
        if len(pages) == 1:
            return pages[0]
        return np.concatenate(pages, axis=0)

    # -- execution ----------------------------------------------------------

    async def execute(self, bgp: BGP) -> ExecutionResult:
        # Accounting is client-local (requests issued / triples received
        # by THIS client): with N concurrent clients on one server,
        # server-counter deltas would attribute everyone's traffic to
        # everyone.
        self._requests_used = 0
        self._received = 0
        self.client_cache.clear()
        timed_out = False
        sols = np.empty((0, bgp.num_vars), dtype=np.int32)
        try:
            sols = await self._run_pipeline(bgp)
        except RequestBudgetExceeded:
            timed_out = True
        return ExecutionResult(
            solutions=sols,
            num_requests=self._requests_used,
            data_received=self._received,
            timed_out=timed_out,
        )

    async def run_workload(self, workload) -> List[ExecutionResult]:
        """Execute a (name, BGP) sequence; the unit the concurrency
        benchmarks hand to each simulated client."""
        return [await self.execute(bgp) for _name, bgp in workload]

    @staticmethod
    async def _gather(coros):
        """asyncio.gather that cancels (and drains) siblings when one
        coroutine raises -- a budget-exhausted query must not leave
        orphan fetches running into the next query's accounting."""
        tasks = [asyncio.ensure_future(c) for c in coros]
        try:
            return await asyncio.gather(*tasks)
        except BaseException:
            for t in tasks:
                t.cancel()
            await asyncio.gather(*tasks, return_exceptions=True)
            raise

    async def _run_pipeline(self, bgp: BGP) -> np.ndarray:
        nv = bgp.num_vars
        probes = await self._gather(
            [self._fetch(tp, None, 0, count_only=self.count_probes)
             for tp in bgp.patterns])
        if min(p.cnt for p in probes) == 0:
            return np.empty((0, nv), dtype=np.int32)
        order = plan_join_order(bgp, [p.cnt for p in probes])

        first_idx = order[0]
        first_tp = bgp.patterns[first_idx]
        first_frag = None if self.count_probes else probes[first_idx]
        triples = await self._fetch_all_pages(first_tp, None, first_frag)
        solutions = _mappings_from_matches(first_tp, triples, nv)

        for idx in order[1:]:
            tp = bgp.patterns[idx]
            if solutions.shape[0] == 0:
                return solutions
            chunks = [solutions[lo : lo + self.max_mpr]
                      for lo in range(0, solutions.shape[0], self.max_mpr)]
            # Independent omega chunks in flight together: same pattern,
            # same batching window -> one grouped launch server-side.
            datas = await self._gather(
                [self._fetch_all_pages(tp, chunk) for chunk in chunks])
            next_rounds = [joined
                           for chunk, data in zip(chunks, datas,
                                                  strict=True)
                           for joined in [_bind_join(tp, data, chunk, nv)]
                           if joined.shape[0]]
            solutions = (np.concatenate(next_rounds, axis=0)
                         if next_rounds
                         else np.empty((0, nv), dtype=np.int32))
        return np.unique(solutions, axis=0) if solutions.shape[0] \
            else solutions


# ---------------------------------------------------------------------------
# Vectorized join helpers (shared with the reference oracle / kernels)
# ---------------------------------------------------------------------------


def _mappings_from_matches(tp: TriplePattern, triples: np.ndarray,
                           num_vars: int) -> np.ndarray:
    """Convert matching triples into solution mappings, vectorized."""
    n = triples.shape[0]
    out = np.full((n, num_vars), UNBOUND, dtype=np.int32)
    ok = np.ones((n,), dtype=bool)
    comps = tp.as_tuple()
    for pos, c in enumerate(comps):
        if is_var(c):
            v = decode_var(c)
            prev_bound = out[:, v] != UNBOUND
            ok &= ~prev_bound | (out[:, v] == triples[:, pos])
            out[:, v] = triples[:, pos]
        else:
            ok &= triples[:, pos] == c
    return out[ok]


def _bind_join(tp: TriplePattern, triples: np.ndarray, omega: np.ndarray,
               num_vars: int) -> np.ndarray:
    """Join fragment triples with the chunk of mappings they were
    restricted by: for every (t, mu') with mu_t ~ mu', emit mu_t + mu'."""
    mu_t = _mappings_from_matches(tp, triples, num_vars)
    t_n, m_n = mu_t.shape[0], omega.shape[0]
    if t_n == 0 or m_n == 0:
        return np.empty((0, num_vars), dtype=np.int32)
    a = mu_t[:, None, :]          # [T, 1, V]
    b = omega[None, :, :]         # [1, M, V]
    both = (a != UNBOUND) & (b != UNBOUND)
    comp = np.all(~both | (a == b), axis=-1)          # [T, M]
    ti, mi = np.nonzero(comp)
    merged = mu_t[ti]
    take = (merged == UNBOUND) & (omega[mi] != UNBOUND)
    merged[take] = omega[mi][take]
    return merged
