"""Server configuration: one frozen value object for every construction
path.

``BrTPFServer.__init__`` had grown a 10-kwarg sprawl that every layer
above it (the async front end, the benchmarks' ``make_server``, the sim
CLI, and now the ASGI app factory and the replica router) re-declared
by hand -- and a config that only exists as a kwarg list cannot cross a
process boundary or be shared verbatim between N replicas.
:class:`ServerConfig` is the transport-neutral replacement: a frozen
dataclass carrying every origin-server knob, shared by
:class:`~repro.core.server.BrTPFServer`,
:class:`~repro.core.batching.AsyncBrTPFServer` (``from_config``), the
ASGI app factory (:func:`repro.serving.http.app_from_config`) and the
replica router (:class:`repro.serving.router.ReplicaRouter`), so every
replica of a fleet is provably built from the same value.

The legacy per-kwarg constructor surface is kept for one release as a
deprecated passthrough (``tests/test_transport.py`` asserts
equivalence); new code should construct a ``ServerConfig`` and hand the
same object everywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

# Number of metadata + hypermedia-control triples per fragment page. A
# real TPF page carries void:triples counts, next/prev page links and the
# interface's hypermedia controls; the reference server emits ~8-30 such
# triples per page. The *value* only scales the constant page overhead --
# the paper's findings are about how the number of pages differs between
# TPF and brTPF -- so it is configurable.
DEFAULT_META_TRIPLES_PER_PAGE = 8
DEFAULT_PAGE_SIZE = 100
DEFAULT_MAX_MPR = 30

SELECTOR_BACKENDS = ("numpy", "kernel", "sharded")


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Origin-server configuration (paper section 4.1 + the accelerated
    backends of PRs 1/3/5).

    * ``page_size`` / ``max_mpr`` / ``meta_triples_per_page`` -- the
      paper's interface parameters (section 5.1).
    * ``selector_backend`` -- ``"numpy"`` (paper-faithful oracle),
      ``"kernel"`` (Pallas bind-join) or ``"sharded"`` (mesh-partitioned
      windowed launches).
    * ``mesh`` / ``shard_window`` / ``shard_axis`` -- sharded-backend
      geometry (``mesh=None`` builds one over all local devices).
    * ``fast_path_rows`` -- small-work threshold below which the
      accelerated backends route to the numpy block evaluation
      (docs/pruning.md); 0 disables the fast path.
    * ``fuse_patterns`` -- cross-pattern kernel fusion (docs/fusion.md):
      when a batch carries requests for >= 2 distinct triple patterns,
      the accelerated backends serve the whole heterogeneous batch with
      fused launches (one candidate stream, per-segment slot tables)
      instead of one grouped launch sequence per pattern. Fragments are
      byte-identical either way; the toggle exists for A/B accounting.
    * ``placement_policy`` -- sharded-backend data placement
      (docs/federation.md, "Placement"): ``"static"`` keeps the legacy
      equal contiguous split; ``"heat"`` attaches a bounded
      :class:`~repro.core.placement.HeatLog` (capacity
      ``heat_capacity``) to the selector so
      ``BrTPFServer.repartition()`` can cut workload-aware shard
      boundaries from observed traffic.
    * ``queue_depth`` -- admission control for the async batching front
      end (docs/serving.md): maximum pending (unflushed) requests;
      overflow raises
      :class:`~repro.core.batching.QueueSaturated` (HTTP 503,
      retryable). ``None`` keeps the legacy unbounded queue.
    """

    page_size: int = DEFAULT_PAGE_SIZE
    max_mpr: int = DEFAULT_MAX_MPR
    meta_triples_per_page: int = DEFAULT_META_TRIPLES_PER_PAGE
    selector_backend: str = "numpy"
    mesh: Any = None
    shard_window: Optional[int] = None
    shard_axis: str = "data"
    fast_path_rows: int = 0
    fuse_patterns: bool = True
    placement_policy: str = "static"
    heat_capacity: int = 4096
    queue_depth: Optional[int] = None

    def __post_init__(self) -> None:
        if self.selector_backend not in SELECTOR_BACKENDS:
            raise ValueError(
                f"unknown selector_backend {self.selector_backend!r}")
        if self.page_size < 1:
            raise ValueError("page_size must be >= 1")
        if self.max_mpr < 1:
            raise ValueError("max_mpr must be >= 1")
        if self.placement_policy not in ("static", "heat"):
            raise ValueError(
                f"unknown placement_policy {self.placement_policy!r}")
        if self.heat_capacity < 1:
            raise ValueError("heat_capacity must be >= 1")
        if self.queue_depth is not None and self.queue_depth < 1:
            raise ValueError("queue_depth must be >= 1 (or None)")

    def replace(self, **changes: Any) -> "ServerConfig":
        return dataclasses.replace(self, **changes)

    def to_wire(self) -> dict:
        """JSON-safe view (``mesh`` is host-local and not serialized;
        a remote replica rebuilds its own over its devices)."""
        out = {f.name: getattr(self, f.name)
               for f in dataclasses.fields(self) if f.name != "mesh"}
        out["mesh"] = None
        return out

    @classmethod
    def from_wire(cls, obj: dict) -> "ServerConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in obj.items() if k in fields})
