"""In-memory triple store with HDT-style sorted indexes.

The paper's server queries an RDF-HDT backend: a compressed, in-memory
representation supporting (a) matching-triple streams for a triple pattern
and (b) O(1)-ish cardinality estimates. We reproduce that contract with
three sorted permutations of the dictionary-encoded triple array (SPO,
POS, OSP) and packed-int64 binary search:

* each triple ``(a, b, c)`` in a given component order is packed into a
  single int64 key ``a << 42 | b << 21 | c`` (21 bits per component,
  i.e. up to 2,097,151 distinct terms — far above our workloads);
* a pattern with a bound *prefix* of the chosen order maps to one
  contiguous key range -> two ``searchsorted`` calls give the exact match
  range *and* the exact cardinality, mirroring HDT;
* non-prefix bound components (e.g. ``(s, ?, o)``) are resolved by
  scanning the best prefix range with a vectorized mask; the advertised
  cardinality is then an *estimate* (the prefix-range size), which is
  precisely the ``cnt`` estimate with error eps that Definition 2 allows.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .fragments import FragmentStore
from .rdf import TriplePattern, is_var

_BITS = 21
_MAX_ID = (1 << _BITS) - 1

# Component orders for the three indexes.
_ORDERS = {
    "spo": (0, 1, 2),
    "pos": (1, 2, 0),
    "osp": (2, 0, 1),
}


def _pack(a: np.ndarray, b: np.ndarray, c: np.ndarray) -> np.ndarray:
    return (
        a.astype(np.int64) << (2 * _BITS)
        | b.astype(np.int64) << _BITS
        | c.astype(np.int64)
    )


@dataclasses.dataclass
class _Index:
    order: Tuple[int, int, int]  # component order, e.g. (1, 2, 0) for POS
    keys: np.ndarray             # int64 [N], sorted packed keys
    perm: np.ndarray             # int32 [N], perm into the triple array


@dataclasses.dataclass
class CandidateRange:
    """The contiguous prefix range a pattern maps to in its chosen index.

    This is the store's device-facing contract: ``(index, lo, hi,
    prefix_len)`` identify the range for paging/accounting, and every
    triple matching the pattern -- or any instantiation of it -- lies in
    this range. The range is *lazy*: holding a ``CandidateRange`` (e.g.
    in the store's range memo) costs O(1), not O(hi - lo).

    ``window(page, size)`` gathers only ``perm[lo + page*size : ...]``
    -- the true range->page index: a page>0 request materializes just
    its window, never the whole range; gathered windows register as
    *pages* of the owning store's range fragment store (one bounded
    page layer, evicted coherently with the range entry itself), so a
    repeated window read never re-gathers. ``triples`` materializes the
    full block (index order, hence deterministic) for consumers that
    stream it in one HBM pass (the single-host bind-join kernel) and
    caches it, so repeated full reads through the memo gather once.
    """

    index: str                   # index name: "spo" | "pos" | "osp"
    lo: int                      # range start in the index
    hi: int                      # range end (exclusive)
    prefix_len: int              # bound components covered by the prefix
    _store_triples: np.ndarray = dataclasses.field(repr=False, default=None)
    _perm: np.ndarray = dataclasses.field(repr=False, default=None)
    _materialized: Optional[np.ndarray] = dataclasses.field(
        repr=False, default=None)
    # page-layer hookup: (fragment store, fragment key) of the memo
    # entry this range lives in -- set by TripleStore.candidate_range
    _fragments: Optional[object] = dataclasses.field(
        repr=False, default=None)
    _key: Optional[tuple] = dataclasses.field(repr=False, default=None)

    def __len__(self) -> int:
        return self.hi - self.lo

    def window(self, page: int, size: int) -> np.ndarray:
        """Rows ``[lo + page*size, min(lo + (page+1)*size, hi))`` of the
        range, int32 [<=size, 3], gathered without materializing the
        rest (unless the full block or this exact window is already
        cached)."""
        a = self.lo + page * size
        b = min(a + size, self.hi)
        if a >= b:
            return np.empty((0, 3), dtype=np.int32)
        if self._materialized is not None:
            return self._materialized[a - self.lo : b - self.lo]
        page_key = None
        if self._fragments is not None:
            page_key = (*self._key, (page, size))
            got = self._fragments.http_get(page_key)
            if got is not None:
                return got
        rows = self._store_triples[self._perm[a:b]]
        if page_key is not None:
            self._fragments.http_put(page_key, rows)
        return rows

    @property
    def triples(self) -> np.ndarray:
        """Full materialized block, int32 [hi - lo, 3] (cached)."""
        if self._materialized is None:
            self._materialized = \
                self._store_triples[self._perm[self.lo:self.hi]]
        return self._materialized

    @property
    def materialized_rows(self) -> int:
        """Rows this range actually pins (memo accounting unit)."""
        return 0 if self._materialized is None else len(self)

    @property
    def components(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Structure-of-arrays view (s, p, o) -- the kernel input layout."""
        t = self.triples
        return t[:, 0], t[:, 1], t[:, 2]


class TripleStore:
    """Sorted-index triple store over ``int32 [N, 3]`` triples."""

    def __init__(self, triples: np.ndarray) -> None:
        triples = np.asarray(triples, dtype=np.int32).reshape(-1, 3)
        # Set semantics: an RDF graph is a set of triples.
        if triples.shape[0] > 0:
            triples = np.unique(triples, axis=0)
            if int(triples.max(initial=0)) > _MAX_ID:
                raise ValueError("term id exceeds 21-bit packing limit")
            if int(triples.min(initial=0)) < 0:
                raise ValueError("data triples must not contain variables")
        self.triples = triples
        self._indexes = {}
        for name, order in _ORDERS.items():
            keys = _pack(
                triples[:, order[0]], triples[:, order[1]], triples[:, order[2]]
            )
            perm = np.argsort(keys, kind="stable").astype(np.int32)
            self._indexes[name] = _Index(order, keys[perm], perm)
        # Per-pattern candidate-range memo (ROADMAP "Kernel-path TPF
        # paging"): materializing ``triples[perm[lo:hi]]`` is the
        # expensive part of a range read -- a gather over a range that
        # can span the whole store. Ranges are lazy, so a memo entry is
        # O(1) until some consumer materializes its full block; the
        # store is immutable, so the memo never goes stale; the server
        # evicts it coherently with its unified fragment store (its
        # ``on_release`` hook calls :meth:`evict_candidate_range`).
        # The memo itself is a FragmentStore data layer keyed
        # ``(pattern_tuple, None)`` with a materialized-rows weigher:
        # broad patterns can materialize near-store-sized copies, so
        # the memo is bounded by retained ROWS as well as entries (64
        # low-selectivity ranges must not pin ~64x the store; the
        # newest entry is always kept).
        # page_capacity bounds retained window slices (CandidateRange
        # .window registers its gathers as pages of this store).
        self._ranges = FragmentStore(
            memo_capacity=64,
            page_capacity=256,
            max_rows=max(4 * triples.shape[0], 4096),
            weigh=lambda rng: rng.materialized_rows)

    def __len__(self) -> int:
        return int(self.triples.shape[0])

    @property
    def num_terms(self) -> int:
        return int(self.triples.max(initial=-1)) + 1

    # -- range-memo accounting (delegates to the fragment store) -------------

    @property
    def range_memo_hits(self) -> int:
        return self._ranges.hits

    @property
    def range_memo_misses(self) -> int:
        return self._ranges.misses

    @property
    def range_memo_cap(self) -> int:
        return self._ranges.memo_capacity

    @range_memo_cap.setter
    def range_memo_cap(self, value: int) -> None:
        self._ranges.memo_capacity = int(value)

    @property
    def range_memo_max_rows(self) -> Optional[int]:
        return self._ranges.max_rows

    @range_memo_max_rows.setter
    def range_memo_max_rows(self, value: Optional[int]) -> None:
        self._ranges.max_rows = value

    @property
    def _range_memo(self) -> dict:
        """{pattern_tuple -> CandidateRange} view of the memo."""
        return {key[0]: rng
                for key, rng in self._ranges.data_payloads().items()}

    # -- index selection ----------------------------------------------------

    @staticmethod
    def _choose_index(tp: TriplePattern) -> Tuple[str, int]:
        """Pick the index whose order has the longest bound prefix.

        Returns (index_name, prefix_len).
        """
        bound = [not is_var(c) for c in tp.as_tuple()]
        best_name, best_len = "spo", 0
        for name, order in _ORDERS.items():
            plen = 0
            for comp in order:
                if bound[comp]:
                    plen += 1
                else:
                    break
            if plen > best_len:
                best_name, best_len = name, plen
        return best_name, best_len

    def _prefix_range(self, tp: TriplePattern) -> Tuple[str, int, int, int]:
        """(index, lo, hi, prefix_len) of the candidate range for ``tp``."""
        name, plen = self._choose_index(tp)
        idx = self._indexes[name]
        if plen == 0:
            return name, 0, int(idx.keys.shape[0]), 0
        comps = tp.as_tuple()
        vals = [comps[idx.order[i]] for i in range(plen)]
        padded_lo = vals + [0] * (3 - plen)
        lo_key = int(
            _pack(np.int64(padded_lo[0]), np.int64(padded_lo[1]),
                  np.int64(padded_lo[2]))
        )
        padded_hi = vals + [_MAX_ID] * (3 - plen)
        # Python-int arithmetic: the all-MAX key is int64-max, +1 must not
        # wrap. searchsorted accepts python ints beyond int64 via 'right'
        # side on the exact hi key instead.
        hi_key = int(
            _pack(np.int64(padded_hi[0]), np.int64(padded_hi[1]),
                  np.int64(padded_hi[2]))
        )
        lo = int(np.searchsorted(idx.keys, lo_key, side="left"))
        hi = int(np.searchsorted(idx.keys, hi_key, side="right"))
        return name, lo, hi, plen

    # -- public API (the HDT-backend contract) ------------------------------

    def candidate_range(self, tp: TriplePattern) -> CandidateRange:
        """Lazy candidate range for ``tp`` (kernel / windowed input).

        The chosen index's bound-prefix range, in index order. Supersets
        the exact match set (non-prefix bound components and
        repeated-variable constraints are *not* applied here -- the
        bind-join/tpf-match kernels resolve those on device). No rows
        are gathered until ``.window()`` or ``.triples`` is read.
        """
        # Rows are pinned lazily (a consumer may have materialized
        # since the last access), so the fragment store re-enforces the
        # row bound on hits too -- the just-hit entry is LRU-newest,
        # never popped.
        key = (tp.as_tuple(), None)
        memo = self._ranges.get_data(key)
        if memo is not None:
            return memo
        name, lo, hi, plen = self._prefix_range(tp)
        idx = self._indexes[name]
        rng = CandidateRange(index=name, lo=lo, hi=hi, prefix_len=plen,
                             _store_triples=self.triples, _perm=idx.perm,
                             _fragments=self._ranges, _key=key)
        self._ranges.put_data(key, rng)
        return rng

    def evict_candidate_range(self, pattern_tuple: Tuple[int, int, int]
                              ) -> bool:
        """Drop a memoized candidate range (coherence hook fired by the
        server's fragment store when a pattern's last live fragment is
        evicted). Returns True if present."""
        return self._ranges.evict((pattern_tuple, None))

    def cardinality(self, tp: TriplePattern) -> int:
        """Cardinality estimate ``cnt`` (Definition 2).

        Exact when the bound components form a prefix of some index order
        (always true for 0, 1 bound, any 2-adjacent, or all 3); an upper
        bound (prefix-range size) otherwise. Satisfies cnt = 0 <=> empty
        for prefix patterns; for scan patterns cnt = 0 still implies empty.
        """
        _, lo, hi, plen = self._prefix_range(tp)
        est = hi - lo
        if est == 0:
            return 0
        if plen == tp.num_bound():
            # Bound components fully covered by the prefix: exact, unless
            # the pattern has a repeated variable (e.g. (?x, p, ?x)).
            if len(tp.variables()) == 3 - plen:
                return est
        # Fall back to an exact scan count (cheap at our scales; a real
        # HDT backend would return `est` here -- Definition 2 allows it).
        return int(self.match(tp).shape[0])

    def match(self, tp: TriplePattern) -> np.ndarray:
        """All matching triples for ``tp``, int32 [M, 3], sorted order
        of the chosen index (deterministic for paging).

        Routed through :meth:`candidate_range` so a range the memo
        already holds is not re-gathered (``cardinality``'s fallback
        scan previously double-paid the gather) and the reuse is counted
        in ``range_memo_hits``.
        """
        cand = self.candidate_range(tp).triples
        if cand.shape[0] == 0:
            return cand
        mask = np.ones(cand.shape[0], dtype=bool)
        # Residual constant constraints not covered by the prefix.
        for comp, c in enumerate(tp.as_tuple()):
            if not is_var(c):
                mask &= cand[:, comp] == c
        # Repeated-variable constraints (e.g. (?x, p, ?x)).
        comps = tp.as_tuple()
        for i in range(3):
            for j in range(i + 1, 3):
                if is_var(comps[i]) and comps[i] == comps[j]:
                    mask &= cand[:, i] == cand[:, j]
        return cand[mask]

    def match_range(self, tp: TriplePattern, offset: int,
                    limit: int) -> Tuple[np.ndarray, int]:
        """Paged matching: (page_triples, total_count).

        Deterministic given (tp, offset, limit) -- required for paging.
        """
        m = self.match(tp)
        return m[offset : offset + limit], int(m.shape[0])

    def contains(self, triple: np.ndarray) -> bool:
        t = np.asarray(triple, dtype=np.int32)
        key = int(_pack(t[0:1], t[1:2], t[2:3])[0])
        idx = self._indexes["spo"]
        pos = int(np.searchsorted(idx.keys, key, side="left"))
        return pos < idx.keys.shape[0] and int(idx.keys[pos]) == key


def store_from_ntriples(lines, dictionary) -> TripleStore:
    """Tiny N-Triples-ish loader for tests/examples: 's p o' per line."""
    rows = []
    for line in lines:
        line = line.strip().rstrip(".").strip()
        if not line or line.startswith("#"):
            continue
        s, p, o = line.split()[:3]
        rows.append([dictionary.intern(s), dictionary.intern(p),
                     dictionary.intern(o)])
    return TripleStore(np.asarray(rows, dtype=np.int32).reshape(-1, 3))
