"""In-memory triple store with HDT-style sorted indexes.

The paper's server queries an RDF-HDT backend: a compressed, in-memory
representation supporting (a) matching-triple streams for a triple pattern
and (b) O(1)-ish cardinality estimates. We reproduce that contract with
three sorted permutations of the dictionary-encoded triple array (SPO,
POS, OSP) and packed-int64 binary search:

* each triple ``(a, b, c)`` in a given component order is packed into a
  single int64 key ``a << 42 | b << 21 | c`` (21 bits per component,
  i.e. up to 2,097,151 distinct terms — far above our workloads);
* a pattern with a bound *prefix* of the chosen order maps to one
  contiguous key range -> two ``searchsorted`` calls give the exact match
  range *and* the exact cardinality, mirroring HDT;
* non-prefix bound components (e.g. ``(s, ?, o)``) are resolved by
  scanning the best prefix range with a vectorized mask; the advertised
  cardinality is then an *estimate* (the prefix-range size), which is
  precisely the ``cnt`` estimate with error eps that Definition 2 allows.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from .fragments import FragmentStore
from .rdf import TriplePattern, is_var

_BITS = 21
_MAX_ID = (1 << _BITS) - 1

# Component orders for the three indexes.
_ORDERS = {
    "spo": (0, 1, 2),
    "pos": (1, 2, 0),
    "osp": (2, 0, 1),
}


def _pack(a: np.ndarray, b: np.ndarray, c: np.ndarray) -> np.ndarray:
    return (
        a.astype(np.int64) << (2 * _BITS)
        | b.astype(np.int64) << _BITS
        | c.astype(np.int64)
    )


@dataclasses.dataclass
class _Index:
    order: Tuple[int, int, int]  # component order, e.g. (1, 2, 0) for POS
    keys: np.ndarray             # int64 [N], sorted packed keys
    perm: np.ndarray             # int32 [N], perm into the triple array


@dataclasses.dataclass
class CandidateRange:
    """The contiguous prefix range a pattern maps to in its chosen index.

    This is the store's device-facing contract: ``(index, lo, hi,
    prefix_len)`` identify the range for paging/accounting, and every
    triple matching the pattern -- or any instantiation of it -- lies in
    this range. The range is *lazy*: holding a ``CandidateRange`` (e.g.
    in the store's range memo) costs O(1), not O(hi - lo).

    ``window(page, size)`` gathers only ``perm[lo + page*size : ...]``
    -- the true range->page index: a page>0 request materializes just
    its window, never the whole range; gathered windows register as
    *pages* of the owning store's range fragment store (one bounded
    page layer, evicted coherently with the range entry itself), so a
    repeated window read never re-gathers. ``triples`` materializes the
    full block (index order, hence deterministic) for consumers that
    stream it in one HBM pass (the single-host bind-join kernel) and
    caches it, so repeated full reads through the memo gather once.
    """

    index: str                   # index name: "spo" | "pos" | "osp"
    lo: int                      # range start in the index
    hi: int                      # range end (exclusive)
    prefix_len: int              # bound components covered by the prefix
    _store_triples: np.ndarray = dataclasses.field(repr=False, default=None)
    _perm: np.ndarray = dataclasses.field(repr=False, default=None)
    _materialized: Optional[np.ndarray] = dataclasses.field(
        repr=False, default=None)
    # page-layer hookup: (fragment store, fragment key) of the memo
    # entry this range lives in -- set by TripleStore.candidate_range
    _fragments: Optional[object] = dataclasses.field(
        repr=False, default=None)
    _key: Optional[tuple] = dataclasses.field(repr=False, default=None)

    def __len__(self) -> int:
        return self.hi - self.lo

    def window(self, page: int, size: int) -> np.ndarray:
        """Rows ``[lo + page*size, min(lo + (page+1)*size, hi))`` of the
        range, int32 [<=size, 3], gathered without materializing the
        rest (unless the full block or this exact window is already
        cached)."""
        a = self.lo + page * size
        b = min(a + size, self.hi)
        if a >= b:
            return np.empty((0, 3), dtype=np.int32)
        if self._materialized is not None:
            return self._materialized[a - self.lo : b - self.lo]
        page_key = None
        if self._fragments is not None:
            page_key = (*self._key, (page, size))
            got = self._fragments.http_get(page_key)
            if got is not None:
                return got
        rows = self._store_triples[self._perm[a:b]]
        if page_key is not None:
            self._fragments.http_put(page_key, rows)
        return rows

    @property
    def triples(self) -> np.ndarray:
        """Full materialized block, int32 [hi - lo, 3] (cached)."""
        if self._materialized is None:
            self._materialized = \
                self._store_triples[self._perm[self.lo:self.hi]]
        return self._materialized

    @property
    def materialized_rows(self) -> int:
        """Rows this range actually pins (memo accounting unit)."""
        return 0 if self._materialized is None else len(self)

    @property
    def components(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Structure-of-arrays view (s, p, o) -- the kernel input layout."""
        t = self.triples
        return t[:, 0], t[:, 1], t[:, 2]


def prefix_interval_keys(comps: np.ndarray, order: Tuple[int, int, int],
                         plen: int) -> Tuple[np.ndarray, np.ndarray]:
    """Packed ``(lo_keys, hi_keys)`` of the length-``plen`` bound prefix
    of each pattern row in ``comps`` (int64 [K, 3]) under ``order``.

    The single source of the sub-range key derivation -- shared by
    :meth:`TripleStore.subranges` and the sharded planner
    (:meth:`~repro.core.federation.FederatedStore.plan_windows`), so the
    two backends cannot drift in how a binding maps to a key interval.
    Unbound tail positions fill with 0 / ``_MAX_ID``; ``searchsorted``
    left/right on the result gives the exact index interval.
    """
    lo_cols, hi_cols = [], []
    for i in range(3):
        if i < plen:
            col = comps[:, order[i]]
            lo_cols.append(col)
            hi_cols.append(col)
        else:
            lo_cols.append(np.zeros(comps.shape[0], np.int64))
            hi_cols.append(np.full(comps.shape[0], _MAX_ID, np.int64))
    return (_pack(lo_cols[0], lo_cols[1], lo_cols[2]),
            _pack(hi_cols[0], hi_cols[1], hi_cols[2]))


def merge_spans(bounds: np.ndarray) -> np.ndarray:
    """Merge per-binding ``(lo, hi)`` intervals into disjoint union spans.

    The union-merge rule of the pruned read path (docs/pruning.md):
    drop empty intervals, sort by ``lo``, and coalesce overlapping *or
    adjacent* intervals -- the result is the minimal sorted sequence of
    disjoint ``[lo, hi)`` spans covering exactly the union. Disjointness
    is what makes the pruned candidate block duplicate-free within one
    index (each row position appears in at most one span).
    """
    bounds = np.asarray(bounds, dtype=np.int64).reshape(-1, 2)
    bounds = bounds[bounds[:, 1] > bounds[:, 0]]
    if bounds.shape[0] == 0:
        return np.empty((0, 2), dtype=np.int64)
    bounds = bounds[np.argsort(bounds[:, 0], kind="stable")]
    merged: List[List[int]] = [[int(bounds[0, 0]), int(bounds[0, 1])]]
    for lo, hi in bounds[1:]:
        if lo <= merged[-1][1]:                 # overlap or adjacency
            merged[-1][1] = max(merged[-1][1], int(hi))
        else:
            merged.append([int(lo), int(hi)])
    return np.asarray(merged, dtype=np.int64)


@dataclasses.dataclass
class SpanGroup:
    """Sub-ranges of one index for one uniform instantiation shape."""

    index: str                   # index name: "spo" | "pos" | "osp"
    prefix_len: int              # bound prefix length of the shape
    bounds: np.ndarray           # int64 [K, 2] per-binding (lo, hi)
    spans: np.ndarray            # int64 [S, 2] merged disjoint union

    @property
    def rows(self) -> int:
        if self.spans.shape[0] == 0:
            return 0
        return int((self.spans[:, 1] - self.spans[:, 0]).sum())


@dataclasses.dataclass
class SubRanges:
    """Omega-restricted candidate sub-ranges for one request.

    Each distinct binding attached to a brTPF request instantiates a
    *more-bound* pattern whose matches occupy a contiguous key range of
    some index order -- so the union of those per-binding ``(lo, hi)``
    sub-ranges covers every triple that can join with the attached
    intermediate result, and everything outside the union is provably
    join-irrelevant. ``groups`` holds one :class:`SpanGroup` per uniform
    instantiation shape (mappings with different bound-variable sets
    instantiate differently-shaped patterns, each with its own best
    index); ``rows`` is the pre-dedup union size, the quantity selector
    backends compare against the full prefix range to decide whether
    pruning pays.
    """

    pattern: Tuple[int, int, int]
    groups: List[SpanGroup]

    @property
    def rows(self) -> int:
        return sum(g.rows for g in self.groups)

    def page_key(self) -> tuple:
        """Stable page-layer key for the pruned row set: pruned
        selections memoize independently of full-range reads (and of
        each other -- distinct span unions get distinct keys)."""
        return ("pruned",) + tuple(
            (g.index, g.spans.tobytes()) for g in self.groups)


class TripleStore:
    """Sorted-index triple store over ``int32 [N, 3]`` triples."""

    def __init__(self, triples: np.ndarray) -> None:
        triples = np.asarray(triples, dtype=np.int32).reshape(-1, 3)
        # Set semantics: an RDF graph is a set of triples.
        if triples.shape[0] > 0:
            triples = np.unique(triples, axis=0)
            if int(triples.max(initial=0)) > _MAX_ID:
                raise ValueError("term id exceeds 21-bit packing limit")
            if int(triples.min(initial=0)) < 0:
                raise ValueError("data triples must not contain variables")
        self.triples = triples
        self._indexes = {}
        for name, order in _ORDERS.items():
            keys = _pack(
                triples[:, order[0]], triples[:, order[1]], triples[:, order[2]]
            )
            perm = np.argsort(keys, kind="stable").astype(np.int32)
            self._indexes[name] = _Index(order, keys[perm], perm)
        # Per-pattern candidate-range memo (ROADMAP "Kernel-path TPF
        # paging"): materializing ``triples[perm[lo:hi]]`` is the
        # expensive part of a range read -- a gather over a range that
        # can span the whole store. Ranges are lazy, so a memo entry is
        # O(1) until some consumer materializes its full block; the
        # store is immutable, so the memo never goes stale; the server
        # evicts it coherently with its unified fragment store (its
        # ``on_release`` hook calls :meth:`evict_candidate_range`).
        # The memo itself is a FragmentStore data layer keyed
        # ``(pattern_tuple, None)`` with a materialized-rows weigher:
        # broad patterns can materialize near-store-sized copies, so
        # the memo is bounded by retained ROWS as well as entries (64
        # low-selectivity ranges must not pin ~64x the store; the
        # newest entry is always kept).
        # page_capacity bounds retained window slices (CandidateRange
        # .window registers its gathers as pages of this store).
        self._ranges = FragmentStore(
            memo_capacity=64,
            page_capacity=256,
            max_rows=max(4 * triples.shape[0], 4096),
            weigh=lambda rng: rng.materialized_rows)

    def __len__(self) -> int:
        return int(self.triples.shape[0])

    @property
    def num_terms(self) -> int:
        return int(self.triples.max(initial=-1)) + 1

    # -- range-memo accounting (delegates to the fragment store) -------------

    @property
    def range_memo_hits(self) -> int:
        return self._ranges.hits

    @property
    def range_memo_misses(self) -> int:
        return self._ranges.misses

    @property
    def range_memo_cap(self) -> int:
        return self._ranges.memo_capacity

    @range_memo_cap.setter
    def range_memo_cap(self, value: int) -> None:
        self._ranges.memo_capacity = int(value)

    @property
    def range_memo_max_rows(self) -> Optional[int]:
        return self._ranges.max_rows

    @range_memo_max_rows.setter
    def range_memo_max_rows(self, value: Optional[int]) -> None:
        self._ranges.max_rows = value

    @property
    def _range_memo(self) -> dict:
        """{pattern_tuple -> CandidateRange} view of the memo."""
        return {key[0]: rng
                for key, rng in self._ranges.data_payloads().items()}

    # -- index selection ----------------------------------------------------

    @staticmethod
    def _choose_index(tp: TriplePattern) -> Tuple[str, int]:
        """Pick the index whose order has the longest bound prefix.

        Returns (index_name, prefix_len).
        """
        bound = [not is_var(c) for c in tp.as_tuple()]
        best_name, best_len = "spo", 0
        for name, order in _ORDERS.items():
            plen = 0
            for comp in order:
                if bound[comp]:
                    plen += 1
                else:
                    break
            if plen > best_len:
                best_name, best_len = name, plen
        return best_name, best_len

    def _prefix_range(self, tp: TriplePattern) -> Tuple[str, int, int, int]:
        """(index, lo, hi, prefix_len) of the candidate range for ``tp``."""
        name, plen = self._choose_index(tp)
        idx = self._indexes[name]
        if plen == 0:
            return name, 0, int(idx.keys.shape[0]), 0
        comps = tp.as_tuple()
        vals = [comps[idx.order[i]] for i in range(plen)]
        padded_lo = vals + [0] * (3 - plen)
        lo_key = int(
            _pack(np.int64(padded_lo[0]), np.int64(padded_lo[1]),
                  np.int64(padded_lo[2]))
        )
        padded_hi = vals + [_MAX_ID] * (3 - plen)
        # Python-int arithmetic: the all-MAX key is int64-max, +1 must not
        # wrap. searchsorted accepts python ints beyond int64 via 'right'
        # side on the exact hi key instead.
        hi_key = int(
            _pack(np.int64(padded_hi[0]), np.int64(padded_hi[1]),
                  np.int64(padded_hi[2]))
        )
        lo = int(np.searchsorted(idx.keys, lo_key, side="left"))
        hi = int(np.searchsorted(idx.keys, hi_key, side="right"))
        return name, lo, hi, plen

    # -- public API (the HDT-backend contract) ------------------------------

    def candidate_range(self, tp: TriplePattern,
                        memoize: bool = True) -> CandidateRange:
        """Lazy candidate range for ``tp`` (kernel / windowed input).

        The chosen index's bound-prefix range, in index order. Supersets
        the exact match set (non-prefix bound components and
        repeated-variable constraints are *not* applied here -- the
        bind-join/tpf-match kernels resolve those on device). No rows
        are gathered until ``.window()`` or ``.triples`` is read.

        ``memoize=False`` is the *probe* path (``cardinality`` fallback
        scans and other one-shot estimates): a memoized range is still
        reused -- and counted as a hit -- but an absent one is built
        without inserting a memo entry and without charging a miss, so
        probe traffic can neither churn the LRU nor distort the memo's
        hit/miss accounting (the streaming read paths are what the
        range-memo metrics describe).
        """
        # Rows are pinned lazily (a consumer may have materialized
        # since the last access), so the fragment store re-enforces the
        # row bound on hits too -- the just-hit entry is LRU-newest,
        # never popped.
        key = (tp.as_tuple(), None)
        memo = self._ranges.get_data(key, count_miss=memoize)
        if memo is not None:
            return memo
        name, lo, hi, plen = self._prefix_range(tp)
        idx = self._indexes[name]
        rng = CandidateRange(index=name, lo=lo, hi=hi, prefix_len=plen,
                             _store_triples=self.triples, _perm=idx.perm,
                             _fragments=self._ranges if memoize else None,
                             _key=key if memoize else None)
        if memoize:
            self._ranges.put_data(key, rng)
        return rng

    def evict_candidate_range(self, pattern_tuple: Tuple[int, int, int]
                              ) -> bool:
        """Drop a memoized candidate range (coherence hook fired by the
        server's fragment store when a pattern's last live fragment is
        evicted). Returns True if present."""
        return self._ranges.evict((pattern_tuple, None))

    # -- Omega-restricted candidate pruning (docs/pruning.md) ----------------

    def subranges(self, tp: TriplePattern, omega: Optional[np.ndarray] = None,
                  insts: Optional[List[TriplePattern]] = None,
                  ) -> Optional[SubRanges]:
        """Per-binding candidate sub-ranges for an Omega-restricted read.

        Each distinct binding value instantiates a more-bound pattern;
        when the instantiated shape has a longer bound prefix in some
        index order, its matches occupy one contiguous key range there.
        This batches the derivation: the packed ``(lo, hi)`` prefix keys
        of ALL distinct bindings of a shape are searchsorted against the
        index's int64 key array in one vectorized call each, and the
        resulting intervals are union-merged into disjoint spans
        (:func:`merge_spans`). Streaming only the merged union is exact:
        every triple matching any instantiated pattern lies inside that
        pattern's sub-range, so rows outside the union are guaranteed
        join-irrelevant (the paper's "only triples that contribute to
        the join" server promise, enforced on the read side).

        ``insts`` may carry the already-instantiated (deduped) pattern
        list -- the server computes it for lookup accounting. Returns
        ``None`` when pruning cannot narrow anything: no instantiation
        binds a prefix position (e.g. empty Omega, or mappings that
        leave the pattern's shape unchanged).
        """
        if insts is None:
            from .selectors import instantiate_patterns
            insts = instantiate_patterns(tp, omega)
        if not insts:
            return None
        shapes: "dict[tuple, List[TriplePattern]]" = {}
        for p in insts:
            mask = tuple(is_var(c) for c in p.as_tuple())
            shapes.setdefault(mask, []).append(p)
        groups: List[SpanGroup] = []
        for pats in shapes.values():
            name, plen = self._choose_index(pats[0])
            if plen == 0:
                # Some instantiation is fully unbound: its sub-range is
                # the whole store, nothing can be pruned.
                return None
            order = self._indexes[name].order
            comps = np.asarray([p.as_tuple() for p in pats],
                               dtype=np.int64)               # [K, 3]
            lo_keys, hi_keys = prefix_interval_keys(comps, order, plen)
            keys = self._indexes[name].keys
            los = np.searchsorted(keys, lo_keys, side="left")
            his = np.searchsorted(keys, hi_keys, side="right")
            bounds = np.stack([los, his], axis=1).astype(np.int64)
            groups.append(SpanGroup(index=name, prefix_len=plen,
                                    bounds=bounds,
                                    spans=merge_spans(bounds)))
        return SubRanges(pattern=tp.as_tuple(), groups=groups)

    def gather_subranges(self, sr: SubRanges) -> np.ndarray:
        """Materialize the pruned candidate row set, int32 [U, 3].

        One gather per span group; span disjointness within an index
        guarantees no duplicates per group, and a cross-group
        ``np.unique`` dedups the (rare) multi-shape case where two
        indexes surface the same physical triple -- the selector
        epilogues require each candidate triple to appear exactly once.
        Row order is arbitrary by contract (the selectors' stream-order
        epilogue re-sorts kept rows), which is what lets the pruned and
        full-range paths stay byte-identical.

        Gathered row sets register as pages of the owning pattern's
        range-memo entry (keyed by :meth:`SubRanges.page_key`), so a
        repeated pruned read never re-gathers and is evicted coherently
        with the pattern's other fragments.
        """
        key = (sr.pattern, None, sr.page_key())
        got = self._ranges.http_get(key)
        if got is not None:
            return got
        blocks = []
        for g in sr.groups:
            if g.spans.shape[0] == 0:
                continue
            perm = self._indexes[g.index].perm
            idxs = np.concatenate([perm[lo:hi] for lo, hi in g.spans])
            blocks.append(self.triples[idxs])
        if not blocks:
            rows = np.empty((0, 3), dtype=np.int32)
        else:
            rows = np.concatenate(blocks, axis=0)
            if len(sr.groups) > 1:
                rows = np.unique(rows, axis=0)
        self._ranges.http_put(key, rows)
        return rows

    def cardinality(self, tp: TriplePattern) -> int:
        """Cardinality estimate ``cnt`` (Definition 2).

        Exact when the bound components form a prefix of some index order
        (always true for 0, 1 bound, any 2-adjacent, or all 3); an upper
        bound (prefix-range size) otherwise. Satisfies cnt = 0 <=> empty
        for prefix patterns; for scan patterns cnt = 0 still implies empty.
        """
        _, lo, hi, plen = self._prefix_range(tp)
        est = hi - lo
        if est == 0:
            return 0
        if plen == tp.num_bound():
            # Bound components fully covered by the prefix: exact, unless
            # the pattern has a repeated variable (e.g. (?x, p, ?x)).
            if len(tp.variables()) == 3 - plen:
                return est
        # Fall back to an exact scan count (cheap at our scales; a real
        # HDT backend would return `est` here -- Definition 2 allows it).
        # Probe path: reuse a memoized range (counted as a hit) but
        # never insert/charge one -- cardinality estimates must not
        # churn the streaming memo.
        return int(self.match(tp, memoize=False).shape[0])

    def match(self, tp: TriplePattern,
              memoize: bool = True) -> np.ndarray:
        """All matching triples for ``tp``, int32 [M, 3], sorted order
        of the chosen index (deterministic for paging).

        Routed through :meth:`candidate_range` so a range the memo
        already holds is not re-gathered (``cardinality``'s fallback
        scan previously double-paid the gather) and the reuse is counted
        in ``range_memo_hits``.
        """
        cand = self.candidate_range(tp, memoize=memoize).triples
        if cand.shape[0] == 0:
            return cand
        mask = np.ones(cand.shape[0], dtype=bool)
        # Residual constant constraints not covered by the prefix.
        for comp, c in enumerate(tp.as_tuple()):
            if not is_var(c):
                mask &= cand[:, comp] == c
        # Repeated-variable constraints (e.g. (?x, p, ?x)).
        comps = tp.as_tuple()
        for i in range(3):
            for j in range(i + 1, 3):
                if is_var(comps[i]) and comps[i] == comps[j]:
                    mask &= cand[:, i] == cand[:, j]
        return cand[mask]

    def match_range(self, tp: TriplePattern, offset: int,
                    limit: int) -> Tuple[np.ndarray, int]:
        """Paged matching: (page_triples, total_count).

        Deterministic given (tp, offset, limit) -- required for paging.
        """
        m = self.match(tp)
        return m[offset : offset + limit], int(m.shape[0])

    def contains(self, triple: np.ndarray) -> bool:
        t = np.asarray(triple, dtype=np.int32)
        key = int(_pack(t[0:1], t[1:2], t[2:3])[0])
        idx = self._indexes["spo"]
        pos = int(np.searchsorted(idx.keys, key, side="left"))
        return pos < idx.keys.shape[0] and int(idx.keys[pos]) == key


def store_from_ntriples(lines, dictionary) -> TripleStore:
    """Tiny N-Triples-ish loader for tests/examples: 's p o' per line."""
    rows = []
    for line in lines:
        line = line.strip().rstrip(".").strip()
        if not line or line.startswith("#"):
            continue
        s, p, o = line.split()[:3]
        rows.append([dictionary.intern(s), dictionary.intern(p),
                     dictionary.intern(o)])
    return TripleStore(np.asarray(rows, dtype=np.int32).reshape(-1, 3))
