"""Multi-client throughput simulation (paper sections 6 and 7.2).

The paper drives one server with up to 64 concurrent clients on a 17-
machine cluster. This container has one CPU core, so raw wall-clock
concurrency is impossible -- instead we use *trace replay*: every query
is executed once, for real, through the actual server/client code, and
the per-request records (server work, bytes returned, client join work)
are replayed through a discrete-event queueing model of the cluster:

  client --(latency/2)--> [server: k workers, FIFO] --(latency/2 +
       bytes/bandwidth)--> client-side join work --> next request

The optional shared HTTP cache (section 7.2) is replayed *inside* the
simulation -- hits depend on the global interleaving of all clients'
requests, exactly like the paper's nginx proxy. Service-time constants
are calibrated by timing the real engine on this machine
(``calibrate()``), so the simulated seconds are grounded in measured
per-triple and per-request costs.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .bgp import BGP
from .cache import LRUCache
from .client import BrTPFClient, TPFClient
from .server import BrTPFServer


# ---------------------------------------------------------------------------
# Trace collection
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HttpRecord:
    key: tuple
    lookups: int
    scanned: int
    recv: int


@dataclasses.dataclass
class QueryTrace:
    """Ordered per-query event list: HttpRecord | ('join', units)."""
    name: str
    events: List[object]
    completed: bool   # completed during trace collection (budget not hit)


class _Recorder:
    def __init__(self) -> None:
        self.events: List[object] = []

    def __call__(self, kind: str, payload) -> None:
        if kind == "http":
            self.events.append(HttpRecord(**payload))
        elif kind == "join":
            self.events.append(("join", int(payload)))


def collect_traces(server: BrTPFServer, workload: Sequence[Tuple[str, BGP]],
                   client_kind: str, max_mpr: Optional[int] = None,
                   request_budget: int = 20000) -> List[QueryTrace]:
    """Execute the workload once through the real engine, recording
    per-request traces. ``client_kind``: 'tpf' | 'brtpf'."""
    traces: List[QueryTrace] = []
    for name, bgp in workload:
        rec = _Recorder()
        if client_kind == "tpf":
            client = TPFClient(server, request_budget=request_budget,
                               tick=rec)
        else:
            client = BrTPFClient(server, max_mpr=max_mpr,
                                 request_budget=request_budget, tick=rec)
        res = client.execute(bgp)
        traces.append(QueryTrace(name, rec.events, not res.timed_out))
    return traces


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SimParams:
    server_workers: int = 4            # paper: 4-core server machine
    req_overhead_s: float = 1.0e-3     # servlet + HTTP handling per request
    lookup_s: float = 2.0e-4           # per instantiated-pattern index probe
    scan_s_per_triple: float = 1.5e-6  # serialization + backend scan
    cache_hit_s: float = 2.0e-4        # nginx hit service time
    client_overhead_s: float = 2.0e-4  # per-request client bookkeeping
    join_s_per_triple: float = 4.0e-6  # client-side hash-join per triple
    net_latency_s: float = 1.0e-3      # one-way LAN latency
    bytes_per_triple: float = 120.0    # serialized triple size
    bandwidth_bps: float = 10e9 / 8    # 10 GbE
    timeout_s: float = 300.0           # the paper's 5-minute timeout
    duration_s: float = 3600.0         # measure throughput over one hour
    # both paper clients issue HTTP requests asynchronously in parallel
    # (section 6.3); latency/client overhead amortize over the window
    pipeline_depth: int = 8
    max_events: int = 4_000_000        # replay safety valve


def calibrate(server: BrTPFServer, workload, reps: int = 3) -> SimParams:
    """Ground the cost model in measured engine timings on this host."""
    from .rdf import TriplePattern, encode_var
    store = server.store
    v = encode_var
    # time a representative scan-heavy pattern
    tp = TriplePattern(v(0), v(1), v(2))
    t0 = time.perf_counter()
    n = 0
    for _ in range(reps):
        n += store.match(tp).shape[0]
    scan_s = (time.perf_counter() - t0) / max(n, 1)
    # time index probes (fully bound patterns)
    probe = TriplePattern(1, 2, 3)
    t0 = time.perf_counter()
    for _ in range(200):
        store.cardinality(probe)
    lookup_s = (time.perf_counter() - t0) / 200
    p = SimParams()
    p.scan_s_per_triple = max(scan_s, 1e-8)
    p.lookup_s = max(lookup_s, 1e-7)
    p.join_s_per_triple = 2.5 * p.scan_s_per_triple  # joins touch each
    return p                                         # triple a few times


# ---------------------------------------------------------------------------
# Discrete-event replay
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SimResult:
    completed: int
    timeouts: int
    attempted: int
    qet_sum: float            # total QET of completed queries
    qets: List[float]
    simulated_s: float = 3600.0   # horizon actually replayed

    @property
    def throughput_per_hour(self) -> float:
        return self.completed * 3600.0 / max(self.simulated_s, 1e-9)

    @property
    def attempts_per_hour(self) -> float:
        return self.attempted * 3600.0 / max(self.simulated_s, 1e-9)

    @property
    def avg_qet(self) -> float:
        return self.qet_sum / self.completed if self.completed else 0.0


class _Server:
    """k identical workers + FIFO queue."""

    def __init__(self, workers: int) -> None:
        self.free_at = [0.0] * workers

    def schedule(self, arrival: float, service: float) -> float:
        """Returns completion time; assigns the earliest-free worker."""
        i = int(np.argmin(self.free_at))
        start = max(arrival, self.free_at[i])
        done = start + service
        self.free_at[i] = done
        return done


@dataclasses.dataclass
class _ClientState:
    qi: int = 0                 # index into the client's query sequence
    ev: int = 0                 # next event within the current query
    query_start: float = 0.0
    timed_out: bool = False


def simulate(traces_per_client: Sequence[Sequence[QueryTrace]],
             params: SimParams,
             cache_size: Optional[int] = None,
             use_cache: bool = False,
             wrap: bool = False) -> SimResult:
    """Replay per-client query streams through the queueing model.

    Event-granular interleaving: the heap orders *individual requests*
    across all clients, so server FIFO contention and shared-cache state
    evolve in global time order, as they would on the paper's cluster.
    Clients restart their sequence if they exhaust it before the hour is
    up (the paper's per-core 193-query sequences were sized not to).
    """
    server = _Server(params.server_workers)
    cache = LRUCache(cache_size) if use_cache else None
    completed = timeouts = attempted = 0
    qet_sum = 0.0
    qets: List[float] = []

    states = [_ClientState() for _ in traces_per_client]
    heap: List[Tuple[float, int]] = [(0.0, ci)
                                     for ci in range(len(states))]
    heapq.heapify(heap)
    events = 0
    frontier = 0.0

    while heap:
        t, ci = heapq.heappop(heap)
        frontier = max(frontier, min(t, params.duration_s))
        if t >= params.duration_s:
            continue
        st = states[ci]
        traces = traces_per_client[ci]
        trace = traces[st.qi % len(traces)]

        if st.ev == 0:
            st.query_start = t
            st.timed_out = not trace.completed  # budget-truncated trace

        # Query finished (all events done, or timeout crossed)?
        over = t - st.query_start > params.timeout_s
        if st.ev >= len(trace.events) or st.timed_out or over:
            if st.timed_out or over:
                t = min(t, st.query_start + params.timeout_s)
                if t <= params.duration_s:
                    timeouts += 1
                    attempted += 1
            else:
                completed += 1
                attempted += 1
                qet_sum += t - st.query_start
                qets.append(t - st.query_start)
            st.qi += 1
            st.ev = 0
            st.timed_out = False
            # per-execution client restart (the paper restarts the client
            # process between executions); also guarantees time progress
            t += 0.01
            if st.qi < len(traces) or wrap:
                heapq.heappush(heap, (t, ci))
            continue

        ev = trace.events[st.ev]
        st.ev += 1
        depth = max(params.pipeline_depth, 1)
        if isinstance(ev, HttpRecord):
            t += params.net_latency_s / depth
            hit = False
            if cache is not None:
                hit = cache.get(ev.key) is not None
                if not hit:
                    cache.put(ev.key, True)
            if hit:
                t += params.cache_hit_s
            else:
                service = (params.req_overhead_s
                           + ev.lookups * params.lookup_s
                           + ev.scanned * params.scan_s_per_triple)
                t = server.schedule(t, service)
            t += (params.net_latency_s / depth
                  + ev.recv * params.bytes_per_triple
                  / params.bandwidth_bps)
            t += params.client_overhead_s / depth
        else:  # ('join', units)
            t += ev[1] * params.join_s_per_triple
        heapq.heappush(heap, (t, ci))
        events += 1
        if events > params.max_events:
            break

    simulated = (params.duration_s if events <= params.max_events
                 else frontier)
    return SimResult(completed, timeouts, attempted, qet_sum, qets,
                     simulated_s=max(simulated, 1e-9))


def split_workload(workload, num_clients: int):
    """Partition the workload into per-client disjoint sequences
    (the paper splits 12,400 queries into 64 distinct sets)."""
    per = max(1, len(workload) // num_clients)
    return [workload[i * per:(i + 1) * per] or workload[:per]
            for i in range(num_clients)]
