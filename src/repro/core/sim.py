"""Multi-client throughput simulation (paper sections 6 and 7.2).

The paper drives one server with up to 64 concurrent clients on a 17-
machine cluster. This container has one CPU core, so raw wall-clock
concurrency is impossible -- instead we use *trace replay*: every query
is executed once, for real, through the actual server/client code, and
the per-request records (server work, bytes returned, client join work)
are replayed through a discrete-event queueing model of the cluster:

  client --(latency/2)--> [server: k workers, FIFO] --(latency/2 +
       bytes/bandwidth)--> client-side join work --> next request

The optional shared HTTP cache (section 7.2) is replayed *inside* the
simulation -- hits depend on the global interleaving of all clients'
requests, exactly like the paper's nginx proxy. Service-time constants
are calibrated by timing the real engine on this machine
(``calibrate()``), so the simulated seconds are grounded in measured
per-triple and per-request costs.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .bgp import BGP
from .cache import LRUCache
from .client import BrTPFClient, TPFClient
from .config import ServerConfig
from .server import BrTPFServer


# ---------------------------------------------------------------------------
# Trace collection
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HttpRecord:
    key: tuple
    lookups: int
    scanned: int
    recv: int
    # kernel-backend launch geometry (zero for numpy-backend traces):
    # ``cand`` padded candidates streamed (summed over this request's
    # launches; on the sharded backend each launch streams one per-shard
    # window, so cand = launches * window), ``pats`` padded pattern
    # slots of this request's launch share, ``launches`` how many kernel
    # launches the request triggered (1 on the single-host kernel path;
    # the per-shard window-page count on the sharded path);
    # ``pattern_key`` identifies requests that can share one candidate
    # stream under cross-request batching.
    pattern_key: tuple = ()
    cand: int = 0
    pats: int = 0
    launches: int = 0
    # raw (pre-padding) candidate rows behind ``cand``: the fused-launch
    # model re-pads these at FUSED_BT tile granularity, which is how the
    # real fused stream is laid out (solo launches pad to a pow2 shape
    # bucket instead). 0 on old traces -> fall back to ``cand``.
    cand_rows: int = 0
    # raw full-range rows: when a batch's combined sub-range union
    # reaches this, pruning stops paying and the launch streams the full
    # range -- the cap on the model's additive union estimate.
    cand_full_rows: int = 0
    # per-shard planned-window-page delta (sharded backend only; empty
    # tuple otherwise / on old traces): the shard-heat model replays it
    # so --live can validate per-shard launch counts after a
    # workload-aware repartition (docs/federation.md, "Placement").
    shard_pages: tuple = ()


@dataclasses.dataclass
class QueryTrace:
    """Ordered per-query event list: HttpRecord | ('join', units)."""
    name: str
    events: List[object]
    completed: bool   # completed during trace collection (budget not hit)


class _Recorder:
    def __init__(self) -> None:
        self.events: List[object] = []

    def __call__(self, kind: str, payload) -> None:
        if kind == "http":
            self.events.append(HttpRecord(**payload))
        elif kind == "join":
            self.events.append(("join", int(payload)))


def collect_traces(server: BrTPFServer, workload: Sequence[Tuple[str, BGP]],
                   client_kind: str, max_mpr: Optional[int] = None,
                   request_budget: int = 20000) -> List[QueryTrace]:
    """Execute the workload once through the real engine, recording
    per-request traces. ``client_kind``: 'tpf' | 'brtpf'."""
    traces: List[QueryTrace] = []
    for name, bgp in workload:
        rec = _Recorder()
        if client_kind == "tpf":
            client = TPFClient(server, request_budget=request_budget,
                               tick=rec)
        else:
            client = BrTPFClient(server, max_mpr=max_mpr,
                                 request_budget=request_budget, tick=rec)
        res = client.execute(bgp)
        traces.append(QueryTrace(name, rec.events, not res.timed_out))
    return traces


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

# fused stream tile size -- mirrors DEFAULT_FUSED_BT in kernels/ops.py:
# a fused launch's candidate stream is laid out in bt-row tiles (one
# segment per tile) and padded to a power-of-two tile count.
_FUSED_BT = 256


def _pow2_at_least(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


@dataclasses.dataclass
class SimParams:
    server_workers: int = 4            # paper: 4-core server machine
    req_overhead_s: float = 1.0e-3     # servlet + HTTP handling per request
    lookup_s: float = 2.0e-4           # per instantiated-pattern index probe
    scan_s_per_triple: float = 1.5e-6  # serialization + backend scan
    cache_hit_s: float = 2.0e-4        # nginx hit service time
    client_overhead_s: float = 2.0e-4  # per-request client bookkeeping
    join_s_per_triple: float = 4.0e-6  # client-side hash-join per triple
    net_latency_s: float = 1.0e-3      # one-way LAN latency
    bytes_per_triple: float = 120.0    # serialized triple size
    bandwidth_bps: float = 10e9 / 8    # 10 GbE
    timeout_s: float = 300.0           # the paper's 5-minute timeout
    duration_s: float = 3600.0         # measure throughput over one hour
    # both paper clients issue HTTP requests asynchronously in parallel
    # (section 6.3); latency/client overhead amortize over the window
    pipeline_depth: int = 8
    max_events: int = 4_000_000        # replay safety valve
    # -- kernel selector backend (TPU projection) ---------------------------
    # Used for requests whose trace carries launch geometry (cand > 0).
    # Defaults project a TPU core: ~1e11 int32 compare cells/s on the
    # (8 x 128) VPU, ~1 TB/s effective HBM for the 12 B/triple candidate
    # stream, and a fixed per-launch dispatch overhead. The numbers scale
    # the comparison, not its direction (kernel >> per-pattern scan).
    kernel_launch_overhead_s: float = 2.0e-5
    kernel_cell_s: float = 1.0e-11       # per compare-grid cell
    kernel_stream_s: float = 1.2e-11     # per candidate triple streamed
    # > 0 enables server-side cross-request batching: same-pattern
    # requests arriving while a launch is still queued share its
    # candidate stream and pay only their marginal pattern-slot cells.
    batch_window_s: float = 0.0
    # cross-pattern kernel fusion (docs/fusion.md): with batching on, a
    # request whose pattern DIFFERS from the open launch's still joins
    # it -- as a new fused segment that brings its own candidate stream
    # (same-pattern joiners share an existing segment's stream and add
    # none). Caps mirror ``fusion_legality`` in core/kernel_selectors.py:
    # a launch refuses new segments past the segment/stream ceilings.
    fuse_patterns: bool = True
    fused_max_segments: int = 16      # MAX_FUSED_SEGMENTS
    fused_max_stream: int = 131072    # MAX_FUSED_STREAM (candidate rows)
    # unified fragment store (core/fragments.py): a kernel-path request
    # whose fragment was computed by an EARLIER request (and whose
    # launch is no longer joinable) skips its launch entirely -- it is
    # served from the memo at servlet overhead. Mirrors the real
    # server's memo-capacity LRU.
    selector_memo_entries: int = 256


def calibrate(server: BrTPFServer, workload, reps: int = 3) -> SimParams:
    """Ground the cost model in measured engine timings on this host."""
    from .rdf import TriplePattern, encode_var
    store = server.store
    v = encode_var
    # time a representative scan-heavy pattern
    tp = TriplePattern(v(0), v(1), v(2))
    t0 = time.perf_counter()
    n = 0
    for _ in range(reps):
        n += store.match(tp).shape[0]
    scan_s = (time.perf_counter() - t0) / max(n, 1)
    # time index probes (fully bound patterns)
    probe = TriplePattern(1, 2, 3)
    t0 = time.perf_counter()
    for _ in range(200):
        store.cardinality(probe)
    lookup_s = (time.perf_counter() - t0) / 200
    p = SimParams()
    p.scan_s_per_triple = max(scan_s, 1e-8)
    p.lookup_s = max(lookup_s, 1e-7)
    p.join_s_per_triple = 2.5 * p.scan_s_per_triple  # joins touch each
    return p                                         # triple a few times


# ---------------------------------------------------------------------------
# Discrete-event replay
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SimResult:
    completed: int
    timeouts: int
    attempted: int
    qet_sum: float            # total QET of completed queries
    qets: List[float]
    simulated_s: float = 3600.0   # horizon actually replayed
    # kernel-backend replay only: launches *created* (a request joining
    # an open same-pattern launch inside the batching window does not
    # create one) and kernel-path requests replayed -- the pair the live
    # validation loop (``live_replay``) checks against the real front end.
    launches: int = 0
    kernel_requests: int = 0
    # launches avoided because the request's fragment was resident in
    # the modeled unified store (memo or shared HTTP cache) -- the
    # third quantity live_replay validates.
    launches_skipped: int = 0
    # cross-pattern fusion shape (mirrors Counters.fused_launches /
    # fused_segments): launches that ended up serving >= 2 distinct
    # pattern keys, and the total distinct keys across those launches.
    fused_launches: int = 0
    fused_segments: int = 0
    # candidate rows streamed by created launches (requests that join an
    # open launch share its stream and add none; skipped requests stream
    # nothing). Traces collected against a pruning server already carry
    # the pruned per-request stream in ``HttpRecord.cand``, so this is
    # the model's Omega-restricted streaming total -- the fourth
    # quantity live_replay validates.
    cand_streamed: int = 0
    # raw (pre-padding) candidate rows behind cand_streamed. Additive
    # across requests, so -- unlike the padded total, whose pow2/tile
    # padding depends on how requests regrouped into launches -- this is
    # invariant under batching composition and is the tighter live
    # validation quantity.
    cand_rows: int = 0
    # per-shard planned-window-page totals accumulated from created
    # launches' HttpRecord.shard_pages deltas (sharded traces only;
    # empty otherwise) -- the shard-heat model --live validates per
    # shard (docs/federation.md, "Placement").
    shard_launches: tuple = ()

    @property
    def launches_per_request(self) -> float:
        return self.launches / max(self.kernel_requests, 1)

    @property
    def cand_per_request(self) -> float:
        return self.cand_streamed / max(self.kernel_requests, 1)

    @property
    def skips_per_request(self) -> float:
        return self.launches_skipped / max(self.kernel_requests, 1)

    @property
    def fused_segments_per_launch(self) -> float:
        return self.fused_segments / max(self.fused_launches, 1)

    @property
    def throughput_per_hour(self) -> float:
        return self.completed * 3600.0 / max(self.simulated_s, 1e-9)

    @property
    def attempts_per_hour(self) -> float:
        return self.attempted * 3600.0 / max(self.simulated_s, 1e-9)

    @property
    def avg_qet(self) -> float:
        return self.qet_sum / self.completed if self.completed else 0.0


@dataclasses.dataclass
class _Launch:
    """One (possibly grouped, possibly fused) launch queued on a worker."""

    key: tuple
    start: float                 # when it begins executing (no more joins)
    done: float                  # completion; grows as requests join
    worker: int
    waiters: List[tuple] = dataclasses.field(default_factory=list)
    # fused-segment bookkeeping: raw candidate rows per pattern key
    # (same-key joiners extend their segment's sub-range union --
    # bind-join chunks are disjoint, so union ~ sum) and the creator's
    # solo padded stream (the floor when the launch never fuses: a
    # singleton launch pads to the solo shape bucket).
    seg_rows: Dict[tuple, int] = dataclasses.field(default_factory=dict)
    # per-key full-range row cap: members' combined sub-range unions
    # cannot exceed the pattern's range, and once they reach it the real
    # launch streams the (unpruned) full range instead
    seg_full: Dict[tuple, int] = dataclasses.field(default_factory=dict)
    solo_cand: int = 0
    # fragment identities already being computed by this launch: a
    # same-fragment duplicate arriving in the same window is served from
    # the batch prefill's memo (a store skip), never a new group
    frags: set = dataclasses.field(default_factory=set)

    @property
    def keys(self):
        return self.seg_rows.keys()

    def seg_streamed(self) -> List[int]:
        """Per-segment raw rows actually streamed (union capped at full)."""
        return [min(r, self.seg_full.get(k)) if self.seg_full.get(k)
                else r for k, r in self.seg_rows.items()]

    def stream_tiles(self) -> int:
        """FUSED_BT-aligned tile count of the fused candidate stream."""
        return sum(-(-max(r, 1) // _FUSED_BT)
                   for r in self.seg_streamed())


class _Server:
    """k identical workers + FIFO queue (+ optional launch batching)."""

    def __init__(self, workers: int, batch_window: float = 0.0,
                 fuse: bool = False, max_segments: int = 16,
                 max_stream: int = 131072) -> None:
        self.free_at = [0.0] * workers
        self.batch_window = batch_window
        self.fuse = fuse
        self.max_segments = max_segments
        self.max_stream = max_stream
        # pattern_key -> newest still-queued launch for that pattern
        # (unfused batching); under fusion the newest launch is joinable
        # by ANY pattern, so one global slot suffices.
        self._open: Dict[tuple, _Launch] = {}
        self._open_any: Optional[_Launch] = None

    def schedule(self, arrival: float, service: float) -> float:
        """Returns completion time; assigns the earliest-free worker."""
        i = int(np.argmin(self.free_at))
        start = max(arrival, self.free_at[i])
        done = start + service
        self.free_at[i] = done
        return done

    def schedule_launch(self, arrival: float, key: tuple, overhead: float,
                        stream: float, marginal: float,
                        cand_rows: int = 0, solo_cand: int = 0,
                        frag_key: tuple = (), full_rows: int = 0,
                        ) -> Tuple[_Launch, bool, bool, bool]:
        """Schedule one kernel launch, batching/fusing where possible.

        ``overhead`` is the per-launch dispatch cost, ``stream`` the
        cost of this request's candidate HBM stream, ``marginal`` its
        own pattern-slot compare cells. A request arriving before an
        earlier launch *starts* joins it (``batch_window`` > 0 delays
        each start to give concurrent requests time to coalesce):

        * same ``key`` -- it shares that segment's candidate stream and
          the launch grows by ``marginal`` only (one padded grouped
          launch, ``BrTPFServer.handle_batch``);
        * different ``key`` under fusion -- it becomes a NEW segment of
          the fused launch (``select_fused``): the launch grows by
          ``stream + marginal`` because the segment brings its own
          candidate block, but pays no extra dispatch overhead. The
          launch refuses segments past the ``fusion_legality`` caps.

        Every member completes together at the launch's final ``done``.
        Returns (launch, created, new_segment, duplicate) --
        ``new_segment`` is True when this request added its own
        candidate stream (always True for a created launch);
        ``duplicate`` marks a same-fragment repeat served from the batch
        prefill's memo (a store skip on the live server, no new work).
        """
        tiles = -(-max(cand_rows, 1) // _FUSED_BT)
        if self.batch_window > 0.0:
            open_ = self._open_any if self.fuse else self._open.get(key)
            if open_ is not None and arrival <= open_.start:
                if frag_key and frag_key in open_.frags:
                    return open_, False, False, True
                if key in open_.keys:
                    grow, new_seg = marginal, False
                    open_.seg_rows[key] += max(cand_rows, 0)
                    open_.seg_full[key] = max(open_.seg_full.get(key, 0),
                                              full_rows)
                    open_.frags.add(frag_key)
                elif (self.fuse
                        and len(open_.keys) < self.max_segments
                        and (open_.stream_tiles() + tiles) * _FUSED_BT
                        <= self.max_stream):
                    grow, new_seg = stream + marginal, True
                    open_.seg_rows[key] = max(cand_rows, 0)
                    open_.seg_full[key] = full_rows
                    open_.frags.add(frag_key)
                else:
                    open_ = None   # fusion caps reached: fresh launch
                if open_ is not None:
                    open_.done += grow
                    # the launch grew, so this worker's whole queue (the
                    # launch plus anything accepted after it) shifts by
                    # the same amount -- never rewind free_at
                    self.free_at[open_.worker] += grow
                    return open_, False, new_seg, False
        i = int(np.argmin(self.free_at))
        start = max(arrival, self.free_at[i]) + self.batch_window
        launch = _Launch(key=key, start=start,
                         done=start + overhead + stream + marginal,
                         worker=i, seg_rows={key: max(cand_rows, 0)},
                         seg_full={key: full_rows},
                         solo_cand=solo_cand, frags={frag_key})
        self.free_at[i] = launch.done
        if self.batch_window > 0.0:
            self._open[key] = launch
            self._open_any = launch
        return launch, True, True, False


@dataclasses.dataclass
class _ClientState:
    qi: int = 0                 # index into the client's query sequence
    ev: int = 0                 # next event within the current query
    query_start: float = 0.0
    timed_out: bool = False


def simulate(traces_per_client: Sequence[Sequence[QueryTrace]],
             params: SimParams,
             cache_size: Optional[int] = None,
             use_cache: bool = False,
             wrap: bool = False) -> SimResult:
    """Replay per-client query streams through the queueing model.

    Event-granular interleaving: the heap orders *individual requests*
    across all clients, so server FIFO contention and shared-cache state
    evolve in global time order, as they would on the paper's cluster.
    Clients restart their sequence if they exhaust it before the hour is
    up (the paper's per-core 193-query sequences were sized not to).
    """
    server = _Server(params.server_workers,
                     batch_window=params.batch_window_s,
                     fuse=params.fuse_patterns,
                     max_segments=params.fused_max_segments,
                     max_stream=params.fused_max_stream)
    cache = LRUCache(cache_size) if use_cache else None
    # Unified-store memo model: LRU set of fragment keys served so far.
    # A later request for a resident fragment skips its launch entirely
    # -- served at servlet overhead, exactly like the real server's
    # fragment store (whose async front end fast-paths resident pages
    # instead of holding them for the batching window, and whose batch
    # planner counts every same-key request beyond a prefilled
    # selection's consumer as a store hit). Skip accounting applies to
    # accelerated-backend replays only, mirroring
    # ``Counters.launches_skipped``.
    # frag_key -> name of the query that computed it. The owner matters
    # for kernel replays: a repeat EXECUTION of the same query finds its
    # fragments resident (the live store skips those launches), whereas
    # a cand > 0 event from a DIFFERENT query is trace evidence that the
    # real store had evicted the fragment by then -- it must launch.
    memo: "OrderedDict[tuple, str]" = OrderedDict()
    kernel_replay = any(
        isinstance(ev, HttpRecord) and ev.cand > 0
        for traces in traces_per_client
        for trace in traces for ev in trace.events)
    sim_launches = kernel_requests = sim_skips = sim_cand = sim_rows = 0
    # per-shard planned-window-page accumulator (sharded traces only:
    # grows to the widest shard_pages delta seen; stays [] otherwise)
    shard_acc: List[int] = []
    completed = timeouts = attempted = 0
    qet_sum = 0.0
    qets: List[float] = []

    states = [_ClientState() for _ in traces_per_client]
    heap: List[Tuple[float, int]] = [(0.0, ci)
                                     for ci in range(len(states))]
    heapq.heapify(heap)
    launches: List[_Launch] = []   # launch i <-> heap id -(i + 1)
    events = 0
    frontier = 0.0
    depth = max(params.pipeline_depth, 1)

    def resume_waiters(launch: _Launch) -> None:
        # every member of a grouped launch completes at the final done
        for wci, wev in launch.waiters:
            wt = (launch.done + params.net_latency_s / depth
                  + wev.recv * params.bytes_per_triple
                  / params.bandwidth_bps
                  + params.client_overhead_s / depth)
            heapq.heappush(heap, (wt, wci))

    while heap:
        t, ci = heapq.heappop(heap)
        frontier = max(frontier, min(t, params.duration_s))
        if ci < 0:
            launch = launches[-ci - 1]
            if t < launch.done:     # grew after this event was queued
                heapq.heappush(heap, (launch.done, ci))
            else:
                resume_waiters(launch)
            continue
        if t >= params.duration_s:
            continue
        st = states[ci]
        traces = traces_per_client[ci]
        trace = traces[st.qi % len(traces)]

        if st.ev == 0:
            st.query_start = t
            st.timed_out = not trace.completed  # budget-truncated trace

        # Query finished (all events done, or timeout crossed)?
        over = t - st.query_start > params.timeout_s
        if st.ev >= len(trace.events) or st.timed_out or over:
            if st.timed_out or over:
                t = min(t, st.query_start + params.timeout_s)
                if t <= params.duration_s:
                    timeouts += 1
                    attempted += 1
            else:
                completed += 1
                attempted += 1
                qet_sum += t - st.query_start
                qets.append(t - st.query_start)
            st.qi += 1
            st.ev = 0
            st.timed_out = False
            # per-execution client restart (the paper restarts the client
            # process between executions); also guarantees time progress
            t += 0.01
            if st.qi < len(traces) or wrap:
                heapq.heappush(heap, (t, ci))
            continue

        ev = trace.events[st.ev]
        st.ev += 1
        events += 1
        if events > params.max_events:
            break
        if isinstance(ev, HttpRecord):
            t += params.net_latency_s / depth
            frag_key = ev.key[:2]   # page-independent fragment identity
            hit = False
            if cache is not None:
                hit = cache.get(ev.key) is not None
                if not hit:
                    cache.put(ev.key, True)
            if hit:
                t += params.cache_hit_s
                if kernel_replay:
                    sim_skips += 1   # page resident: launch avoided
            elif frag_key in memo and not (
                    kernel_replay and ev.cand > 0
                    and memo[frag_key] != trace.name):
                # unified-store skip: the fragment was computed by an
                # earlier request -- served from the memo at servlet
                # overhead, no launch. Kernel traces encode collection-
                # time residency: a cand > 0 event means the real server
                # streamed candidates, i.e. its store had EVICTED any
                # earlier copy -- unless the earlier copy came from a
                # prior execution of this same query (trace duplication
                # across clients / wrap-around), which collection never
                # saw and which the live store serves residency-free.
                memo.move_to_end(frag_key)
                if kernel_replay:
                    sim_skips += 1
                    kernel_requests += 1
                t = server.schedule(t, params.req_overhead_s)
            elif ev.cand > 0:
                # kernel-backend request: per-launch cost model, with
                # optional cross-request batching on the pattern key.
                # ``cand`` already sums the candidate rows streamed over
                # all of the request's launches (window pages on the
                # sharded backend run as separate launches -- on every
                # shard in parallel -- so each pays dispatch overhead
                # but the HBM stream total is just ``cand``).
                n_launch = max(ev.launches, 1)
                overhead = n_launch * params.kernel_launch_overhead_s
                stream = ev.cand * params.kernel_stream_s
                # per-request work that never batches: HTTP handling +
                # this request's own pattern-slot compare cells (pats
                # sums per-launch slot counts, so the per-launch grid is
                # cand/n * pats/n cells, summed over n launches).
                marginal = (params.req_overhead_s
                            + ev.cand * ev.pats
                            * params.kernel_cell_s / n_launch)
                launch, created, new_seg, dup = server.schedule_launch(
                    t, ev.pattern_key, overhead, stream, marginal,
                    cand_rows=ev.cand_rows or ev.cand,
                    solo_cand=ev.cand, frag_key=frag_key,
                    full_rows=ev.cand_full_rows)
                kernel_requests += 1
                if dup and kernel_replay:
                    # same-fragment repeat inside the window: the live
                    # batch planner serves it from the prefill memo and
                    # counts a store skip, not a new launch member
                    sim_skips += 1
                # a created request stands for all of its window
                # launches (1 on the single-host kernel path); a
                # joining request rides them and creates none. A
                # same-pattern joiner streams no candidates of its own;
                # a cross-pattern joiner fused in as a new segment DOES
                # stream its own candidate block. Streamed-row totals
                # for batched launches are settled at the end (the
                # launch's padding depends on whether it fused), so only
                # the unbatched path charges here.
                sim_launches += n_launch if created else 0
                # shard-heat model: a created request's window pages land
                # on the shards its trace recorded (a same-pattern joiner
                # rides the open launch's pages and adds none; a fused
                # new segment brings its own page spans, which the live
                # placed planner also charges per segment).
                if (created or new_seg) and ev.shard_pages:
                    if len(shard_acc) < len(ev.shard_pages):
                        shard_acc.extend(
                            [0] * (len(ev.shard_pages) - len(shard_acc)))
                    for si, pg in enumerate(ev.shard_pages):
                        shard_acc[si] += int(pg)
                if params.batch_window_s <= 0.0:
                    sim_cand += ev.cand if created else 0
                    sim_rows += (ev.cand_rows or ev.cand) if created else 0
                # the launch leaves this fragment resident in the
                # modeled unified store
                memo[frag_key] = trace.name
                memo.move_to_end(frag_key)
                while len(memo) > params.selector_memo_entries:
                    memo.popitem(last=False)
                if params.batch_window_s > 0.0:
                    # block this client on the launch: it resumes (with
                    # its response transfer) when the launch completes,
                    # which may move later if more requests join.
                    launch.waiters.append((ci, ev))
                    if created:
                        launches.append(launch)
                        heapq.heappush(heap,
                                       (launch.done, -len(launches)))
                    continue
                t = launch.done
            else:
                service = (params.req_overhead_s
                           + ev.lookups * params.lookup_s
                           + ev.scanned * params.scan_s_per_triple)
                t = server.schedule(t, service)
                # served -> resident (repeats of this fragment skip)
                memo[frag_key] = trace.name
                memo.move_to_end(frag_key)
                while len(memo) > params.selector_memo_entries:
                    memo.popitem(last=False)
            t += (params.net_latency_s / depth
                  + ev.recv * params.bytes_per_triple
                  / params.bandwidth_bps)
            t += params.client_overhead_s / depth
        else:  # ('join', units)
            t += ev[1] * params.join_s_per_triple
        heapq.heappush(heap, (t, ci))

    simulated = (params.duration_s if events <= params.max_events
                 else frontier)
    # fused-shape tallies: every created launch under batching is in
    # ``launches``; one that accumulated >= 2 distinct pattern keys
    # modelled a cross-pattern fused launch (Counters.fused_launches).
    # Its stream is the segments' tile-aligned blocks padded to a pow2
    # tile count (``select_fused``); a singleton launch pads its block
    # to the solo shape bucket instead, which the trace already carries.
    fused = [ln for ln in launches if len(ln.keys) > 1]
    for ln in launches:
        streamed = ln.seg_streamed()
        sim_rows += sum(streamed)
        if len(ln.keys) > 1:
            sim_cand += _pow2_at_least(ln.stream_tiles()) * _FUSED_BT
        else:
            # same-pattern joiners grew the union block (capped at the
            # full range); the solo shape bucket (already pow2,
            # min-bucket floored) is the floor
            sim_cand += max(ln.solo_cand, _pow2_at_least(sum(streamed)))
    return SimResult(completed, timeouts, attempted, qet_sum, qets,
                     simulated_s=max(simulated, 1e-9),
                     launches=sim_launches,
                     kernel_requests=kernel_requests,
                     launches_skipped=sim_skips,
                     fused_launches=len(fused),
                     fused_segments=sum(len(ln.keys) for ln in fused),
                     cand_streamed=sim_cand, cand_rows=sim_rows,
                     shard_launches=tuple(shard_acc))


def split_workload(workload, num_clients: int):
    """Partition the workload into per-client disjoint sequences
    (the paper splits 12,400 queries into 64 distinct sets)."""
    per = max(1, len(workload) // num_clients)
    return [workload[i * per:(i + 1) * per] or workload[:per]
            for i in range(num_clients)]


# ---------------------------------------------------------------------------
# Live validation: replay traces through the REAL async front end
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LiveValidation:
    """Simulated vs observed launch counts for one trace replay.

    ``simulated`` comes from the cost model's launch bookkeeping
    (:attr:`SimResult.launches`); ``observed`` from actually pushing the
    same request streams through ``AsyncBrTPFServer`` over a
    kernel-backend server and reading ``Counters.kernel_launches``. The
    two use different clocks (simulated seconds vs wall time), so exact
    equality is not expected -- agreement within ~10% validates that the
    sim's batching window models what the server now really does.
    """

    simulated_launches: int
    observed_launches: int
    requests: int
    observed_batched: int     # requests served via shared grouped launches
    flushes: int
    # unified-fragment-store validation: launches each side SKIPPED
    # because the request's fragment was already resident (sim: the
    # memo model; observed: Counters.launches_skipped).
    simulated_skipped: int = 0
    observed_skipped: int = 0
    # Omega-restricted pruning validation: candidate rows streamed by
    # the launches each side created (sim: SimResult.cand_streamed over
    # the pruned traces; observed: Counters.kernel_cand_streamed).
    # Grouped live launches stream ONE (padded) block for the whole
    # group while the sim charges the creating request's solo stream,
    # so agreement is approximate under batching -- but both collapse
    # together when pruning shrinks the streams.
    simulated_cand: int = 0
    observed_cand: int = 0
    # raw (pre-padding) candidate rows. The padded totals above shift
    # with how requests regroup into launches (pow2/tile padding is not
    # additive); raw rows are composition-invariant, so this is the
    # tight streaming-agreement check under fusion.
    simulated_cand_rows: int = 0
    observed_cand_rows: int = 0
    # cross-pattern fusion validation: launches that served >= 2
    # distinct patterns (sim: _Launch.keys; observed:
    # Counters.fused_launches) and their total segment counts.
    simulated_fused: int = 0
    observed_fused: int = 0
    simulated_fused_segments: int = 0
    observed_fused_segments: int = 0
    # shard-heat validation (sharded backend only; empty tuples
    # otherwise): per-shard planned-window-page totals (sim:
    # SimResult.shard_launches from the traces' shard_pages deltas;
    # observed: BrTPFServer.shard_launch_snapshot deltas) -- the
    # placement layer's per-shard agreement surface.
    simulated_shard: tuple = ()
    observed_shard: tuple = ()
    # resilience cross-check (docs/resilience.md): replayed requests
    # carry no deadlines, so the live front end must shed NOTHING --
    # a non-zero count here means expired-deadline shedding leaked into
    # a deadline-free replay and the launch comparison above is void.
    observed_shed: int = 0

    @property
    def agreement(self) -> float:
        """observed / simulated launch ratio (1.0 = perfect)."""
        return self.observed_launches / max(self.simulated_launches, 1)

    @property
    def within(self) -> float:
        """Relative disagreement |obs - sim| / sim."""
        return (abs(self.observed_launches - self.simulated_launches)
                / max(self.simulated_launches, 1))

    @property
    def skip_within(self) -> float:
        """Relative skipped-launch disagreement |obs - sim| / max(sim, 1)."""
        return (abs(self.observed_skipped - self.simulated_skipped)
                / max(self.simulated_skipped, 1))

    @property
    def cand_within(self) -> float:
        """Relative streamed-candidate disagreement |obs - sim| / max(sim, 1)."""
        return (abs(self.observed_cand - self.simulated_cand)
                / max(self.simulated_cand, 1))

    @property
    def cand_rows_within(self) -> float:
        """Relative raw-candidate-row disagreement |obs - sim| / max(sim, 1)."""
        return (abs(self.observed_cand_rows - self.simulated_cand_rows)
                / max(self.simulated_cand_rows, 1))

    @property
    def shard_within(self) -> float:
        """Total per-shard page disagreement: sum_s |obs_s - sim_s| /
        max(sum_s sim_s, 1). Zero-pads the shorter side, so a shard one
        side never touched still counts as disagreement."""
        n = max(len(self.simulated_shard), len(self.observed_shard))
        sim = list(self.simulated_shard) + [0] * (n - len(self.simulated_shard))
        obs = list(self.observed_shard) + [0] * (n - len(self.observed_shard))
        return (sum(abs(o - s) for o, s in zip(obs, sim, strict=True))
                / max(sum(sim), 1))


def requests_from_trace(trace: QueryTrace) -> List["object"]:
    """Rebuild the :class:`~repro.core.server.Request` sequence of a
    trace (join events are client-local and carry no request)."""
    from .rdf import TriplePattern
    from .server import Request
    reqs = []
    for ev in trace.events:
        if not isinstance(ev, HttpRecord):
            continue
        pattern_tuple, omega_rows, page = ev.key
        omega = (None if not omega_rows
                 else np.asarray(omega_rows, dtype=np.int32))
        reqs.append(Request(TriplePattern(*pattern_tuple), omega, page))
    return reqs


def live_replay(traces_per_client: Sequence[Sequence[QueryTrace]],
                server: BrTPFServer,
                params: SimParams,
                batch_window_s: float = 2e-3,
                max_batch: int = 64) -> LiveValidation:
    """Validate the sim's launch model against the real front end.

    Replays each client's request stream through an
    :class:`~repro.core.batching.AsyncBrTPFServer` wrapped around
    ``server`` (which must use the kernel backend for launch counts to
    be meaningful), runs the cost-model replay of the *same* traces, and
    reports both launch counts side by side -- including the launches
    each side *skipped* via the unified fragment store. Each live client awaits its
    responses in order, mirroring the sim's one-outstanding-request-per-
    client-per-stream structure.
    """
    from .batching import serve_concurrent
    # The live loop drives ONE in-process server: flushes serialize on
    # the event loop, so the matching cost model is a single worker --
    # an open launch then stays joinable while the previous flush is
    # still executing, exactly like the real pending-batch queue.
    sim_params = dataclasses.replace(params, batch_window_s=batch_window_s,
                                     server_workers=1)
    sim = simulate(traces_per_client, sim_params)

    streams = [[req for trace in traces for req in requests_from_trace(trace)]
               for traces in traces_per_client]
    base = server.counters.snapshot()
    shard_snap = getattr(server, "shard_launch_snapshot", None)
    shard_before = shard_snap() if shard_snap is not None else None
    _responses, front = serve_concurrent(
        server, streams, batch_window_s=batch_window_s, max_batch=max_batch)
    after = server.counters
    shard_obs = ()
    if shard_before is not None and shard_before.size:
        shard_obs = tuple(
            int(x) for x in (shard_snap() - shard_before).tolist())
    return LiveValidation(
        simulated_launches=sim.launches,
        observed_launches=after.kernel_launches - base.kernel_launches,
        requests=front.stats.requests + front.stats.fast_path,
        observed_batched=(after.kernel_batched_requests
                          - base.kernel_batched_requests),
        flushes=front.stats.flushes,
        simulated_skipped=sim.launches_skipped,
        observed_skipped=(after.launches_skipped
                          - base.launches_skipped),
        simulated_cand=sim.cand_streamed,
        observed_cand=(after.kernel_cand_streamed
                       - base.kernel_cand_streamed),
        simulated_cand_rows=sim.cand_rows,
        observed_cand_rows=(after.kernel_cand_rows
                            - base.kernel_cand_rows),
        simulated_fused=sim.fused_launches,
        observed_fused=after.fused_launches - base.fused_launches,
        simulated_fused_segments=sim.fused_segments,
        observed_fused_segments=(after.fused_segments
                                 - base.fused_segments),
        simulated_shard=sim.shard_launches,
        observed_shard=shard_obs,
        observed_shed=front.stats.shed,
    )


def main(argv=None) -> int:
    """CLI: replay a small WatDiv workload through the cost model and
    (with ``--live``) through the real async front end.

    Example::

        python -m repro.core.sim --live --clients 16 --window 2e-3
    """
    import argparse
    parser = argparse.ArgumentParser(
        description="brTPF multi-client replay: cost model vs live front end")
    parser.add_argument("--live", action="store_true",
                        help="also replay through AsyncBrTPFServer and "
                             "report observed launch counts")
    parser.add_argument("--clients", type=int, default=16)
    parser.add_argument("--queries", type=int, default=12)
    parser.add_argument("--backend", choices=("kernel", "sharded"),
                        default="kernel",
                        help="selector backend for trace collection and "
                             "the live server. 'sharded' replays the "
                             "shard-heat model and validates per-shard "
                             "page counts; run with XLA_FLAGS="
                             "--xla_force_host_platform_device_count=N "
                             "for a multi-shard mesh")
    parser.add_argument("--shard-window", type=int, default=None,
                        help="sharded-backend window rows per launch "
                             "(default: the backend's own choice)")
    parser.add_argument("--window", type=float, default=2e-3,
                        help="batching window in seconds (sim and live)")
    parser.add_argument("--max-batch", type=int, default=64)
    parser.add_argument("--max-mpr", type=int, default=30)
    parser.add_argument("--no-fuse", action="store_true",
                        help="disable cross-pattern kernel fusion in both "
                             "the cost model and the live server (A/B)")
    parser.add_argument("--seed", type=int, default=0)
    args = parser.parse_args(argv)

    from ..data.watdiv import WatDivScale, generate, generate_workload
    scale = WatDivScale(users=600, products=240, reviews=1000,
                        retailers=12, genres=15, cities=20, tags=40)
    data = generate(scale, seed=args.seed)
    workload = generate_workload(data, args.queries, seed=args.seed + 1)

    config = ServerConfig(max_mpr=args.max_mpr,
                          selector_backend=args.backend,
                          shard_window=args.shard_window,
                          fuse_patterns=not args.no_fuse)
    server = BrTPFServer(data.store, config)
    traces = collect_traces(server, workload, "brtpf",
                            max_mpr=args.max_mpr)
    params = calibrate(server, workload)
    params.batch_window_s = args.window
    params.fuse_patterns = not args.no_fuse
    per_client = split_workload(traces, args.clients)

    sim = simulate(per_client, params)
    print(f"sim: clients={args.clients} window={args.window:g}s "
          f"fuse={not args.no_fuse} "
          f"completed={sim.completed} kernel_requests={sim.kernel_requests} "
          f"launches={sim.launches} "
          f"launches_per_request={sim.launches_per_request:.3f} "
          f"launches_skipped={sim.launches_skipped} "
          f"fused_launches={sim.fused_launches} "
          f"fused_segments_per_launch={sim.fused_segments_per_launch:.2f} "
          f"cand_streamed={sim.cand_streamed} "
          f"cand_per_request={sim.cand_per_request:.0f}")
    if not args.live:
        return 0

    live_server = BrTPFServer(data.store, config)
    lv = live_replay(per_client, live_server, params,
                     batch_window_s=args.window, max_batch=args.max_batch)
    print(f"live: requests={lv.requests} flushes={lv.flushes} "
          f"observed_launches={lv.observed_launches} "
          f"batched_requests={lv.observed_batched} "
          f"observed_skipped={lv.observed_skipped}")
    print(f"validation: simulated={lv.simulated_launches} "
          f"observed={lv.observed_launches} "
          f"agreement={lv.agreement:.3f} "
          f"(|rel err|={lv.within:.1%})")
    print(f"validation(skips): simulated={lv.simulated_skipped} "
          f"observed={lv.observed_skipped} "
          f"(|rel err|={lv.skip_within:.1%})")
    print(f"validation(cand): simulated={lv.simulated_cand} "
          f"observed={lv.observed_cand} "
          f"(|rel err|={lv.cand_within:.1%})")
    print(f"validation(cand_rows): simulated={lv.simulated_cand_rows} "
          f"observed={lv.observed_cand_rows} "
          f"(|rel err|={lv.cand_rows_within:.1%})")
    print(f"validation(fused): simulated={lv.simulated_fused} launches / "
          f"{lv.simulated_fused_segments} segments, "
          f"observed={lv.observed_fused} / {lv.observed_fused_segments}")
    if args.backend == "sharded":
        print(f"validation(shard): simulated={list(lv.simulated_shard)} "
              f"observed={list(lv.observed_shard)} "
              f"(|rel err|={lv.shard_within:.1%})")
    # The live loop reports through the SAME canonical snapshot schema
    # the serving edge exposes at GET /metrics (core/metrics.py), so a
    # number printed here is directly comparable to what the load
    # generator (benchmarks/latency.py) reads over the wire.
    snap = live_server.metrics_snapshot()
    c = snap["counters"]
    print(f"metrics[{snap['v']}]: num_requests={c['num_requests']} "
          f"kernel_launches={c['kernel_launches']} "
          f"fused_launches={c['fused_launches']} "
          f"fused_segments_per_launch="
          f"{snap['fused_segments_per_launch']:.2f} "
          f"kernel_batched_requests={c['kernel_batched_requests']} "
          f"launches_skipped={snap['launches_skipped']} "
          f"selector_memo_hit_rate="
          f"{snap['selector_memo']['hit_rate']:.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
