"""Async batching front end for the combined TPF/brTPF server.

The paper evaluates the server under up to 64 *concurrent* clients
(section 6); PR 1 gave the kernel backend ``handle_batch`` so that N
same-pattern requests cost one grouped bind-join launch -- but only when
a caller hands them over as one pre-assembled list. This module closes
that gap: :class:`AsyncBrTPFServer` is an asyncio front end that
accumulates requests arriving within a configurable window
(``batch_window_s``), flushes early when ``max_batch`` requests are
pending, and dispatches every flush through ``handle_batch`` -- so the
cross-request coalescing the throughput simulation charges for
(``SimParams.batch_window_s``) is something the server actually does.

Flush semantics (documented contract, tested in tests/test_batching.py):

* A request is validated against maxMpR at *enqueue* time: an oversized
  request fails alone, immediately, and never enters a batch -- so one
  misbehaving client cannot poison the coalesced requests of others
  (``handle_batch`` itself stays atomic; the front end simply never
  feeds it an invalid member).
* A request whose page is already resident in the server's unified
  fragment store (HTTP-cached page or memo-resident fragment,
  ``BrTPFServer.page_resident``) is served immediately instead of
  waiting out the window: it launches nothing, so there is nothing to
  coalesce, and holding it would only add latency. Counted in
  ``BatchStats.fast_path``; responses/accounting identical to the
  batched path.
* The first pending request arms a flush timer for ``batch_window_s``
  seconds; the batch flushes when the timer fires or as soon as
  ``max_batch`` requests are pending, whichever comes first. Exactly one
  of the two flushes a given batch (the timer finds an empty queue after
  a flush-on-full and is a no-op).
* A flush atomically takes the pending queue; requests arriving while a
  flush is executing start a new batch with a fresh timer -- they are
  never silently appended to a batch whose kernel launch already ran.
* Responses resolve in enqueue order within a batch, and batches flush
  FIFO; every response is byte-identical to what a sequential
  ``BrTPFServer.handle`` call would have returned (``handle_batch``
  guarantees this; the paging/caching/transfer accounting is shared).

``batch_window_s <= 0`` degenerates to immediate per-request dispatch
(still through ``handle_batch`` so solo requests take the normal
``handle`` path inside it).
"""
from __future__ import annotations

import asyncio
import dataclasses
from typing import List, Optional, Sequence, Tuple

from .selectors import Fragment
from .server import BrTPFServer, Request

DEFAULT_BATCH_WINDOW_S = 2e-3
DEFAULT_MAX_BATCH = 64


class QueueSaturated(RuntimeError):
    """Admission control (docs/serving.md): the batching queue is full.

    Raised at enqueue time when ``queue_depth`` pending requests are
    already waiting for a flush. The condition is *retryable* -- the
    queue drains within a batching window -- so the ASGI app maps it to
    HTTP 503 with a ``retryable`` error envelope instead of buffering
    without bound."""


class DeadlineExceeded(RuntimeError):
    """Deadline-aware shedding (docs/resilience.md): the request's
    remaining ``timeout_ms`` budget expired before it could be served.

    Raised at enqueue time when the budget is already exhausted, and at
    flush time for requests that expired while waiting out the batching
    window -- shedding them keeps an expired request from burning space
    in a fused launch whose response nobody is waiting for. Retryable
    (the ASGI app maps it to HTTP 504 with code ``DEADLINE_EXCEEDED``):
    fragment requests are idempotent, and the *next* attempt may hit a
    now-resident page or a less loaded replica."""


@dataclasses.dataclass
class BatchStats:
    """Front-end accounting (kernel launch counts live on the wrapped
    server's :class:`~repro.core.metrics.Counters`)."""

    requests: int = 0           # accepted into a batch
    rejected: int = 0           # failed validation at enqueue
    fast_path: int = 0          # served immediately: page already resident
    flushes: int = 0            # non-empty batches dispatched
    timer_flushes: int = 0      # ... because the window elapsed
    full_flushes: int = 0       # ... because max_batch was reached
    coalesced_requests: int = 0  # requests sharing a flush with >= 1 other
    max_batch_seen: int = 0
    shed: int = 0               # expired-deadline requests shed unserved

    @property
    def mean_batch(self) -> float:
        return self.requests / self.flushes if self.flushes else 0.0


class AsyncBrTPFServer:
    """Asyncio accumulation window in front of a :class:`BrTPFServer`.

    ``await handle(req)`` enqueues the request and resolves with its
    :class:`Fragment` when the batch it joined has been served. All
    callers must run on the same event loop.

    ``executor`` optionally runs ``handle_batch`` off-loop (e.g. a
    ``concurrent.futures.ThreadPoolExecutor``): the event loop then
    stays responsive during a flush, so requests really can arrive
    mid-flush (they start the next batch). With the default inline
    dispatch the loop blocks for the duration of the batch -- fine for
    benchmarks and tests on this one-core container.
    """

    def __init__(
        self,
        server: BrTPFServer,
        batch_window_s: float = DEFAULT_BATCH_WINDOW_S,
        max_batch: int = DEFAULT_MAX_BATCH,
        executor=None,
        queue_depth: Optional[int] = None,
    ) -> None:
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if queue_depth is not None and queue_depth < 1:
            raise ValueError("queue_depth must be >= 1 (or None)")
        self.server = server
        self.batch_window_s = float(batch_window_s)
        self.max_batch = int(max_batch)
        self.queue_depth = queue_depth
        self.stats = BatchStats()
        self._executor = executor
        self._pending: List[Tuple[Request, "asyncio.Future",
                                  Optional[float]]] = []
        self._timer: Optional[asyncio.TimerHandle] = None
        self._flush_lock = asyncio.Lock()
        self._closed = False

    @classmethod
    def from_config(cls, store, config=None,
                    batch_window_s: float = DEFAULT_BATCH_WINDOW_S,
                    max_batch: int = DEFAULT_MAX_BATCH,
                    cache=None, executor=None,
                    queue_depth: Optional[int] = None
                    ) -> "AsyncBrTPFServer":
        """Build the wrapped origin server from a
        :class:`~repro.core.config.ServerConfig` -- the construction
        path the ASGI app factory and the replica router share, so a
        whole fleet is provably configured from one value object.
        ``queue_depth`` defaults to the config's knob when not passed
        explicitly."""
        if queue_depth is None:
            queue_depth = getattr(config, "queue_depth", None)
        return cls(BrTPFServer(store, config, cache=cache),
                   batch_window_s=batch_window_s, max_batch=max_batch,
                   executor=executor, queue_depth=queue_depth)

    @property
    def max_mpr(self) -> int:
        """The wrapped server's maxMpR (the 414 bound a transport
        advertises)."""
        return self.server.max_mpr

    def note_mappings(self, req: Request) -> None:
        """Charge the request's attached solution mappings to the
        server's ``mappings_sent``. Called by the WIRE boundary
        (transport / ASGI app) -- in-process clients charge the counter
        themselves, so the two paths never double-count."""
        if req.omega is not None:
            self.server.counters.mappings_sent += int(req.omega.shape[0])

    def metrics_snapshot(self) -> dict:
        """The canonical metrics envelope (metrics.py) with this front
        end's flush/coalescing stats attached under ``"batch"``."""
        from .metrics import metrics_snapshot
        return metrics_snapshot(self.server, batch=self.stats)

    # -- request boundary ----------------------------------------------------

    async def handle(self, req: Request) -> Fragment:
        """Enqueue one page request; resolves with its fragment."""
        if self._closed:
            raise RuntimeError("AsyncBrTPFServer is closed")
        # Per-request validation: an oversized request fails alone, now,
        # and never joins a batch (handle_batch's atomic all-or-nothing
        # check therefore never rejects a coalesced batch).
        try:
            self.server.validate(req)
        except Exception:
            self.stats.rejected += 1
            raise
        # Deadline check at enqueue (docs/resilience.md): a request that
        # arrives with an exhausted budget is shed now -- nobody is
        # waiting for the response, so serving it would be pure waste.
        if req.timeout_ms is not None and req.timeout_ms <= 0:
            self.stats.shed += 1
            raise DeadlineExceeded(
                f"request arrived with exhausted deadline budget "
                f"(timeout_ms={req.timeout_ms})")
        # Unified-store fast path: a page that is already resident (an
        # HTTP-cached page or a memo-resident fragment) launches
        # nothing, so there is nothing to coalesce -- serve it now
        # instead of holding it for the batching window. Responses and
        # accounting are identical to the batched path (handle() serves
        # from the store either way); only the window latency is saved.
        # The flush lock serializes this handle() against handle_batch
        # (with an executor, a flush mutates server state off-loop).
        if self.server.page_resident(req):
            async with self._flush_lock:
                self.stats.fast_path += 1
                return self.server.handle(req)
        # Admission control (docs/serving.md): refuse instead of
        # buffering without bound -- the queue drains within one
        # batching window, so the client can retry after backoff.
        if (self.queue_depth is not None
                and len(self._pending) >= self.queue_depth):
            self.stats.rejected += 1
            raise QueueSaturated(
                f"batching queue full: {len(self._pending)} pending >= "
                f"queue_depth={self.queue_depth}")
        loop = asyncio.get_running_loop()
        fut: "asyncio.Future" = loop.create_future()
        # Absolute expiry on the loop clock: checked again at flush, so
        # a request that spent its whole budget waiting out the batching
        # window is shed instead of joining the launch.
        expires = (None if req.timeout_ms is None
                   else loop.time() + req.timeout_ms / 1e3)
        self._pending.append((req, fut, expires))
        self.stats.requests += 1
        if self.batch_window_s <= 0 or len(self._pending) >= self.max_batch:
            cause = ("full" if len(self._pending) >= self.max_batch
                     else "inline")
            self._cancel_timer()
            await self._flush(cause)
        elif self._timer is None:
            self._timer = loop.call_later(self.batch_window_s,
                                          self._on_timer, loop)
        return await fut

    async def aclose(self) -> None:
        """Flush anything pending and refuse further requests."""
        self._closed = True
        self._cancel_timer()
        await self._flush("close")

    async def repartition(self, heat=None) -> None:
        """Atomic placement cutover (docs/federation.md, "Placement"):
        runs ``BrTPFServer.repartition`` under the flush lock, so the
        store swap + fragment invalidation land strictly between
        flushes -- no batch is ever served half-old, half-new."""
        async with self._flush_lock:
            self.server.repartition(heat)

    # -- flush machinery -----------------------------------------------------

    def _cancel_timer(self) -> None:
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None

    def _on_timer(self, loop) -> None:
        self._timer = None
        if self._pending:
            loop.create_task(self._flush("timer"))

    async def _flush(self, cause: str) -> None:
        """Dispatch the current batch through ``handle_batch``.

        The lock serializes flushes (FIFO -- asyncio.Lock wakes waiters
        in acquisition order), and the pending queue is swapped out
        *before* dispatch so mid-flush arrivals open a new batch. The
        cause is counted here, after the non-empty batch is taken, so a
        racing timer/full flush that finds an empty queue counts as
        nothing.
        """
        async with self._flush_lock:
            taken = self._pending
            if not taken:
                return
            self._pending = []
            self._cancel_timer()
            # Deadline check at flush (docs/resilience.md): shed every
            # request whose budget expired while it waited -- an expired
            # member never enters the coalesced launch, so live requests
            # pay nothing for a dead neighbor.
            loop = asyncio.get_running_loop()
            now = loop.time()
            batch = []
            for req, fut, expires in taken:
                if expires is not None and now >= expires:
                    self.stats.shed += 1
                    if not fut.done():
                        fut.set_exception(DeadlineExceeded(
                            f"deadline expired "
                            f"{(now - expires) * 1e3:.1f}ms before flush "
                            f"(timeout_ms={req.timeout_ms})"))
                    continue
                batch.append((req, fut))
            if not batch:
                return
            self.stats.flushes += 1
            if cause == "timer":
                self.stats.timer_flushes += 1
            elif cause == "full":
                self.stats.full_flushes += 1
            self.stats.max_batch_seen = max(self.stats.max_batch_seen,
                                            len(batch))
            if len(batch) > 1:
                self.stats.coalesced_requests += len(batch)
            reqs = [r for r, _ in batch]
            try:
                if self._executor is not None:
                    frags = await loop.run_in_executor(
                        self._executor, self.server.handle_batch, reqs)
                else:
                    frags = self.server.handle_batch(reqs)
            except Exception as exc:
                for _, fut in batch:
                    if not fut.done():
                        fut.set_exception(exc)
                return
            for (_, fut), frag in zip(batch, frags, strict=True):
                if not fut.done():
                    fut.set_result(frag)


# ---------------------------------------------------------------------------
# Concurrent drivers (benchmarks, live sim validation, tests)
# ---------------------------------------------------------------------------


async def drive_streams(
    front: AsyncBrTPFServer,
    streams: Sequence[Sequence[Request]],
) -> List[List[Fragment]]:
    """Replay request streams concurrently: one coroutine per stream,
    each awaiting its responses in order (a client pipelines across
    streams, not within one). Returns per-stream fragment lists."""

    async def one(stream: Sequence[Request]) -> List[Fragment]:
        return [await front.handle(r) for r in stream]

    return list(await asyncio.gather(*[one(s) for s in streams]))


def serve_concurrent(
    server: BrTPFServer,
    streams: Sequence[Sequence[Request]],
    batch_window_s: float = DEFAULT_BATCH_WINDOW_S,
    max_batch: int = DEFAULT_MAX_BATCH,
) -> Tuple[List[List[Fragment]], AsyncBrTPFServer]:
    """Synchronous convenience wrapper: build a front end over
    ``server``, replay ``streams`` concurrently, close, and return
    (responses, front) -- ``front.stats`` carries the flush accounting."""
    front = AsyncBrTPFServer(server, batch_window_s=batch_window_s,
                             max_batch=max_batch)

    async def main() -> List[List[Fragment]]:
        try:
            return await drive_streams(front, streams)
        finally:
            await front.aclose()

    return asyncio.run(main()), front
