"""Selector functions and fragments (paper Definitions 1 and 2).

``tpf_select`` is the classic triple-pattern selector. ``brtpf_select``
implements the bindings-restricted selector s_(tp, Omega) exactly as the
server algorithm in paper section 4.1 computes it:

  1. iterate over the sequence Omega of solution mappings;
  2. apply each mapping to tp, yielding (potentially) more concrete
     triple patterns;
  3. remove duplicate instantiated patterns;
  4. evaluate each remaining pattern against the backend and concatenate
     the resulting match streams.

The concatenated stream is the fragment's data-triple sequence; paging
slices that sequence deterministically (Omega is a *sequence*, so the
instantiation order -- and hence the page contents -- is well defined,
which is why Definition 2 insists on sequences rather than sets).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from .rdf import TriplePattern
from .store import TripleStore


def tpf_select(store: TripleStore, tp: TriplePattern) -> np.ndarray:
    """Definition 1, empty-Omega branch: all matching triples."""
    return store.match(tp)


def instantiate_patterns(
    tp: TriplePattern, omega: Optional[np.ndarray]
) -> List[TriplePattern]:
    """Steps 1-3 of the server algorithm: instantiate + dedup (ordered)."""
    if omega is None or omega.shape[0] == 0:
        return [tp]
    seen = {}
    out: List[TriplePattern] = []
    for row in omega:
        inst = tp.instantiate(row)
        key = inst.as_tuple()
        if key not in seen:
            seen[key] = True
            out.append(inst)
    return out


def brtpf_select_with_cnt(
    store: TripleStore, tp: TriplePattern, omega: Optional[np.ndarray]
) -> Tuple[np.ndarray, int]:
    """Definition 1 selector + Definition 2 ``cnt`` in one backend pass.

    Returns the fragment's data-triple *sequence* (concatenated streams,
    cross-stream duplicates removed so the result is a set of triples as
    Definition 3 of the LDF framework requires Gamma to be) and the
    cardinality estimate (sum of per-instantiation stream sizes, which
    over-counts cross-stream duplicates -- a bounded-error estimate as
    Definition 2(b) permits: abs(|Gamma| - cnt) <= eps).
    """
    streams = [store.match(p) for p in instantiate_patterns(tp, omega)]
    cnt = int(sum(s.shape[0] for s in streams))
    if len(streams) == 1:
        return streams[0], cnt
    cat = np.concatenate([s for s in streams if s.shape[0]], axis=0) \
        if any(s.shape[0] for s in streams) else np.empty((0, 3), np.int32)
    if cat.shape[0] == 0:
        return cat, cnt
    # Ordered dedup: keep first occurrence (deterministic paging).
    _, first = np.unique(cat, axis=0, return_index=True)
    return cat[np.sort(first)], cnt


def brtpf_select(
    store: TripleStore, tp: TriplePattern, omega: Optional[np.ndarray]
) -> np.ndarray:
    return brtpf_select_with_cnt(store, tp, omega)[0]


def brtpf_cardinality(
    store: TripleStore, tp: TriplePattern, omega: Optional[np.ndarray]
) -> int:
    return brtpf_select_with_cnt(store, tp, omega)[1]


def brtpf_count(
    store: TripleStore, tp: TriplePattern, omega: Optional[np.ndarray]
) -> int:
    """Definition-2 ``cnt`` without materializing the data sequence.

    The count-only fast path for count probes: ``store.cardinality`` is
    a pure searchsorted for prefix patterns (the common case), so no
    match stream is gathered or concatenated. Equal to
    ``brtpf_select_with_cnt(...)[1]`` by construction -- cardinality's
    scan fallback is an exact count.
    """
    return int(sum(store.cardinality(p)
                   for p in instantiate_patterns(tp, omega)))


@dataclasses.dataclass
class Fragment:
    """One page of a (br)TPF -- the wire-level unit (LDF Definition 3).

    ``data`` are the page's data triples; ``cnt`` the fragment-level
    cardinality estimate; ``meta_triples`` the number of metadata/control
    triples the page carries (void:triples, hypermedia controls, paging
    links, ...), which the network-load benchmarks charge to dataRecv
    exactly like the paper does.
    """

    data: np.ndarray
    cnt: int
    page: int
    page_size: int
    has_next: bool
    meta_triples: int

    @property
    def triples_received(self) -> int:
        return int(self.data.shape[0]) + self.meta_triples
