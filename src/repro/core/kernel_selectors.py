"""Kernel-backed bindings-restricted selectors (the server hot path).

``brtpf_select_with_cnt`` in ``selectors.py`` evaluates the section-4.1
server algorithm the way the paper's Java servlet does: one backend
index probe + stream per instantiated pattern. This module is the same
selector inverted for the accelerator: the store exposes the pattern's
contiguous index range as one packed candidate block
(:meth:`TripleStore.candidate_range`), the Pallas ``bindjoin`` kernel
streams that block through VMEM *once* against every instantiated
pattern, and a fixed-shape ``compact_mask`` epilogue plus a small host
reorder produce a fragment that is byte-identical to the numpy
selector's -- same data-triple sequence, same ordering, same
Definition-2 ``cnt`` estimate (``selectors.brtpf_select_with_cnt`` is
the parity oracle; ``tests/test_kernel_selectors.py`` enforces it).

Cross-request batching: concurrent brTPF requests for the *same* triple
pattern share the same candidate range, so their (padded) pattern sets
ride one grouped kernel launch -- one HBM pass over the candidates for
G requests instead of G passes. ``BrTPFServer.handle_batch`` feeds this
path and the recorded per-launch geometry feeds the multi-client replay
in ``sim.py``.

Omega-restricted pruning (docs/pruning.md): when the attached mappings
instantiate more-bound shapes, the launch streams the merged union of
their per-binding index sub-ranges (``TripleStore.subranges``) instead
of the full prefix range -- the rows outside the union are guaranteed
join-irrelevant, so the response cannot change while the HBM stream
shrinks to the join-relevant candidates. Below ``fast_path_rows``
post-pruning rows the selection skips the kernel entirely
(``select_block_numpy``).

Why parity holds despite the kernel's flat wildcard grid:

* every triple matching an instantiated pattern of ``tp`` also matches
  ``tp``, so ``candidate_range(tp)`` covers all per-pattern streams --
  and the pruned sub-range union covers them by construction (each
  instantiation's matches lie inside its own sub-range);
* repeated-variable constraints are shared by *all* instantiations
  (positions holding the same variable are either both replaced by the
  same constant or both left as that variable), so conjoining the base
  pattern's equality flags (``tpf_match``) restores exact semantics for
  every pattern at once;
* on rows passing those flags, grid-match == exact match per pattern,
  so the kernel's first-match index reproduces the numpy selector's
  first-occurrence dedup and its match count reproduces ``cnt``;
* within a stream, ``store.match(p)`` order is ascending packed key
  under p's chosen index -- recomputable on host for the (small) kept
  set, giving the exact concatenation order.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from .fragments import FragmentStore, fragment_key
from .rdf import TriplePattern, is_var
from .selectors import instantiate_patterns
from .store import _ORDERS, TripleStore, _pack

# Candidate blocks are padded to power-of-two multiples of the kernel's
# candidate tile so the jit cache stays bounded (log2(N) shapes) on a
# server that sees arbitrary range sizes.
_MIN_BUCKET = 1024

# Shared zero-row fragment payload (zero-size, never mutated).
_EMPTY = np.empty((0, 3), dtype=np.int32)

# Small-work fast path default: below this many (post-pruning)
# candidate rows a kernel launch cannot pay for its dispatch overhead
# (BENCH_kernels.json's `wildcard` row shows the kernel losing to the
# numpy backend outright at small work sizes) -- the selector routes to
# the numpy oracle instead and records the decision in LaunchRecord.
# 0 disables the fast path (the default for bare selectors, so launch
# accounting in tests stays deterministic; servers/benchmarks opt in
# via ``fast_path_rows``).
DEFAULT_FAST_PATH_ROWS = 256


# Cross-pattern fusion capacity caps (docs/fusion.md). Conservative by
# design: a fused launch that would exceed any of them falls back to
# per-group launches rather than risking VMEM pressure or an unbounded
# jit cache. All power-of-two (KL004).
MAX_FUSED_SEGMENTS = 16      # segments sharing one launch
MAX_FUSED_SLOTS = 32768      # flat pattern slot table (S * G * Mp)
MAX_FUSED_STREAM = 131072    # concatenated candidate rows

# Tile size for fused launches: each segment's candidate block is
# tile-aligned independently, so the finer tile bounds alignment waste.
FUSED_BT = 256
assert FUSED_BT == kops.DEFAULT_FUSED_BT


def _bucket(n: int) -> int:
    b = _MIN_BUCKET
    while b < n:
        b *= 2
    return b


def _pow2_at_least(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class FusedSegment:
    """One segment of a fused cross-pattern launch.

    A segment is what ``select_same_pattern`` serves alone today: one
    triple pattern plus G request groups (each an Omega or None). The
    fused path concatenates every segment's pruned candidate union into
    one stream and resolves per-segment slot tables inside the kernel.

    ``count_only`` marks a count-probe segment: its groups only need the
    Definition-2 ``cnt``, so the launch skips the gather/stream epilogue
    for it and the fragment carries no data triples.

    ``depends_on`` declares that this segment's Omega derives from the
    output of another in-flight segment (by index into the fused batch).
    Fusion legality is conservative: any declared dependency refuses to
    fuse and falls back to per-group launches, in the spirit of DaCe's
    state-fusion tests -- only provably independent work units share a
    launch. Batched server requests are independent by construction
    (each arrives with its Omega fully materialized), so the server
    never sets this; planners that pipeline bind-join rounds must.
    """

    tp: TriplePattern
    omegas: List[Optional[np.ndarray]]
    patterns: Optional[List[List[TriplePattern]]] = None
    count_only: bool = False
    depends_on: Tuple[int, ...] = ()


def fusion_legality(segments: Sequence[FusedSegment], *,
                    stream_rows: int, slot_table: int,
                    max_segments: int = MAX_FUSED_SEGMENTS,
                    max_slots: int = MAX_FUSED_SLOTS,
                    max_stream: int = MAX_FUSED_STREAM) -> Optional[str]:
    """Decide whether a set of segments may share one fused launch.

    Returns None when fusion is legal, else a human-readable refusal
    reason (the caller falls back to per-group launches and the reason
    is surfaced in logs/tests). Explicit and conservative: dependencies
    forbid fusion outright, and capacity ceilings bound the slot table,
    the candidate stream, and the segment count.
    """
    if any(seg.depends_on for seg in segments):
        return "dependent segments: an Omega derives from an in-flight output"
    if len(segments) > max_segments:
        return f"segment count {len(segments)} exceeds {max_segments}"
    if slot_table > max_slots:
        return f"slot table {slot_table} exceeds {max_slots}"
    if stream_rows > max_stream:
        return f"candidate stream {stream_rows} exceeds {max_stream}"
    return None


@functools.partial(jax.jit, static_argnames=("capacity",))
def _compact_epilogue(keep, idx_first, nmatch, base_mask, row_valid,
                      capacity: int):
    """Device epilogue over the grouped kernel outputs.

    Combines the per-group keep grid with the base pattern's
    repeated-variable mask and the padding-row mask, then produces the
    fixed-shape compacted row indices + count per group and the
    Definition-2 ``cnt`` (sum of per-row match counts over kept rows).
    """
    mask = keep & base_mask[:, None] & row_valid[:, None]   # (Tp, G)
    cnts = jnp.sum(jnp.where(mask, nmatch, 0), axis=0)      # (G,)
    rows, counts = jax.vmap(
        lambda m: kops.compact_mask(m, capacity),
        in_axes=1, out_axes=0)(mask)                        # (G, Tp), (G,)
    return rows, counts, cnts


@jax.jit
def _count_epilogue(keep, nmatch, base_mask, row_valid):
    """Count-only epilogue: just the Definition-2 ``cnt`` per group.

    No compaction, no row indices -- a count-only selection never
    gathers the rows it would not return (docs/fusion.md).
    """
    mask = keep & base_mask[:, None] & row_valid[:, None]   # (Tp, G)
    return jnp.sum(jnp.where(mask, nmatch, 0), axis=0)      # (G,)


@jax.jit
def _fused_base_mask(cand, seg_of_row, base_vecs):
    """Per-row base-pattern mask for a fused stream.

    ``base_vecs`` is int32 [S, 8] (one ``pattern_vec_from`` per segment);
    each row applies its own segment's bound components and repeated-
    variable equality flags -- the fused-stream generalization of the
    single ``tpf_match`` launch on the same-pattern path.
    """
    bv = base_vecs[jnp.maximum(seg_of_row, 0)]              # (T, 8)
    mask = jnp.ones(cand.shape[0], dtype=bool)
    for i in range(3):
        mask &= (bv[:, i] < 0) | (cand[:, i] == bv[:, i])
    mask &= (bv[:, 3] == 0) | (cand[:, 0] == cand[:, 1])
    mask &= (bv[:, 4] == 0) | (cand[:, 0] == cand[:, 2])
    mask &= (bv[:, 5] == 0) | (cand[:, 1] == cand[:, 2])
    return mask & (seg_of_row >= 0)


@functools.partial(jax.jit, static_argnames=("capacity",))
def _fused_epilogue(keep, nmatch, base_mask, row_valid, seg_onehot,
                    capacity: int):
    """Device epilogue over the fused kernel outputs.

    Like ``_compact_epilogue`` but segment-aware: compacted row indices
    stay ascending per output column, and because every segment owns a
    disjoint ascending row extent of the stream, the per-(segment,
    group) kept counts (``seg_onehot.T @ mask``) let the host split each
    column's index list into per-segment runs without a second pass.
    """
    mask = keep & base_mask[:, None] & row_valid[:, None]       # (Tp, G)
    m32 = mask.astype(jnp.int32)
    seg_counts = seg_onehot.T @ m32                             # (S, G)
    seg_cnts = seg_onehot.T @ jnp.where(mask, nmatch, 0)        # (S, G)
    rows, _counts = jax.vmap(
        lambda m: kops.compact_mask(m, capacity),
        in_axes=1, out_axes=0)(mask)                            # (G, Tp)
    return rows, seg_counts, seg_cnts


@dataclasses.dataclass
class LaunchRecord:
    """Geometry/accounting of one grouped kernel launch.

    The single accounting surface for every accelerated selector path:
    the single-host :class:`KernelSelector` records one per grouped
    bind-join launch (``cand_streamed`` = padded range bucket), the
    mesh-sharded selector (``federation.ShardedSelector``) one per
    window launch (``cand_streamed`` = the per-shard window -- what one
    device streams, independent of range or shard size).

    ``skipped=True`` records a launch that was *avoided* because the
    requested fragment was already resident in the unified fragment
    store (``core/fragments.py``): no candidates were streamed, no
    pattern slots paid, and the server's launch budget must not charge
    it (``Counters.launches_skipped`` counts these instead).

    ``pruned=True`` marks a launch whose candidate stream was the
    Omega-restricted sub-range union instead of the full prefix range
    (``cand_full`` records what the unpruned stream would have been).
    ``fast_path=True`` records a small-work decision: the (post-pruning)
    candidate row count fell below the selector's ``fast_path_rows``
    threshold, so the groups were served by the numpy oracle with no
    kernel launch at all -- the server charges it to
    ``Counters.fast_path_selects``, never to the launch budget.

    ``segments`` counts the distinct triple-pattern segments the launch
    served: 1 for the classic same-pattern grouped launch, >= 2 for a
    cross-pattern fused launch (docs/fusion.md) whose candidate stream
    concatenates every segment's pruned union. ``reclaimed_rows``
    records sub-window compaction on the sharded path: rows inside a
    shard window that ``merge_spans`` proved dead and the launch
    therefore never streamed.
    """

    cand_streamed: int      # padded candidates streamed once (T)
    pat_slots: int          # padded pattern slots across groups (G * Mp)
    groups: int             # requests served by the launch
    skipped: bool = False   # avoided entirely: fragment-store residency
    pruned: bool = False    # streamed the sub-range union, not the range
    cand_full: int = 0      # unpruned stream size (pruning accounting)
    fast_path: bool = False  # routed to the numpy oracle (small work)
    segments: int = 1       # distinct pattern segments fused in the launch
    reclaimed_rows: int = 0  # dead sub-window rows compacted away
    # raw (pre-padding) candidate rows behind cand_streamed; 0 means
    # "not tracked, use cand_streamed". The throughput sim re-derives a
    # fused launch's tile-aligned stream from these, since padding
    # granularity differs between solo (shape bucket) and fused
    # (FUSED_BT tiles) launches.
    cand_rows: int = 0
    # raw full-range rows (pre-padding, pre-pruning): the ceiling the
    # stream flips to when a batch's combined sub-range union stops
    # paying (``pruned`` goes False); lets the sim cap its additive
    # union estimate at the real range size.
    full_rows: int = 0

    @property
    def cells(self) -> int:
        return self.cand_streamed * self.pat_slots


def marshal_pattern_grid(
    tp: TriplePattern, patterns: Sequence[List[TriplePattern]],
    g_slots: int, m_slots: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Encode per-request instantiated-pattern lists as kernel inputs.

    Returns (pats int32 [g_slots, m_slots, 3] with -1 wildcards,
    valid int32 [g_slots, m_slots], base_vec int32 [8] carrying the
    base pattern's components + repeated-variable equality flags).
    Shared by the single-host kernel selector and the sharded windowed
    selector so the two backends cannot drift in how they encode a
    request (``g_slots``/``m_slots`` are each caller's padded grid).
    """
    pats = np.full((g_slots, m_slots, 3), -1, dtype=np.int32)
    valid = np.zeros((g_slots, m_slots), dtype=np.int32)
    for gi, insts in enumerate(patterns):
        for mi, p in enumerate(insts):
            pats[gi, mi] = [c if not is_var(c) else -1
                            for c in p.as_tuple()]
            valid[gi, mi] = 1
    comps = tp.as_tuple()
    base_vec = kops.pattern_vec_from(
        tuple(-1 if is_var(c) else c for c in comps),
        eq_sp=int(is_var(comps[0]) and comps[0] == comps[1]),
        eq_so=int(is_var(comps[0]) and comps[0] == comps[2]),
        eq_po=int(is_var(comps[1]) and comps[1] == comps[2]),
    )
    return pats, valid, base_vec


def stream_order(kept: np.ndarray, first: np.ndarray,
                 insts: List[TriplePattern]) -> np.ndarray:
    """Reorder kept rows into the numpy selector's sequence order.

    The numpy selector concatenates per-pattern match streams in
    pattern order, then dedups keeping first occurrences: a triple
    lands in the stream of the first pattern it matches, and within
    a stream rows ascend by packed key under that pattern's chosen
    index. ``first`` (from the kernel) gives the stream; the packed
    key is recomputed here for the kept rows only. Shared by the
    single-host kernel selector and the sharded windowed selector --
    it is what makes both byte-identical to the oracle.
    """
    sortkey = np.empty(kept.shape[0], dtype=np.int64)
    for j in np.unique(first):
        name, _ = TripleStore._choose_index(insts[j])
        order = _ORDERS[name]
        sel = first == j
        sortkey[sel] = _pack(kept[sel, order[0]], kept[sel, order[1]],
                             kept[sel, order[2]])
    return kept[np.lexsort((sortkey, first))]


def consult_fragments(
    fragments: Optional[FragmentStore], tp: TriplePattern,
    omegas: Sequence[Optional[np.ndarray]],
    launches: List[LaunchRecord],
) -> Tuple[List[Optional[Tuple[np.ndarray, int]]], List[int]]:
    """Serve request groups already resident in the unified fragment
    store; return (results-with-resident-filled, live group indices).

    Shared by the single-host and sharded selectors: each resident
    group's launch share is *skipped* -- recorded as a
    ``LaunchRecord(skipped=True)`` plus ``fragments.note_skip()`` --
    and only the live indices proceed to marshalling/launch. Residency
    peeks are non-counting (the server accounts its own memo lookups
    for the same requests); they do bump the entry's LRU position.
    """
    results: List[Optional[Tuple[np.ndarray, int]]] = [None] * len(omegas)
    if fragments is None:
        return results, list(range(len(omegas)))
    live: List[int] = []
    for i, om in enumerate(omegas):
        got = fragments.peek_data(fragment_key(tp.as_tuple(), om),
                                  touch=True)
        if got is not None:
            results[i] = got
            fragments.note_skip()
            launches.append(LaunchRecord(cand_streamed=0, pat_slots=0,
                                         groups=1, skipped=True))
        else:
            live.append(i)
    return results, live


def record_fragments(
    fragments: Optional[FragmentStore], tp: TriplePattern,
    omegas: Sequence[Optional[np.ndarray]],
    results: Sequence[Tuple[np.ndarray, int]],
) -> None:
    """Register freshly computed selections so the *next* identical
    request -- through any layer -- skips its launch."""
    if fragments is None:
        return
    for om, payload in zip(omegas, results, strict=True):
        fragments.put_data(fragment_key(tp.as_tuple(), om), payload)


def consult_segment(
    fragments: Optional[FragmentStore], seg: FusedSegment,
    results_row: List[Optional[Tuple[np.ndarray, int]]],
    launches: List[LaunchRecord],
) -> List[int]:
    """Fragment-store residency for one fused segment's groups.

    Data segments reuse ``consult_fragments``; count-only groups are
    answered from a resident *data* fragment's cnt (never the other way
    round: a count result carries no rows to reuse). Shared by the
    single-host and sharded fused paths.
    """
    if not seg.count_only:
        res, live = consult_fragments(fragments, seg.tp, seg.omegas,
                                      launches)
        for i, r in enumerate(res):
            if r is not None:
                results_row[i] = r
        return live
    live: List[int] = []
    for i, om in enumerate(seg.omegas):
        got = None
        if fragments is not None:
            got = fragments.peek_data(
                fragment_key(seg.tp.as_tuple(), om), touch=True)
        if got is not None:
            fragments.note_skip()
            launches.append(LaunchRecord(
                cand_streamed=0, pat_slots=0, groups=1, skipped=True))
            results_row[i] = (_EMPTY, int(got[1]))
        else:
            live.append(i)
    return live


def finish_segment(
    fragments: Optional[FragmentStore], seg: FusedSegment,
    omegas_live: Sequence[Optional[np.ndarray]],
    fresh: Sequence[Tuple[np.ndarray, int]],
    results_row: List[Optional[Tuple[np.ndarray, int]]],
    live: Sequence[int],
) -> None:
    """Register fresh results (data segments only) and fill slots."""
    if not seg.count_only:
        record_fragments(fragments, seg.tp, omegas_live, fresh)
    for i, res in zip(live, fresh, strict=True):
        results_row[i] = res


def select_block_numpy(
    block: np.ndarray, tp: TriplePattern,
    patterns: Sequence[List[TriplePattern]],
    count_only: bool = False,
) -> List[Tuple[np.ndarray, int]]:
    """Numpy evaluation of G grouped selections over one candidate block.

    The small-work fast path: computes exactly what the grouped kernel +
    epilogue compute -- per-row first-matching-pattern index, per-row
    matching-pattern count, the base pattern's residual repeated-
    variable/bound-component mask, then the shared ``stream_order``
    epilogue -- so it is byte-identical to both the kernel path and the
    numpy oracle by the same argument, without launching anything and
    without touching the store's memo layers (``block`` is already in
    hand). ``block`` must cover every instantiated pattern's matches and
    contain no duplicate triples (the candidate-range / sub-range-union
    contracts). ``count_only`` skips the kept-row gather and
    ``stream_order`` entirely: only the Definition-2 ``cnt`` is
    produced (count probes never read the rows).
    """
    comps = tp.as_tuple()
    base = np.ones(block.shape[0], dtype=bool)
    for i, c in enumerate(comps):
        if not is_var(c):
            base &= block[:, i] == c
    for i in range(3):
        for j in range(i + 1, 3):
            if is_var(comps[i]) and comps[i] == comps[j]:
                base &= block[:, i] == block[:, j]
    out: List[Tuple[np.ndarray, int]] = []
    empty = np.empty((0, 3), dtype=np.int32)
    for insts in patterns:
        pats = np.asarray([[c if not is_var(c) else -1
                            for c in p.as_tuple()] for p in insts],
                          dtype=np.int32)                    # [M, 3]
        comp = np.ones((block.shape[0], pats.shape[0]), dtype=bool)
        for i in range(3):
            comp &= (pats[None, :, i] < 0) | (
                block[:, i, None] == pats[None, :, i])       # [T, M]
        comp &= base[:, None]
        keep = comp.any(axis=1)
        cnt = int(comp.sum())
        if count_only or not keep.any():
            out.append((empty, cnt))
            continue
        kept = block[keep]
        first = np.argmax(comp[keep], axis=1)    # first matching pattern
        out.append((stream_order(kept, first, list(insts)), cnt))
    return out


class KernelSelector:
    """Bind-join-kernel selector over one :class:`TripleStore`.

    ``fragments`` optionally connects the selector to the unified
    fragment store: selections already resident there are returned
    without a kernel launch (recorded as skipped launches), and fresh
    selections are registered for every other layer to reuse.

    Omega-restricted pruning (docs/pruning.md) is always on: when every
    instantiated pattern binds a prefix of some index order, the launch
    streams the gathered union of their ``(lo, hi)`` sub-ranges
    (:meth:`TripleStore.subranges`) instead of the pattern's full prefix
    range -- byte-identical output, candidate stream shrunk to the
    join-relevant rows. ``fast_path_rows`` > 0 additionally routes
    selections whose (post-pruning) candidate count falls below the
    threshold to the numpy oracle (no launch; recorded in
    :class:`LaunchRecord`).
    """

    def __init__(self, store: TripleStore,
                 fragments: Optional[FragmentStore] = None,
                 fast_path_rows: int = 0) -> None:
        self.store = store
        self.fragments = fragments
        self.fast_path_rows = int(fast_path_rows)
        self.launches: List[LaunchRecord] = []

    # -- public API ----------------------------------------------------------

    def select_with_cnt(
        self, tp: TriplePattern, omega: Optional[np.ndarray],
        insts: Optional[List[TriplePattern]] = None,
    ) -> Tuple[np.ndarray, int]:
        """Kernel-backed ``brtpf_select_with_cnt`` (byte-identical)."""
        return self.select_same_pattern(
            tp, [omega], None if insts is None else [insts])[0]

    def select_same_pattern(
        self, tp: TriplePattern, omegas: Sequence[Optional[np.ndarray]],
        patterns: Optional[List[List[TriplePattern]]] = None,
    ) -> List[Tuple[np.ndarray, int]]:
        """Serve G same-pattern requests from ONE grouped kernel launch.

        ``omegas`` is one entry per request (None = plain TPF selector);
        ``patterns`` optionally carries the already-instantiated pattern
        lists (the server computes them for lookup accounting -- don't
        redo steps 1-3 of the algorithm here).
        Returns per-request (data-triple sequence, cnt), each identical
        to what ``brtpf_select_with_cnt(store, tp, omega_g)`` returns.

        Groups whose selection is already resident in the connected
        fragment store never reach the kernel: their launch share is
        recorded as skipped and only the remaining groups launch.
        """
        if patterns is None:
            patterns = [instantiate_patterns(tp, om) for om in omegas]
        results, live = consult_fragments(self.fragments, tp, omegas,
                                          self.launches)
        if live:
            live_omegas = [omegas[i] for i in live]
            fresh = self._launch_groups(tp, live_omegas,
                                        [patterns[i] for i in live])
            record_fragments(self.fragments, tp, live_omegas, fresh)
            for i, res in zip(live, fresh, strict=True):
                results[i] = res
        return results

    def select_count(self, tp: TriplePattern, omega: Optional[np.ndarray],
                     insts: Optional[List[TriplePattern]] = None) -> int:
        """Count-only selection: Definition-2 ``cnt``, no row gather.

        The standalone count-probe path (docs/fusion.md): the candidate
        stream and the bind-join grid are still evaluated (the count
        needs them) but no kept row is ever compacted, gathered, or
        stream-ordered. A resident data fragment answers for free.
        """
        if self.fragments is not None:
            got = self.fragments.peek_data(
                fragment_key(tp.as_tuple(), omega), touch=True)
            if got is not None:
                self.fragments.note_skip()
                self.launches.append(LaunchRecord(
                    cand_streamed=0, pat_slots=0, groups=1, skipped=True))
                return int(got[1])
        patterns = [insts if insts is not None
                    else instantiate_patterns(tp, omega)]
        return self._launch_groups(tp, [omega], patterns,
                                   count_only=True)[0][1]

    def select_fused(self, segments: Sequence[FusedSegment]
                     ) -> List[List[Tuple[np.ndarray, int]]]:
        """Serve S heterogeneous segments from ONE fused kernel launch.

        Each segment is exactly what ``select_same_pattern`` serves
        alone: one triple pattern plus its request groups. The fused
        path concatenates every segment's (pruned) candidate block into
        one tile-aligned stream, marshals rectangular per-segment slot
        tables, and launches ``kops.bindjoin_fused`` once; the kernel
        resolves each tile's segment from its program id. Residency
        skips, Omega-restricted pruning, the small-work fast path, and
        the ``stream_order`` parity epilogue all behave exactly as on
        the unfused path, so fragments are byte-identical. When
        ``fusion_legality`` refuses (declared dependencies or capacity
        ceilings) or only one segment has launch-worthy work, every
        segment falls back to its own grouped launch.
        """
        results: List[List[Optional[Tuple[np.ndarray, int]]]] = [
            [None] * len(seg.omegas) for seg in segments]
        prepared: List[Tuple[int, List[List[TriplePattern]], List[int]]] = []
        for si, seg in enumerate(segments):
            patterns = seg.patterns
            if patterns is None:
                patterns = [instantiate_patterns(seg.tp, om)
                            for om in seg.omegas]
            live = self._consult_segment(seg, results[si])
            if live:
                prepared.append((si, patterns, live))

        # Per-segment prologue, identical to ``_launch_groups``: range,
        # sub-range union, small-work fast path. Only segments that
        # would genuinely launch join the fused stream.
        work = []
        for si, patterns, live in prepared:
            seg = segments[si]
            omegas_live = [seg.omegas[i] for i in live]
            pats_live = [patterns[i] for i in live]
            rng = self.store.candidate_range(seg.tp)
            full = len(rng)
            if full == 0:
                for i in live:
                    results[si][i] = (_EMPTY, 0)
                continue
            all_insts = [p for group in pats_live for p in group]
            sr = self.store.subranges(seg.tp, insts=all_insts)
            pruned = sr is not None and sr.rows < full
            block = None
            if pruned:
                block = self.store.gather_subranges(sr)
                t = int(block.shape[0])
                if t == 0:
                    for i in live:
                        results[si][i] = (_EMPTY, 0)
                    continue
            else:
                t = full
            if 0 < t <= self.fast_path_rows:
                self.launches.append(LaunchRecord(
                    cand_streamed=t, pat_slots=0, groups=len(live),
                    pruned=pruned, cand_full=full, fast_path=True))
                if block is None:
                    block = rng.triples
                fresh = select_block_numpy(block, seg.tp, pats_live,
                                           count_only=seg.count_only)
                self._finish_segment(seg, omegas_live, fresh,
                                     results[si], live)
                continue
            if block is None:
                block = rng.triples
            work.append((si, pats_live, omegas_live, live, block, t,
                         pruned, full))

        if not work:
            return results

        # Fused geometry: common padded (G, Mp) slot grid, power-of-two
        # segment/tile counts (bounded jit cache), per-segment blocks
        # tile-aligned so every bt-tile belongs to exactly one segment.
        bt = FUSED_BT
        s = len(work)
        s_pad = _pow2_at_least(s)
        g_pad = _pow2_at_least(max(len(w[3]) for w in work))
        m_max = max(max(len(p) for p in w[1]) for w in work)
        mp = kops.padded_pattern_slots(m_max)
        tiles = [-(-w[5] // bt) for w in work]
        total_tiles = sum(tiles)
        reason = fusion_legality(
            [segments[w[0]] for w in work],
            stream_rows=total_tiles * bt, slot_table=s_pad * g_pad * mp)
        if s == 1 or reason is not None:
            # Documented fallback (docs/fusion.md): one grouped launch
            # per segment, same blocks, byte-identical results.
            for si, pats_live, omegas_live, live, block, t, pruned, full \
                    in work:
                seg = segments[si]
                fresh = self._launch_block(
                    seg.tp, pats_live, block, t, pruned, full,
                    count_only=seg.count_only)
                self._finish_segment(seg, omegas_live, fresh,
                                     results[si], live)
            return results

        tiles_pad = _pow2_at_least(total_tiles)
        t_pad = tiles_pad * bt
        cand = np.zeros((t_pad, 3), dtype=np.int32)
        row_valid = np.zeros((t_pad,), dtype=bool)
        seg_of_tile = np.full((tiles_pad,), -1, dtype=np.int32)
        pats_all = np.full((s_pad, g_pad, m_max, 3), -1, dtype=np.int32)
        valid_all = np.zeros((s_pad, g_pad, m_max), dtype=np.int32)
        base_vecs = np.zeros((s_pad, 8), dtype=np.int32)
        cursor = 0
        for wi, (si, pats_live, _om, _live, block, t, _pr, _full) \
                in enumerate(work):
            cand[cursor:cursor + t] = block
            row_valid[cursor:cursor + t] = True
            seg_of_tile[cursor // bt:cursor // bt + tiles[wi]] = wi
            p_grid, v_grid, bv = marshal_pattern_grid(
                segments[si].tp, pats_live, g_pad, m_max)
            pats_all[wi] = p_grid
            valid_all[wi] = v_grid
            base_vecs[wi] = bv
            cursor += tiles[wi] * bt
        seg_of_row = np.repeat(seg_of_tile, bt)
        seg_onehot = (seg_of_row[:, None]
                      == np.arange(s_pad)[None, :]).astype(np.int32)

        keep, idx, nmatch = kops.bindjoin_fused(
            jnp.asarray(cand), jnp.asarray(seg_of_tile),
            jnp.asarray(pats_all), jnp.asarray(valid_all), bt=bt)
        base_mask = _fused_base_mask(
            jnp.asarray(cand), jnp.asarray(seg_of_row),
            jnp.asarray(base_vecs))
        rows, seg_counts, seg_cnts = _fused_epilogue(
            keep, nmatch, base_mask, jnp.asarray(row_valid),
            jnp.asarray(seg_onehot), capacity=t_pad)

        full_tiles = sum(-(-w[7] // bt) for w in work)
        self.launches.append(LaunchRecord(
            cand_streamed=t_pad, pat_slots=g_pad * mp,
            groups=sum(len(w[3]) for w in work),
            pruned=any(w[6] for w in work),
            cand_full=_pow2_at_least(full_tiles) * bt,
            segments=s, cand_rows=sum(w[5] for w in work),
            full_rows=sum(w[7] for w in work)))

        rows = np.asarray(rows)
        seg_counts = np.asarray(seg_counts)
        seg_cnts = np.asarray(seg_cnts)
        idx = np.asarray(idx)
        # Column g's compacted indices ascend, and segments own disjoint
        # ascending row extents: segment wi's run starts after every
        # earlier segment's kept count in that column.
        off = np.cumsum(seg_counts, axis=0) - seg_counts     # (S, G)
        for wi, (si, pats_live, omegas_live, live, _b, _t, _pr, _full) \
                in enumerate(work):
            seg = segments[si]
            fresh: List[Tuple[np.ndarray, int]] = []
            for gi in range(len(live)):
                cnt = int(seg_cnts[wi, gi])
                n = int(seg_counts[wi, gi])
                if seg.count_only or n == 0:
                    fresh.append((_EMPTY, cnt))
                    continue
                kept_rows = rows[gi, off[wi, gi]:off[wi, gi] + n]
                kept = cand[kept_rows]             # tp-index order
                first = idx[kept_rows, gi]
                fresh.append((stream_order(kept, first, pats_live[gi]),
                              cnt))
            self._finish_segment(seg, omegas_live, fresh, results[si],
                                 live)
        return results

    def _consult_segment(self, seg: FusedSegment,
                         results_row: List[Optional[Tuple[np.ndarray, int]]]
                         ) -> List[int]:
        return consult_segment(self.fragments, seg, results_row,
                               self.launches)

    def _finish_segment(self, seg: FusedSegment,
                        omegas_live: Sequence[Optional[np.ndarray]],
                        fresh: Sequence[Tuple[np.ndarray, int]],
                        results_row: List[Optional[Tuple[np.ndarray, int]]],
                        live: Sequence[int]) -> None:
        return finish_segment(self.fragments, seg, omegas_live, fresh,
                              results_row, live)

    def _launch_groups(
        self, tp: TriplePattern, omegas: Sequence[Optional[np.ndarray]],
        patterns: List[List[TriplePattern]], count_only: bool = False,
    ) -> List[Tuple[np.ndarray, int]]:
        """One grouped kernel launch over the store-miss groups."""
        rng = self.store.candidate_range(tp)
        full = len(rng)
        if full == 0:
            return [(_EMPTY, 0)] * len(omegas)

        g = len(omegas)

        # Omega-restricted pruning: the union of the groups' per-binding
        # sub-ranges covers every triple that can match any instantiated
        # pattern, so streaming only that union is exact. The flat
        # (cross-group) instantiation list keeps the grouped geometry:
        # one candidate block still serves all G requests.
        all_insts = [p for group in patterns for p in group]
        sr = self.store.subranges(tp, insts=all_insts)
        pruned = sr is not None and sr.rows < full
        if pruned:
            block = self.store.gather_subranges(sr)
            t = int(block.shape[0])
            if t == 0:
                # no binding has any candidates (e.g. Omega values
                # absent from the store): nothing to stream, cnt = 0
                return [(_EMPTY, 0)] * len(omegas)
        else:
            t = full

        # Small-work fast path: below the threshold the kernel cannot
        # pay its dispatch overhead -- serve the groups from the numpy
        # oracle and record the decision (no kernel launch charged).
        if 0 < t <= self.fast_path_rows:
            self.launches.append(LaunchRecord(
                cand_streamed=t, pat_slots=0, groups=g,
                pruned=pruned, cand_full=full, fast_path=True))
            if not pruned:
                block = rng.triples
            return select_block_numpy(block, tp, patterns,
                                      count_only=count_only)

        if not pruned:
            block = rng.triples
        return self._launch_block(tp, patterns, block, t, pruned, full,
                                  count_only=count_only)

    def _launch_block(
        self, tp: TriplePattern, patterns: List[List[TriplePattern]],
        block: np.ndarray, t: int, pruned: bool, full: int,
        count_only: bool = False,
    ) -> List[Tuple[np.ndarray, int]]:
        """The grouped launch proper, over an already-prepared block.

        Shared by ``_launch_groups`` and ``select_fused``'s legality
        fallback so both take the exact same launch with the exact same
        accounting. ``count_only`` skips the compact/gather/stream
        epilogue: only the per-group Definition-2 counts come back.
        """
        g = len(patterns)
        m = max(len(p) for p in patterns)
        pats, valid, base_vec = marshal_pattern_grid(tp, patterns, g, m)

        # Pad the candidate block to a shape bucket (bounded jit cache).
        tpad = _bucket(t)
        cand = np.zeros((tpad, 3), dtype=np.int32)
        cand[:t] = block
        row_valid = np.zeros((tpad,), dtype=bool)
        row_valid[:t] = True

        keep, idx, nmatch = kops.bindjoin_grouped(
            jnp.asarray(cand), jnp.asarray(pats), jnp.asarray(valid))
        base_mask = kops.tpf_match(jnp.asarray(cand), jnp.asarray(base_vec))

        mp = kops.padded_pattern_slots(m)
        self.launches.append(
            LaunchRecord(cand_streamed=tpad, pat_slots=g * mp, groups=g,
                         pruned=pruned, cand_full=_bucket(full),
                         cand_rows=t, full_rows=full))

        if count_only:
            cnts = _count_epilogue(keep, nmatch, base_mask,
                                   jnp.asarray(row_valid))
            return [(_EMPTY, int(c)) for c in np.asarray(cnts)]

        rows, counts, cnts = _compact_epilogue(
            keep, idx, nmatch, base_mask, jnp.asarray(row_valid),
            capacity=tpad)
        rows = np.asarray(rows)
        counts = np.asarray(counts)
        cnts = np.asarray(cnts)
        idx = np.asarray(idx)
        out: List[Tuple[np.ndarray, int]] = []
        for gi in range(g):
            n = int(counts[gi])
            if n == 0:
                out.append((_EMPTY, int(cnts[gi])))
                continue
            kept_rows = rows[gi, :n]
            kept = cand[kept_rows]                 # tp-index order
            first = idx[kept_rows, gi]             # first matching pattern
            out.append((self._stream_order(kept, first, patterns[gi]),
                        int(cnts[gi])))
        return out

    # -- ordering epilogue ---------------------------------------------------

    def _stream_order(self, kept: np.ndarray, first: np.ndarray,
                      insts: List[TriplePattern]) -> np.ndarray:
        return stream_order(kept, first, insts)
