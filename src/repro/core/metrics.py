"""Request/transfer accounting -- the paper's evaluation metrics.

* ``num_requests`` (#req): fragment *pages* requested (section 5.1 --
  "the measurements for #req ... correspond ... to the number of pages
  requested").
* ``data_received`` (dataRecv): RDF triples contained in all fragment
  pages received, data + metadata/control triples (section 5.1).
* ``cache_hits`` (#hits): requests served by the HTTP cache (section 7.1).
* server/client work counters feed the throughput simulation (section 6).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Counters:
    num_requests: int = 0
    data_received: int = 0          # triples (data + metadata)
    data_triples: int = 0           # data triples only
    meta_triples: int = 0
    cache_hits: int = 0
    server_lookups: int = 0         # index lookups performed by the server
    server_triples_scanned: int = 0
    mappings_sent: int = 0          # solution mappings attached to requests
    # kernel-selector launch accounting (selector_backend="kernel"):
    kernel_launches: int = 0        # grouped bind-join kernel launches
    kernel_cand_streamed: int = 0   # padded candidates streamed (HBM pass)
    kernel_pat_slots: int = 0       # padded pattern slots across groups
    kernel_batched_requests: int = 0  # requests served by shared launches

    def merge(self, other: "Counters") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))

    def snapshot(self) -> "Counters":
        return dataclasses.replace(self)

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)
