"""Request/transfer accounting -- the paper's evaluation metrics.

* ``num_requests`` (#req): fragment *pages* requested (section 5.1 --
  "the measurements for #req ... correspond ... to the number of pages
  requested").
* ``data_received`` (dataRecv): RDF triples contained in all fragment
  pages received, data + metadata/control triples (section 5.1).
* ``cache_hits`` (#hits): requests served by the HTTP cache (section 7.1).
* ``launches_skipped``: requests served from the unified fragment store
  (``core/fragments.py``) that would otherwise have reached an
  accelerated selector -- kernel/window launches avoided by residency.
* server/client work counters feed the throughput simulation (section 6).

:func:`metrics_snapshot` is the ONE observability schema (brtpf/v1):
counters plus the per-layer surface over the unified store -- the HTTP
cache's section-7 hit rate, the selector-memo (data-layer) hit rate,
the candidate-range memo hit rate and the skipped-launch count -- each
layer accounted separately, so memo traffic can never masquerade as
HTTP hits. ``BrTPFServer.metrics_snapshot()``, the async front end's
``AsyncBrTPFServer.metrics_snapshot()``, the replica router's merged
snapshot and the ASGI app's ``GET /metrics`` all emit THIS schema, so
the sim ``--live`` loop and the closed-loop load generator read the
same keys over the wire as in-process. (:func:`layer_metrics` is the
pre-PR-7 name, kept as an alias.)

:func:`latency_summary` is the shared latency-quantile schema of the
closed-loop load generator (``benchmarks/latency.py``): p50/p95/p99
latency in milliseconds plus ``req_per_s`` -- the SLO quantities the
``loopback:*`` budget gates bound.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass
class Counters:
    num_requests: int = 0
    data_received: int = 0          # triples (data + metadata)
    data_triples: int = 0           # data triples only
    meta_triples: int = 0
    cache_hits: int = 0
    server_lookups: int = 0         # index lookups performed by the server
    server_triples_scanned: int = 0
    mappings_sent: int = 0          # solution mappings attached to requests
    # kernel-selector launch accounting (selector_backend="kernel"):
    kernel_launches: int = 0        # grouped bind-join kernel launches
    kernel_cand_streamed: int = 0   # padded candidates streamed (HBM pass)
    kernel_cand_rows: int = 0       # raw (pre-padding) candidate rows
    kernel_cand_full_rows: int = 0  # raw full-range rows behind launches
    kernel_pat_slots: int = 0       # padded pattern slots across groups
    kernel_batched_requests: int = 0  # requests served by shared launches
    launches_skipped: int = 0       # launches avoided by store residency
    # Omega-restricted pruning / small-work fast path (docs/pruning.md):
    cand_pruned_away: int = 0       # candidate rows NOT streamed thanks
    #                                 to sub-range pruning (full - pruned)
    fast_path_selects: int = 0      # requests served by the numpy block
    #                                 evaluation instead of a launch
    # Cross-pattern kernel fusion (docs/fusion.md). These classify a
    # subset of kernel_launches (a fused launch IS a kernel launch);
    # they are descriptive shape counters, not request dispositions.
    fused_launches: int = 0         # launches serving >= 2 segments
    fused_segments: int = 0         # segments across fused launches

    def merge(self, other: "Counters") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))

    def snapshot(self) -> "Counters":
        return dataclasses.replace(self)

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)


METRICS_VERSION = "brtpf/v1"


def metrics_snapshot(server, batch=None) -> dict:
    """Canonical per-server metrics envelope (brtpf/v1 schema).

    Duck-typed on the server (``fragments``, ``store``, optional
    ``cache``) so this module stays import-light. Each layer reports
    its own hits/misses/hit_rate; ``launches_skipped`` is the unified
    store's count of kernel/window launches avoided by residency.
    ``batch`` optionally attaches an async front end's
    :class:`~repro.core.batching.BatchStats` under ``"batch"`` (the
    flush/coalescing accounting the wire exposes at ``GET /metrics``).

    Every value is a plain int/float/dict: the snapshot is JSON-safe by
    construction, so the in-process dict and the ``GET /metrics`` body
    are the same object modulo serialization.
    """
    f = server.fragments
    # Range-memo accounting is reported as THIS server's delta (the
    # store, and its counters, may be shared across servers -- e.g. the
    # benchmarks' one dataset store); probe paths additionally never
    # charge misses (store.candidate_range(memoize=False)), so the rate
    # below describes real streaming reads only.
    base_hits, base_misses = getattr(server, "_range_base", (0, 0))
    r_hits = server.store.range_memo_hits - base_hits
    r_misses = server.store.range_memo_misses - base_misses
    out = {
        "v": METRICS_VERSION,
        "counters": dataclasses.asdict(server.counters),
        "launches_skipped": f.launches_skipped,
        "selector_memo": {
            "hits": f.hits,
            "misses": f.misses,
            "hit_rate": f.hit_rate,
            "entries": f.data_entries,
        },
        "range_memo": {
            "hits": r_hits,
            "misses": r_misses,
            "hit_rate": r_hits / max(r_hits + r_misses, 1),
        },
        # mean segments per fused launch (1.0-equivalent batches never
        # fuse, so 0.0 means "no fusion happened"): the headline shape
        # metric of docs/fusion.md, derived here so every surface (wire
        # and in-process) computes it identically.
        "fused_segments_per_launch": (
            server.counters.fused_segments
            / max(server.counters.fused_launches, 1)),
    }
    if server.cache is not None:
        out["http"] = {
            "hits": server.cache.hits,
            "misses": server.cache.misses,
            "hit_rate": server.cache.hit_rate,
            "entries": len(server.cache),
        }
    sel = getattr(server, "_selector", None)
    if sel is not None and hasattr(sel, "shard_balance"):
        # per-shard balance (sharded backend): the heat source AND its
        # verification surface (docs/federation.md, "Placement")
        out["shards"] = sel.shard_balance()
    if batch is not None:
        out["batch"] = {
            "requests": batch.requests,
            "rejected": batch.rejected,
            "fast_path": batch.fast_path,
            "flushes": batch.flushes,
            "timer_flushes": batch.timer_flushes,
            "full_flushes": batch.full_flushes,
            "coalesced_requests": batch.coalesced_requests,
            "max_batch_seen": batch.max_batch_seen,
            "mean_batch": batch.mean_batch,
            "shed": batch.shed,
        }
        out["resilience"] = resilience_section(shed=batch.shed)
    return out


# Pre-PR-7 name for the same snapshot; callers should migrate to
# metrics_snapshot (one schema, shared with GET /metrics).
layer_metrics = metrics_snapshot


def resilience_section(retries: int = 0, hedges: int = 0,
                       hedge_wins: int = 0, shed: int = 0,
                       deadline_exceeded: int = 0, giveups: int = 0,
                       breaker: Optional[dict] = None) -> dict:
    """The ``"resilience"`` section of :func:`metrics_snapshot`
    (docs/resilience.md) -- one schema for every surface that reports
    fault-tolerance accounting:

    * a lone async front end reports only ``shed`` (its deadline-aware
      shedding is the one resilience mechanism that lives server-side);
    * the replica router adds the summed replica ``shed`` plus its
      ``breaker`` sub-section (state machine transitions/opens/failovers
      per docs/resilience.md);
    * a :class:`~repro.serving.resilience.ResilientTransport` overlays
      its client-side ``retries`` / ``hedges`` / ``hedge_wins`` /
      ``deadline_exceeded`` / ``giveups`` when asked for metrics, so
      ``GET /metrics`` through a resilient client shows the whole
      retry/hedge/shed story in one envelope.
    """
    out = {
        "retries": int(retries),
        "hedges": int(hedges),
        "hedge_wins": int(hedge_wins),
        "shed": int(shed),
        "deadline_exceeded": int(deadline_exceeded),
        "giveups": int(giveups),
    }
    if breaker is not None:
        out["breaker"] = breaker
    return out


def chaos_summary(ok: int, failed: int, failed_queries: int,
                  samples_s: Sequence[float],
                  wall_s: Optional[float] = None,
                  parity: float = 1.0) -> dict:
    """Outcome schema of the chaos benchmark (``benchmarks/chaos.py``)
    -- the quantities the ``chaos_c16:*`` budget gates bound.

    ``ok``/``failed`` count client-visible request outcomes AFTER the
    resilience layer did its work (a request that succeeded on retry 3
    is one ``ok``); ``failed_queries`` counts whole BGP executions
    abandoned because one of their requests exhausted every attempt;
    ``parity`` is 1.0 iff every query that completed under faults
    produced byte-identical solutions to the fault-free oracle.
    Latency quantiles ride along via :func:`latency_summary` so the same
    run gates both availability and tail latency.
    """
    total = ok + failed
    out = {
        "ok": int(ok),
        "failed": int(failed),
        "failed_queries": int(failed_queries),
        "success_rate": ok / total if total else 0.0,
        "parity": float(parity),
    }
    out.update(latency_summary(samples_s, wall_s))
    return out


def shard_balance(launches: Sequence[int], rows: Sequence[int],
                  pages: Sequence[int]) -> dict:
    """Per-shard balance schema (the ``shards`` section of
    :func:`metrics_snapshot`, docs/federation.md "Placement").

    ``launches``/``rows``/``pages`` are the selector's per-shard
    attribution counters: launches the shard had work in, candidate rows
    it streamed, planned window pages it owned. ``imbalance`` is
    max/mean launches per shard -- 1.0 is perfectly balanced, ``shards``x
    is everything on one shard; the quantity the workload-aware
    re-partitioner minimizes and the ``skew_c16:*`` budgets gate.
    """
    launches = [int(x) for x in launches]
    rows = [int(x) for x in rows]
    pages = [int(x) for x in pages]
    mean = sum(launches) / max(len(launches), 1)
    return {
        "launches": launches,
        "rows": rows,
        "pages": pages,
        "imbalance": (max(launches) / mean) if mean > 0 else 0.0,
    }


def rebalance_report(uniform: dict, heat: dict) -> dict:
    """Before/after schema for a repartition A/B (the skew benchmark's
    budget surface): :func:`shard_balance` snapshots measured under the
    workload-blind equal split (``uniform``) and under the heat-planned
    placement (``heat``). ``imbalance_drop`` > 1 means the re-partition
    helped; the ``skew_c16:imbalance_drop`` budget gates it >= 2.
    """
    drop = uniform["imbalance"] / max(heat["imbalance"], 1e-9)
    return {
        "imbalance_uniform": uniform["imbalance"],
        "imbalance_heat": heat["imbalance"],
        "imbalance_drop": drop,
        "shard_launches_uniform": uniform["launches"],
        "shard_launches_heat": heat["launches"],
    }


def latency_summary(samples_s: Sequence[float],
                    wall_s: Optional[float] = None) -> dict:
    """Latency-quantile schema shared by the closed-loop load generator
    and the ``loopback:*`` budget gates: per-request latencies (seconds)
    -> p50/p95/p99/mean milliseconds + closed-loop ``req_per_s``.

    Quantiles use the nearest-rank method on the sorted samples -- no
    numpy dependency, deterministic, and exact for the small sample
    counts a smoke run produces.
    """
    n = len(samples_s)
    if n == 0:
        return {"requests": 0, "p50_latency_ms": 0.0,
                "p95_latency_ms": 0.0, "p99_latency_ms": 0.0,
                "mean_latency_ms": 0.0, "req_per_s": 0.0}
    ordered = sorted(samples_s)

    def rank_ms(q: float) -> float:
        idx = min(n - 1, max(0, int(q * n + 0.5) - 1))
        return ordered[idx] * 1e3

    wall = wall_s if wall_s is not None else sum(ordered)
    return {
        "requests": n,
        "p50_latency_ms": rank_ms(0.50),
        "p95_latency_ms": rank_ms(0.95),
        "p99_latency_ms": rank_ms(0.99),
        "mean_latency_ms": sum(ordered) / n * 1e3,
        "req_per_s": n / max(wall, 1e-9),
    }
