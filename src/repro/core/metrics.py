"""Request/transfer accounting -- the paper's evaluation metrics.

* ``num_requests`` (#req): fragment *pages* requested (section 5.1 --
  "the measurements for #req ... correspond ... to the number of pages
  requested").
* ``data_received`` (dataRecv): RDF triples contained in all fragment
  pages received, data + metadata/control triples (section 5.1).
* ``cache_hits`` (#hits): requests served by the HTTP cache (section 7.1).
* ``launches_skipped``: requests served from the unified fragment store
  (``core/fragments.py``) that would otherwise have reached an
  accelerated selector -- kernel/window launches avoided by residency.
* server/client work counters feed the throughput simulation (section 6).

:func:`layer_metrics` is the per-layer observability surface over the
unified store: one snapshot with the HTTP cache's section-7 hit rate,
the selector-memo (data-layer) hit rate, the candidate-range memo hit
rate and the skipped-launch count -- each layer accounted separately,
so memo traffic can never masquerade as HTTP hits.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Counters:
    num_requests: int = 0
    data_received: int = 0          # triples (data + metadata)
    data_triples: int = 0           # data triples only
    meta_triples: int = 0
    cache_hits: int = 0
    server_lookups: int = 0         # index lookups performed by the server
    server_triples_scanned: int = 0
    mappings_sent: int = 0          # solution mappings attached to requests
    # kernel-selector launch accounting (selector_backend="kernel"):
    kernel_launches: int = 0        # grouped bind-join kernel launches
    kernel_cand_streamed: int = 0   # padded candidates streamed (HBM pass)
    kernel_pat_slots: int = 0       # padded pattern slots across groups
    kernel_batched_requests: int = 0  # requests served by shared launches
    launches_skipped: int = 0       # launches avoided by store residency
    # Omega-restricted pruning / small-work fast path (docs/pruning.md):
    cand_pruned_away: int = 0       # candidate rows NOT streamed thanks
    #                                 to sub-range pruning (full - pruned)
    fast_path_selects: int = 0      # requests served by the numpy block
    #                                 evaluation instead of a launch

    def merge(self, other: "Counters") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))

    def snapshot(self) -> "Counters":
        return dataclasses.replace(self)

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)


def layer_metrics(server) -> dict:
    """Per-layer cache accounting snapshot for a ``BrTPFServer``.

    Duck-typed on the server (``fragments``, ``store``, optional
    ``cache``) so this module stays import-light. Each layer reports
    its own hits/misses/hit_rate; ``launches_skipped`` is the unified
    store's count of kernel/window launches avoided by residency.
    """
    f = server.fragments
    # Range-memo accounting is reported as THIS server's delta (the
    # store, and its counters, may be shared across servers -- e.g. the
    # benchmarks' one dataset store); probe paths additionally never
    # charge misses (store.candidate_range(memoize=False)), so the rate
    # below describes real streaming reads only.
    base_hits, base_misses = getattr(server, "_range_base", (0, 0))
    r_hits = server.store.range_memo_hits - base_hits
    r_misses = server.store.range_memo_misses - base_misses
    out = {
        "counters": dataclasses.asdict(server.counters),
        "launches_skipped": f.launches_skipped,
        "selector_memo": {
            "hits": f.hits,
            "misses": f.misses,
            "hit_rate": f.hit_rate,
            "entries": f.data_entries,
        },
        "range_memo": {
            "hits": r_hits,
            "misses": r_misses,
            "hit_rate": r_hits / max(r_hits + r_misses, 1),
        },
    }
    if server.cache is not None:
        out["http"] = {
            "hits": server.cache.hits,
            "misses": server.cache.misses,
            "hit_rate": server.cache.hit_rate,
            "entries": len(server.cache),
        }
    return out
