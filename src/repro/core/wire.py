"""Versioned wire schema for the brTPF serving edge (``brtpf/v1``).

Until PR 7 ``Request`` and ``Fragment`` were numpy-bearing value objects
with no serialization: nothing could cross a process boundary, so every
"network" measurement in the repo was an in-process method call. This
module defines the one JSON envelope both sides of the wire speak:

* every envelope carries ``{"v": "brtpf/v1", "kind": ...}``;
* a ``request`` envelope is the request URL's content -- the triple
  pattern as a 3-list of ints (constants >= 0, variables < 0 per
  ``core/rdf.py``), the Omega *sequence* as a list of int lists (order
  preserved -- Definition 2 insists Omega is a sequence, and the page
  contents depend on it), and the page number;
* a ``fragment`` envelope carries the page's data triples, the
  fragment-level ``cnt`` estimate, and the paging / metadata-control
  fields (``meta_triples`` preserved so dataRecv accounting is identical
  over the wire);
* a ``metrics`` envelope wraps :func:`repro.core.metrics.metrics_snapshot`;
* an ``error`` envelope maps server-side failures onto HTTP statuses
  (``MaxMprExceeded`` -> 414, exactly like the paper's URL-length bound).

The HTTP transport (``repro.serving.http``) and the in-process loopback
transport (``repro.serving.transport.LoopbackTransport``) both
round-trip through THESE functions, so transport parity is asserted on
the same envelope -- not on two parallel encoders.

Decoding is strict: a missing/foreign version tag or a malformed body
raises :class:`WireError` (HTTP 400), never a silent best-effort parse.
"""
from __future__ import annotations

import json
from typing import Optional

import numpy as np

from .rdf import TriplePattern

WIRE_VERSION = "brtpf/v1"

KIND_REQUEST = "request"
KIND_FRAGMENT = "fragment"
KIND_METRICS = "metrics"
KIND_ERROR = "error"


class WireError(ValueError):
    """Malformed or version-incompatible wire envelope (HTTP 400)."""


def envelope(kind: str, **fields) -> dict:
    return {"v": WIRE_VERSION, "kind": kind, **fields}


def check_envelope(obj, kind: str) -> dict:
    if not isinstance(obj, dict):
        raise WireError(f"envelope must be a JSON object, got "
                        f"{type(obj).__name__}")
    v = obj.get("v")
    if v != WIRE_VERSION:
        raise WireError(f"unsupported wire version {v!r} "
                        f"(this server speaks {WIRE_VERSION})")
    k = obj.get("kind")
    if k != kind:
        raise WireError(f"expected a {kind!r} envelope, got {k!r}")
    return obj


def _int_list(values, what: str) -> list:
    try:
        return [int(x) for x in values]
    except (TypeError, ValueError) as exc:
        raise WireError(f"{what} must be a list of ints: {exc}") from None


# ---------------------------------------------------------------------------
# Request
# ---------------------------------------------------------------------------


def request_to_wire(req) -> dict:
    """Encode a :class:`~repro.core.server.Request` (brtpf/v1)."""
    omega = None
    omega_vars = None
    if req.omega is not None:
        om = np.asarray(req.omega)
        omega = [[int(x) for x in row] for row in om.tolist()]
        omega_vars = int(om.shape[1]) if om.ndim == 2 else 0
    out = envelope(
        KIND_REQUEST,
        pattern=[int(c) for c in req.pattern.as_tuple()],
        omega=omega,
        omega_vars=omega_vars,
        page=int(req.page),
    )
    # count probes (docs/fusion.md): emitted only when set, so v1 bodies
    # from pre-fusion clients stay byte-identical
    if getattr(req, "count_only", False):
        out["count_only"] = True
    # remaining deadline budget (docs/resilience.md): same
    # emit-only-when-set rule, so deadline-less clients keep producing
    # byte-identical request bodies
    if getattr(req, "timeout_ms", None) is not None:
        out["timeout_ms"] = float(req.timeout_ms)
    return out


def request_from_wire(obj):
    """Decode a ``request`` envelope; inverse of :func:`request_to_wire`."""
    from .server import Request  # no cycle: server never imports wire
    obj = check_envelope(obj, KIND_REQUEST)
    pattern = obj.get("pattern")
    if not isinstance(pattern, (list, tuple)) or len(pattern) != 3:
        raise WireError("'pattern' must be a 3-list [s, p, o]")
    tp = TriplePattern(*_int_list(pattern, "'pattern'"))
    omega = None
    if obj.get("omega") is not None:
        rows = obj["omega"]
        if not isinstance(rows, (list, tuple)):
            raise WireError("'omega' must be a list of mapping rows")
        nv = obj.get("omega_vars")
        if nv is None:
            nv = len(rows[0]) if rows else 0
        flat = [_int_list(row, "omega row") for row in rows]
        if any(len(r) != nv for r in flat):
            raise WireError(f"omega rows must all have {nv} columns")
        omega = np.asarray(flat, dtype=np.int32).reshape(len(flat), int(nv))
    page = obj.get("page", 0)
    if not isinstance(page, int) or page < 0:
        raise WireError("'page' must be a non-negative int")
    count_only = obj.get("count_only", False)
    if not isinstance(count_only, bool):
        raise WireError("'count_only' must be a bool")
    timeout_ms = obj.get("timeout_ms")
    if timeout_ms is not None:
        if (isinstance(timeout_ms, bool)
                or not isinstance(timeout_ms, (int, float))
                or not timeout_ms > 0):
            raise WireError("'timeout_ms' must be a positive number")
        timeout_ms = float(timeout_ms)
    return Request(pattern=tp, omega=omega, page=page,
                   count_only=count_only, timeout_ms=timeout_ms)


# ---------------------------------------------------------------------------
# Fragment
# ---------------------------------------------------------------------------


def fragment_to_wire(frag) -> dict:
    """Encode a :class:`~repro.core.selectors.Fragment` page (brtpf/v1).

    ``meta_triples`` (the page's metadata/control triple count) and
    ``cnt`` ride along so the client-side dataRecv / cardinality
    accounting over the wire matches the in-process numbers exactly.
    """
    data = np.asarray(frag.data)
    return envelope(
        KIND_FRAGMENT,
        data=[[int(x) for x in row] for row in data.tolist()],
        cnt=int(frag.cnt),
        page=int(frag.page),
        page_size=int(frag.page_size),
        has_next=bool(frag.has_next),
        meta_triples=int(frag.meta_triples),
    )


def fragment_from_wire(obj):
    """Decode a ``fragment`` envelope; inverse of :func:`fragment_to_wire`."""
    from .selectors import Fragment  # no cycle: selectors never imports wire
    obj = check_envelope(obj, KIND_FRAGMENT)
    rows = obj.get("data")
    if not isinstance(rows, (list, tuple)):
        raise WireError("'data' must be a list of triples")
    flat = [_int_list(row, "data triple") for row in rows]
    if any(len(r) != 3 for r in flat):
        raise WireError("data triples must have 3 components")
    data = np.asarray(flat, dtype=np.int32).reshape(len(flat), 3)
    try:
        return Fragment(
            data=data,
            cnt=int(obj["cnt"]),
            page=int(obj["page"]),
            page_size=int(obj["page_size"]),
            has_next=bool(obj["has_next"]),
            meta_triples=int(obj["meta_triples"]),
        )
    except KeyError as exc:
        raise WireError(f"fragment envelope missing field {exc}") from None


# ---------------------------------------------------------------------------
# Errors / serialization helpers
# ---------------------------------------------------------------------------


# Machine-readable error codes (docs/serving.md has the full table of
# status <-> code <-> retryability). Strings, not ints: a code names the
# CONDITION (so clients can branch without parsing messages), while the
# status stays the HTTP mapping.
ERROR_CODES = (
    "BAD_REQUEST",         # 400 -- malformed brtpf/v1 envelope
    "NOT_FOUND",           # 404 -- unknown route
    "METHOD_NOT_ALLOWED",  # 405 -- wrong verb on a known route
    "MAX_MPR_EXCEEDED",    # 414 -- |Omega| > maxMpR (paper's URL bound)
    "QUEUE_SATURATED",     # 503 -- admission control; retryable
    "DEADLINE_EXCEEDED",   # 504 -- deadline budget exhausted; retryable
    "INTERNAL",            # 500 -- unclassified server failure
)


def error_to_wire(status: int, message: str, retryable: bool = False,
                  code: Optional[str] = None,
                  retry_after_ms: Optional[float] = None) -> dict:
    out = envelope(KIND_ERROR, status=int(status), error=str(message))
    if retryable:
        # advisory: the condition is transient (e.g. 503 admission
        # control -- the batching queue drains within one window) and
        # the client should retry after backoff. Omitted when False so
        # pre-existing error envelopes stay byte-identical.
        out["retryable"] = True
    if code is not None:
        if code not in ERROR_CODES:
            raise ValueError(f"unknown wire error code {code!r}")
        out["code"] = code
    if retry_after_ms is not None:
        # RETRY_AFTER hint (docs/resilience.md): a floor for the
        # client's backoff, e.g. one batching window on 503. Like
        # retryable/code it is emitted only when set.
        out["retry_after_ms"] = float(retry_after_ms)
    return out


def error_from_wire(obj) -> dict:
    """Decode an ``error`` envelope (strict; the round-trip inverse of
    :func:`error_to_wire`). Returns a normalized dict with ``status``,
    ``error``, ``retryable`` (defaulted False), ``code`` and
    ``retry_after_ms`` (defaulted None) -- what a transport needs to
    build a :class:`~repro.serving.transport.TransportError`."""
    obj = check_envelope(obj, KIND_ERROR)
    status = obj.get("status")
    if isinstance(status, bool) or not isinstance(status, int):
        raise WireError("'status' must be an int")
    message = obj.get("error")
    if not isinstance(message, str):
        raise WireError("'error' must be a string")
    retryable = obj.get("retryable", False)
    if not isinstance(retryable, bool):
        raise WireError("'retryable' must be a bool")
    code = obj.get("code")
    if code is not None and code not in ERROR_CODES:
        raise WireError(f"unknown wire error code {code!r}")
    retry_after_ms = obj.get("retry_after_ms")
    if retry_after_ms is not None:
        if (isinstance(retry_after_ms, bool)
                or not isinstance(retry_after_ms, (int, float))
                or not retry_after_ms >= 0):
            raise WireError("'retry_after_ms' must be a non-negative "
                            "number")
        retry_after_ms = float(retry_after_ms)
    return {"status": status, "error": message, "retryable": retryable,
            "code": code, "retry_after_ms": retry_after_ms}


def dumps(obj: dict) -> bytes:
    """Canonical envelope serialization (compact separators -- the byte
    payload is what the network-load benchmarks weigh)."""
    return json.dumps(obj, separators=(",", ":")).encode("utf-8")


def loads(raw: bytes) -> dict:
    try:
        obj = json.loads(raw.decode("utf-8") if isinstance(raw, bytes)
                         else raw)
    except (ValueError, UnicodeDecodeError) as exc:
        raise WireError(f"invalid JSON body: {exc}") from None
    if not isinstance(obj, dict):
        raise WireError("wire payload must be a JSON object")
    return obj
