"""The combined TPF/brTPF server (paper section 4.1).

One servlet-equivalent component serves both interfaces: a request with a
bindings-restricted selector takes the brTPF path, a plain triple-pattern
request takes the TPF path. Shared machinery (paging, metadata triples,
accounting) is common to both so comparisons are fair -- mirroring the
paper's single-servlet design.

Requests and responses are value objects; the "HTTP layer" is the
``handle`` call boundary, and network metrics are charged per page
exactly as in section 5.1.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

from .cache import LRUCache, request_key
from .metrics import Counters
from .rdf import TriplePattern
from .selectors import (Fragment, brtpf_select_with_cnt,
                        instantiate_patterns, tpf_select)
from .store import TripleStore

# Number of metadata + hypermedia-control triples per fragment page. A
# real TPF page carries void:triples counts, next/prev page links and the
# interface's hypermedia controls; the reference server emits ~8-30 such
# triples per page. The *value* only scales the constant page overhead --
# the paper's findings are about how the number of pages differs between
# TPF and brTPF -- so it is configurable.
DEFAULT_META_TRIPLES_PER_PAGE = 8
DEFAULT_PAGE_SIZE = 100
DEFAULT_MAX_MPR = 30


@dataclasses.dataclass(frozen=True)
class Request:
    """A (br)TPF page request.

    ``omega`` is None for pure TPF requests; otherwise an int32 [M, V]
    sequence of solution mappings with M <= maxMpR (server-enforced).
    """

    pattern: TriplePattern
    omega: Optional[np.ndarray] = None
    page: int = 0

    def key(self):
        om = None
        if self.omega is not None:
            om = tuple(map(tuple, np.asarray(self.omega).tolist()))
        return request_key(self.pattern.as_tuple(), om, self.page)

    @property
    def is_brtpf(self) -> bool:
        return self.omega is not None and self.omega.shape[0] > 0


class MaxMprExceeded(ValueError):
    """HTTP 414 equivalent: too many mappings attached to one request."""


class BrTPFServer:
    """Combined TPF/brTPF server over a :class:`TripleStore`."""

    def __init__(
        self,
        store: TripleStore,
        page_size: int = DEFAULT_PAGE_SIZE,
        max_mpr: int = DEFAULT_MAX_MPR,
        meta_triples_per_page: int = DEFAULT_META_TRIPLES_PER_PAGE,
        cache: Optional[LRUCache] = None,
    ) -> None:
        self.store = store
        self.page_size = int(page_size)
        self.max_mpr = int(max_mpr)
        self.meta_triples_per_page = int(meta_triples_per_page)
        self.cache = cache
        self.counters = Counters()
        # Selector memo: a real server streams a fragment across its
        # pages instead of recomputing the selection per page request.
        # This memo models that (it is NOT the HTTP cache of section 7 --
        # it does not affect any metric, only host CPU time).
        self._selector_memo: "OrderedDict" = OrderedDict()
        self._selector_memo_cap = 256

    # -- request handling ---------------------------------------------------

    def handle(self, req: Request) -> Fragment:
        """Serve one page request (the HTTP GET boundary)."""
        self.counters.num_requests += 1
        if req.omega is not None and req.omega.shape[0] > self.max_mpr:
            raise MaxMprExceeded(
                f"{req.omega.shape[0]} mappings > maxMpR={self.max_mpr}"
            )

        if self.cache is not None:
            cached = self.cache.get(req.key())
            if cached is not None:
                frag = cached  # served by the proxy, not the origin
                self._charge_transfer(frag)
                return frag

        frag = self._compute(req)
        if self.cache is not None:
            self.cache.put(req.key(), frag)
        self._charge_transfer(frag)
        return frag

    def _charge_transfer(self, frag: Fragment) -> None:
        self.counters.data_triples += int(frag.data.shape[0])
        self.counters.meta_triples += frag.meta_triples
        self.counters.data_received += frag.triples_received

    # -- origin-server computation (section 4.1) ----------------------------

    def _compute(self, req: Request) -> Fragment:
        memo_key = req.key()[:2]  # (pattern, omega) -- page-independent
        memo = self._selector_memo.get(memo_key)
        if memo is not None:
            self._selector_memo.move_to_end(memo_key)
            data, cnt = memo
            # work accounting still charges the originating computation
            # only once -- matching the paper's streaming server.
        elif req.is_brtpf:
            patterns = instantiate_patterns(req.pattern, req.omega)
            self.counters.server_lookups += len(patterns)
            data, cnt = brtpf_select_with_cnt(self.store, req.pattern,
                                              req.omega)
        else:
            self.counters.server_lookups += 1
            data = tpf_select(self.store, req.pattern)
            cnt = self.store.cardinality(req.pattern)
        if memo is None:
            self.counters.server_triples_scanned += int(data.shape[0])
            self._selector_memo[memo_key] = (data, cnt)
            if len(self._selector_memo) > self._selector_memo_cap:
                self._selector_memo.popitem(last=False)

        lo = req.page * self.page_size
        page = data[lo : lo + self.page_size]
        return Fragment(
            data=page,
            cnt=cnt,
            page=req.page,
            page_size=self.page_size,
            has_next=lo + self.page_size < data.shape[0],
            meta_triples=self.meta_triples_per_page,
        )

    # -- convenience ---------------------------------------------------------

    def reset_counters(self) -> None:
        self.counters.reset()
        if self.cache is not None:
            self.cache.hits = 0
            self.cache.misses = 0
