"""The combined TPF/brTPF server (paper section 4.1).

One servlet-equivalent component serves both interfaces: a request with a
bindings-restricted selector takes the brTPF path, a plain triple-pattern
request takes the TPF path. Shared machinery (paging, metadata triples,
accounting) is common to both so comparisons are fair -- mirroring the
paper's single-servlet design.

Requests and responses are value objects; the "HTTP layer" is the
``handle`` call boundary, and network metrics are charged per page
exactly as in section 5.1.

``selector_backend`` selects how the origin server evaluates the
bindings-restricted selector:

* ``"numpy"`` -- the paper-faithful per-instantiated-pattern backend
  loop (``selectors.brtpf_select_with_cnt``); kept as the parity oracle.
* ``"kernel"`` -- the Pallas bind-join kernel over the store's packed
  candidate range (``kernel_selectors.KernelSelector``); byte-identical
  fragments, one HBM pass per request, and ``handle_batch`` coalesces
  concurrent same-pattern requests into one grouped launch.
* ``"sharded"`` -- the mesh-partitioned windowed selector
  (``federation.ShardedSelector`` over a ``FederatedStore``): one shard
  per device along ``mesh`` axis ``data``, each launch streams one
  fixed ``shard_window`` of the shard-local sorted range (per-device
  work bounded by the window, never by range or shard size), and
  ``handle_batch`` coalescing rides the same grouped geometry (G
  same-pattern requests = one sharded launch per window). Fragments are
  byte-identical to both other backends.

The kernel and sharded backends share one selector interface
(``select_with_cnt`` / ``select_same_pattern`` / ``launches``) and one
``LaunchRecord`` accounting surface, so batching, memoization, paging
and the launch-budget gates are backend-agnostic.

Every reuse layer -- the HTTP cache's pages, the selector memo and (via
``on_release``) the store's candidate-range memo -- lives in ONE
unified :class:`~repro.core.fragments.FragmentStore` per server: a
kernel or sharded window launch is skipped whenever the requested page
is already resident, regardless of which path populated it
(``Counters.launches_skipped``), and eviction is coherent across layers
(docs/caching.md).
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import OrderedDict
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .cache import LRUCache, request_key
from .config import (DEFAULT_MAX_MPR, DEFAULT_META_TRIPLES_PER_PAGE,
                     DEFAULT_PAGE_SIZE, ServerConfig)
from .fragments import FragmentStore
from .metrics import Counters, metrics_snapshot
from .rdf import TriplePattern
from .selectors import (Fragment, brtpf_select_with_cnt,
                        instantiate_patterns, tpf_select)
from .store import TripleStore

__all__ = ["BrTPFServer", "MaxMprExceeded", "Request", "ServerConfig",
           "DEFAULT_MAX_MPR", "DEFAULT_META_TRIPLES_PER_PAGE",
           "DEFAULT_PAGE_SIZE"]

# Sentinel distinguishing "kwarg not passed" from an explicit value in
# the deprecated per-kwarg constructor surface (see ServerConfig).
_UNSET = object()


@dataclasses.dataclass(frozen=True)
class Request:
    """A (br)TPF page request.

    ``omega`` is None for pure TPF requests; otherwise an int32 [M, V]
    sequence of solution mappings with M <= maxMpR (server-enforced).

    ``count_only`` asks for the fragment's Definition-2 ``cnt`` metadata
    without its data triples (docs/fusion.md): the response is a normal
    :class:`~repro.core.selectors.Fragment` whose data page is empty.
    Count results live under their own memo key -- a count probe can be
    answered FROM a resident data fragment, but never populates (or
    poisons) the data memo the other way round.

    ``timeout_ms`` is the request's REMAINING deadline budget in
    milliseconds (docs/resilience.md): the batching front end sheds the
    request with :class:`~repro.core.batching.DeadlineExceeded` instead
    of burning a launch on it once the budget is exhausted, and both
    transports bound their wait on it. It deliberately does NOT enter
    :meth:`key`: a fragment's identity is (pattern, omega, page), so a
    retried request with a smaller remaining budget still hits every
    cache/memo layer.
    """

    pattern: TriplePattern
    omega: Optional[np.ndarray] = None
    page: int = 0
    count_only: bool = False
    timeout_ms: Optional[float] = None

    def key(self):
        om = None
        if self.omega is not None:
            om = tuple(map(tuple, np.asarray(self.omega).tolist()))
        if self.count_only:
            # distinct key namespace: real omega_rows is None or a tuple
            # of row-tuples, never a str-tagged pair
            om = ("count", om)
        return request_key(self.pattern.as_tuple(), om, self.page)

    @property
    def is_brtpf(self) -> bool:
        return self.omega is not None and self.omega.shape[0] > 0

    # -- wire schema (brtpf/v1; core/wire.py) -------------------------------

    def to_wire(self) -> dict:
        """brtpf/v1 request envelope (JSON-safe; omega as int lists)."""
        from .wire import request_to_wire
        return request_to_wire(self)

    @staticmethod
    def from_wire(obj: dict) -> "Request":
        """Decode a brtpf/v1 request envelope (strict; raises
        :class:`~repro.core.wire.WireError` on malformed input)."""
        from .wire import request_from_wire
        return request_from_wire(obj)


class MaxMprExceeded(ValueError):
    """HTTP 414 equivalent: too many mappings attached to one request."""


class BrTPFServer:
    """Combined TPF/brTPF server over a :class:`TripleStore`."""

    def __init__(
        self,
        store: TripleStore,
        config: Optional[ServerConfig] = None,
        *,
        cache: Optional[LRUCache] = None,
        page_size=_UNSET,
        max_mpr=_UNSET,
        meta_triples_per_page=_UNSET,
        selector_backend=_UNSET,
        mesh=_UNSET,
        shard_window=_UNSET,
        shard_axis=_UNSET,
        fast_path_rows=_UNSET,
    ) -> None:
        # Deprecated per-kwarg surface: any explicit legacy kwarg is
        # folded into a ServerConfig (tests/test_transport.py asserts
        # the two construction paths are equivalent). One release of
        # passthrough, then the kwargs go away.
        legacy = {name: value for name, value in [
            ("page_size", page_size), ("max_mpr", max_mpr),
            ("meta_triples_per_page", meta_triples_per_page),
            ("selector_backend", selector_backend), ("mesh", mesh),
            ("shard_window", shard_window), ("shard_axis", shard_axis),
            ("fast_path_rows", fast_path_rows)] if value is not _UNSET}
        if legacy:
            if config is not None:
                raise TypeError(
                    "pass either a ServerConfig or legacy kwargs, not both: "
                    + ", ".join(sorted(legacy)))
            warnings.warn(
                "BrTPFServer(**kwargs) is deprecated; pass "
                "BrTPFServer(store, ServerConfig(...)) instead",
                DeprecationWarning, stacklevel=2)
            config = ServerConfig(**legacy)
        config = config or ServerConfig()
        self.config = config
        self.store = store
        self.page_size = int(config.page_size)
        self.max_mpr = int(config.max_mpr)
        self.meta_triples_per_page = int(config.meta_triples_per_page)
        self.cache = cache
        self.selector_backend = config.selector_backend
        # Unified fragment store (core/fragments.py): ONE page-granular
        # layer under the HTTP cache, the selector memo and the store's
        # candidate-range memo. The data layer is the selector memo (a
        # real server streams a fragment across its pages instead of
        # recomputing the selection per page request; it is NOT the HTTP
        # cache of section 7 and does not touch its hit/miss metrics);
        # the page layer holds the HTTP cache's rendered pages (the
        # LRUCache binds itself to it below); and when a pattern's last
        # live fragment is evicted, on_release drops the store's
        # candidate range coherently.
        self.fragments = FragmentStore(
            on_release=store.evict_candidate_range)
        if cache is not None:
            cache.bind(self.fragments)
        # Accelerated selector (kernel or sharded backend); None for the
        # paper-faithful numpy oracle. Both implementations share the
        # select_with_cnt / select_same_pattern / launches interface,
        # and both consult the unified store before launching.
        self._selector = None
        self._heat = None
        if config.selector_backend == "kernel":
            from .kernel_selectors import KernelSelector
            self._selector = KernelSelector(
                store, fragments=self.fragments,
                fast_path_rows=config.fast_path_rows)
        elif config.selector_backend == "sharded":
            from .federation import (DEFAULT_SHARD_WINDOW, FederatedStore,
                                     ShardedSelector)
            from .placement import HeatLog
            mesh = config.mesh
            if mesh is None:
                import jax
                from jax.sharding import Mesh
                mesh = Mesh(np.array(jax.devices()), (config.shard_axis,))
            self.federated = FederatedStore.build(store.triples, mesh,
                                                  axis=config.shard_axis)
            # placement_policy="heat": record per-range heat from live
            # traffic so repartition() can re-cut shard boundaries
            # (docs/federation.md, "Placement")
            self._heat = (HeatLog(config.heat_capacity)
                          if config.placement_policy == "heat" else None)
            self._selector = ShardedSelector(
                self.federated,
                window=config.shard_window or DEFAULT_SHARD_WINDOW,
                fragments=self.fragments,
                store=store, fast_path_rows=config.fast_path_rows,
                heat=self._heat)
        self.counters = Counters()
        # Memo keys prefilled by the *current* handle_batch call: their
        # subsequent handle() reads are batched work, not cache skips.
        self._prefilled: set = set()
        # Honest per-server range-memo accounting: the store (and its
        # memo counters) may be shared across servers (the benchmarks
        # reuse one dataset store), so this server's metrics report
        # DELTAS from the counts observed at construction/reset --
        # another server's probe traffic must not show up here.
        self._range_base = (store.range_memo_hits, store.range_memo_misses)

    # -- request handling ---------------------------------------------------

    def validate(self, req: Request) -> None:
        """Reject an over-maxMpR request (HTTP 414). Shared by ``handle``,
        ``handle_batch`` and the async batching front end, which must
        validate per request *before* coalescing."""
        if req.omega is not None and req.omega.shape[0] > self.max_mpr:
            raise MaxMprExceeded(
                f"{req.omega.shape[0]} mappings > maxMpR={self.max_mpr}"
            )

    def handle(self, req: Request) -> Fragment:
        """Serve one page request (the HTTP GET boundary)."""
        self.counters.num_requests += 1
        self.validate(req)

        if self.cache is not None:
            cached = self.cache.get(req.key())
            if cached is not None:
                frag = cached  # served by the proxy, not the origin
                if self._selector is not None:
                    self._note_launch_skip()
                self._charge_transfer(frag)
                return frag

        frag = self._compute(req)
        if self.cache is not None:
            self.cache.put(req.key(), frag)
        self._charge_transfer(frag)
        return frag

    def page_resident(self, req: Request) -> bool:
        """Non-counting residency peek: can this page be served without
        origin selector work, from ANY layer of the unified store (a
        registered HTTP page or the fragment's full memo data)? Used by
        the async front end to bypass the batching window -- there is
        nothing to coalesce for a request that launches nothing.

        Delegates to the unified store's own residency notion: pages
        only ever live there (the bound HTTP cache is a view), so one
        definition serves both."""
        return self.fragments.page_resident(req.key())

    def _note_launch_skip(self) -> None:
        """One request served from the unified store that would
        otherwise have reached the accelerated selector."""
        self.counters.launches_skipped += 1
        self.fragments.note_skip()

    def _charge_transfer(self, frag: Fragment) -> None:
        self.counters.data_triples += int(frag.data.shape[0])
        self.counters.meta_triples += frag.meta_triples
        self.counters.data_received += frag.triples_received

    # -- origin-server computation (section 4.1) ----------------------------

    def _compute(self, req: Request) -> Fragment:
        data, cnt = self._fragment_data(req)
        return self._paginate(data, cnt, req)

    def _fragment_data(self, req: Request) -> Tuple[np.ndarray, int]:
        """Memoized selector evaluation: the fragment's full data-triple
        sequence + cnt estimate, page-independent."""
        memo_key = req.key()[:2]  # (pattern, omega) -- page-independent
        memo = self.fragments.get_data(memo_key)
        if memo is not None:
            # work accounting still charges the originating computation
            # only once -- matching the paper's streaming server. A hit
            # on an accelerated backend is a skipped launch, unless
            # this request IS the batch member its selection was just
            # prefilled for (that is coalescing, already counted as
            # batched_requests). The mark is one-shot: a same-key
            # duplicate beyond the consumer is an ordinary store hit.
            if memo_key in self._prefilled:
                self._prefilled.discard(memo_key)
            elif self._selector is not None:
                self._note_launch_skip()
            return memo
        if req.count_only:
            return self._count_data(req, memo_key)
        if req.is_brtpf:
            patterns = instantiate_patterns(req.pattern, req.omega)
            self.counters.server_lookups += len(patterns)
            if self._selector is not None:
                data, cnt = self._select_kernel(req.pattern, req.omega,
                                                patterns)
            else:
                data, cnt = brtpf_select_with_cnt(self.store, req.pattern,
                                                  req.omega)
        else:
            self.counters.server_lookups += 1
            if self._selector is not None:
                data, cnt = self._select_kernel(req.pattern, None,
                                                [req.pattern])
            else:
                data = tpf_select(self.store, req.pattern)
                cnt = self.store.cardinality(req.pattern)
        self._memoize(memo_key, data, cnt)
        return data, cnt

    def _count_data(self, req: Request, memo_key) -> Tuple[np.ndarray, int]:
        """Count-probe evaluation (docs/fusion.md): Definition-2 ``cnt``
        with no materialized rows. Accelerated backends run their
        ``select_count`` cnt-only path (the bind-join grid still
        evaluates; the gather/stream epilogue is skipped); the numpy
        oracle uses ``brtpf_count`` (pure ``cardinality`` sums)."""
        omega = req.omega if req.is_brtpf else None
        patterns = instantiate_patterns(req.pattern, omega)
        self.counters.server_lookups += len(patterns)
        if self._selector is not None:
            n0 = len(self._selector.launches)
            cnt = self._selector.select_count(req.pattern, omega, patterns)
            self._charge_launches(self._selector.launches[n0:])
        elif omega is not None:
            from .selectors import brtpf_count
            cnt = brtpf_count(self.store, req.pattern, omega)
        else:
            cnt = int(self.store.cardinality(req.pattern))
        data = np.empty((0, 3), dtype=np.int32)
        self._memoize(memo_key, data, cnt)
        return data, cnt

    def _select_kernel(self, tp: TriplePattern,
                       omega: Optional[np.ndarray],
                       insts) -> Tuple[np.ndarray, int]:
        n0 = len(self._selector.launches)
        data, cnt = self._selector.select_with_cnt(tp, omega,
                                                          insts)
        self._charge_launches(self._selector.launches[n0:])
        return data, cnt

    def _charge_launches(self, launches, batched_requests: int = 0) -> None:
        for rec in launches:
            if rec.skipped:
                # a launch the selector avoided via the fragment store
                # (the selector already bumped fragments.launches_skipped)
                self.counters.launches_skipped += 1
                continue
            if rec.fast_path:
                # small-work decision: the groups were served by the
                # numpy block evaluation -- no kernel ran, so the launch
                # budget and the streamed-candidate totals must not be
                # charged (cand_streamed on the record documents the
                # decision quantity, not an HBM pass)
                self.counters.fast_path_selects += rec.groups
                continue
            self.counters.kernel_launches += 1
            self.counters.kernel_cand_streamed += rec.cand_streamed
            self.counters.kernel_cand_rows += (rec.cand_rows
                                               or rec.cand_streamed)
            self.counters.kernel_cand_full_rows += (
                rec.full_rows or rec.cand_rows or rec.cand_streamed)
            self.counters.kernel_pat_slots += rec.pat_slots
            if rec.segments > 1:
                # shape classification of the launch just charged above
                # (fused launches ARE kernel launches), feeding the
                # fused_segments_per_launch metric (docs/fusion.md)
                self.counters.fused_launches += 1
                self.counters.fused_segments += rec.segments
            if rec.pruned:
                # covers sub-window compaction too: a compacted record
                # has cand_full = window, cand_streamed = wc, so its
                # reclaimed_rows = window - wc is exactly this delta
                self.counters.cand_pruned_away += max(
                    rec.cand_full - rec.cand_streamed, 0)
        self.counters.kernel_batched_requests += batched_requests

    def _memoize(self, memo_key, data: np.ndarray, cnt: int) -> None:
        self.counters.server_triples_scanned += int(data.shape[0])
        # The unified store LRU-trims the data layer; when a pattern's
        # last live fragment goes, on_release evicts the store's
        # candidate range coherently (a pattern no fragment is streaming
        # has no reason to pin its materialized range either).
        self.fragments.put_data(memo_key, (data, cnt))

    def _paginate(self, data: np.ndarray, cnt: int, req: Request) -> Fragment:
        lo = req.page * self.page_size
        page = data[lo : lo + self.page_size]
        return Fragment(
            data=page,
            cnt=cnt,
            page=req.page,
            page_size=self.page_size,
            has_next=lo + self.page_size < data.shape[0],
            meta_triples=self.meta_triples_per_page,
        )

    # -- cross-request batching (kernel backend) -----------------------------

    def handle_batch(self, reqs: Sequence[Request]) -> List[Fragment]:
        """Serve a set of concurrent page requests as one unit.

        With an accelerated backend (kernel or sharded), brTPF/TPF
        requests for the *same* triple pattern whose selector results
        are not already available (memo or HTTP cache) are coalesced
        into one grouped launch sequence -- one shared pass over the
        pattern's candidate stream (the range bucket on the kernel
        path; each per-shard window on the sharded path) instead of one
        pass per request. Responses (and all paging / caching /
        transfer accounting) are identical to issuing the requests
        through :meth:`handle` one by one.

        The batch is atomic with respect to validation: an over-maxMpR
        member raises :class:`MaxMprExceeded` *before* any selector
        work runs, so no member's computed fragment is ever discarded.
        """
        for req in reqs:
            self.validate(req)
        if self._selector is None:
            return [self.handle(r) for r in reqs]
        # A batch may carry more distinct selections than the memo cap;
        # widen it for the batch's lifetime so prefilled results are
        # still there when handle() reads them, then trim back.
        cap = self.fragments.memo_capacity
        self.fragments.memo_capacity = cap + len(reqs)
        try:
            self._prefill_batch(reqs)
            return [self.handle(r) for r in reqs]
        finally:
            self._prefilled = set()
            self.fragments.memo_capacity = cap
            self.fragments.trim()

    def _prefill_batch(self, reqs: Sequence[Request]) -> None:
        groups: "OrderedDict" = OrderedDict()
        for req in reqs:
            if self.cache is not None and self.cache.contains(req.key()):
                continue  # served by the proxy, no origin work
            memo_key = req.key()[:2]
            if self.fragments.contains_data(memo_key):
                continue  # resident in the unified store, no launch
            per_pattern = groups.setdefault(
                (req.pattern.as_tuple(), req.count_only), OrderedDict())
            if memo_key not in per_pattern:
                per_pattern[memo_key] = req
        # Cross-pattern fusion (docs/fusion.md): >= 2 distinct
        # (pattern, count_only) groups become segments of fused launches
        # -- singleton groups ride along (they'd otherwise launch solo
        # through handle()). A homogeneous batch has nothing to fuse and
        # keeps the classic same-pattern grouped path below.
        if (self.config.fuse_patterns and len(groups) >= 2
                and hasattr(self._selector, "select_fused")):
            self._prefill_fused(groups)
            return
        for members in groups.values():
            member_reqs = list(members.values())
            if len(member_reqs) < 2:
                continue  # solo requests take the normal handle() path
            tp = member_reqs[0].pattern
            omegas = [r.omega if r.is_brtpf else None
                      for r in member_reqs]
            insts = [instantiate_patterns(tp, om) for om in omegas]
            n0 = len(self._selector.launches)
            results = self._selector.select_same_pattern(
                tp, omegas, insts)
            self._charge_launches(self._selector.launches[n0:],
                                  batched_requests=len(member_reqs))
            self._consume_prefill(member_reqs, insts, results)

    def _prefill_fused(self, groups: "OrderedDict") -> None:
        """Serve a heterogeneous batch's miss groups as fused segments."""
        from .kernel_selectors import FusedSegment
        segments = []
        members = []
        for (_ptuple, count_only), per in groups.items():
            member_reqs = list(per.values())
            tp = member_reqs[0].pattern
            omegas = [r.omega if r.is_brtpf else None
                      for r in member_reqs]
            insts = [instantiate_patterns(tp, om) for om in omegas]
            segments.append(FusedSegment(tp=tp, omegas=omegas,
                                         patterns=insts,
                                         count_only=count_only))
            members.append((member_reqs, insts))
        n0 = len(self._selector.launches)
        rows = self._selector.select_fused(segments)
        self._charge_launches(
            self._selector.launches[n0:],
            batched_requests=sum(len(m) for m, _ in members))
        for (member_reqs, insts), row in zip(members, rows, strict=True):
            self._consume_prefill(member_reqs, insts, row)

    def _consume_prefill(self, member_reqs, insts, results) -> None:
        for req, patterns, (data, cnt) in zip(member_reqs, insts,
                                              results, strict=True):
            self.counters.server_lookups += len(patterns)
            memo_key = req.key()[:2]
            self._memoize(memo_key, data, cnt)
            self._prefilled.add(memo_key)

    # -- convenience ---------------------------------------------------------

    def metrics_snapshot(self) -> dict:
        """Canonical metrics envelope: counters + per-layer cache
        accounting over the unified fragment store (metrics.py). The
        same schema is served at ``GET /metrics`` by the ASGI app, so
        the sim ``--live`` loop and the load generator read identical
        keys over the wire and in-process."""
        return metrics_snapshot(self)

    def shard_launch_snapshot(self) -> np.ndarray:
        """Copy of the per-shard planned-window-page counters (sharded
        backend only; empty for the others) -- the delta surface the
        trace recorder and the sim's per-shard ``--live`` validation
        read (docs/federation.md, "Placement")."""
        sel = self._selector
        if sel is not None and hasattr(sel, "shard_pages"):
            return np.array(sel.shard_pages, dtype=np.int64)
        return np.zeros((0,), dtype=np.int64)

    def repartition(self, heat=None) -> None:
        """Workload-aware re-fragmentation cutover (docs/federation.md,
        "Placement").

        Plans a placement from the recorded heat (the server's own
        ``placement_policy="heat"`` log unless one is passed), rebuilds
        the :class:`~repro.core.federation.FederatedStore` under the new
        boundaries + replica ranges, rebinds the selector, and clears
        the unified fragment store -- conservative cutover coherence:
        fragments are byte-identical across partitionings, but resident
        pages predate the new boundaries and serving them residency-free
        would hide the rebalance from the per-shard counters the sim
        validates. The async front end wraps this under its flush lock
        (``AsyncBrTPFServer.repartition``) so the swap lands atomically
        between flushes.
        """
        if self.selector_backend != "sharded":
            raise RuntimeError("repartition requires the sharded backend")
        heat = heat if heat is not None else self._heat
        if heat is None or len(heat) == 0:
            raise ValueError(
                "no heat recorded: pass a HeatLog, or construct the "
                "server with placement_policy='heat'")
        self.federated = self.federated.repartition(heat)
        self._selector.rebind(self.federated)
        self.fragments.clear()

    def reset_counters(self) -> None:
        self.counters.reset()
        self.fragments.reset_counters()
        sel = self._selector
        if sel is not None and hasattr(sel, "reset_shard_counters"):
            sel.reset_shard_counters()
        self._range_base = (self.store.range_memo_hits,
                            self.store.range_memo_misses)
        if self.cache is not None:
            self.cache.hits = 0
            self.cache.misses = 0
