"""Basic graph patterns (BGPs): the query fragment the paper studies.

A BGP is a list of triple patterns sharing a variable namespace. The
paper restricts its study to BGPs (section 1) because they are the
fundamental fragment both client algorithms must handle.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .rdf import TermDictionary, TriplePattern, encode_var


@dataclasses.dataclass
class BGP:
    patterns: Tuple[TriplePattern, ...]
    num_vars: int
    var_names: Tuple[str, ...] = ()

    def __post_init__(self) -> None:
        if not self.var_names:
            self.var_names = tuple(f"?v{i}" for i in range(self.num_vars))

    def variables_of(self, i: int) -> Tuple[int, ...]:
        return self.patterns[i].variables()

    def all_variables(self) -> Tuple[int, ...]:
        out: List[int] = []
        for tp in self.patterns:
            for v in tp.variables():
                if v not in out:
                    out.append(v)
        return tuple(out)

    def __len__(self) -> int:
        return len(self.patterns)


def parse_bgp(text: str, dictionary: TermDictionary) -> BGP:
    """Parse a whitespace BGP: one 's p o' triple pattern per line ('.'
    terminators optional); terms starting with '?' are variables."""
    var_ids: Dict[str, int] = {}
    patterns: List[TriplePattern] = []
    for line in text.strip().splitlines():
        line = line.strip().rstrip(".").strip()
        if not line or line.startswith("#"):
            continue
        toks = line.split()
        if len(toks) != 3:
            raise ValueError(f"bad triple pattern: {line!r}")
        comps = []
        for tok in toks:
            if tok.startswith("?"):
                if tok not in var_ids:
                    var_ids[tok] = len(var_ids)
                comps.append(encode_var(var_ids[tok]))
            else:
                comps.append(dictionary.intern(tok))
        patterns.append(TriplePattern(*comps))
    names = tuple(sorted(var_ids, key=var_ids.get))
    return BGP(tuple(patterns), len(var_ids), names)


def bgp_from_arrays(patterns: Sequence[Sequence[int]]) -> BGP:
    """Build a BGP from raw encoded component triples (tests/generators)."""
    tps = tuple(TriplePattern(*map(int, p)) for p in patterns)
    nv = 0
    for tp in tps:
        for v in tp.variables():
            nv = max(nv, v + 1)
    return BGP(tps, nv)


def evaluate_bgp_reference(triples: np.ndarray, bgp: BGP) -> np.ndarray:
    """Brute-force BGP evaluation oracle (for tests): nested-loop join
    over the raw triple array. Returns solution mappings int32 [R, V]."""
    from .rdf import UNBOUND, mapping_from_triple, merge

    solutions = [np.full((bgp.num_vars,), UNBOUND, dtype=np.int32)]
    for tp in bgp.patterns:
        nxt = []
        for mu in solutions:
            inst = tp.instantiate(mu)
            for t in triples:
                m = mapping_from_triple(inst, t, bgp.num_vars)
                if m is not None:
                    nxt.append(merge(mu.copy(), m))
        solutions = nxt
    if not solutions:
        return np.empty((0, bgp.num_vars), dtype=np.int32)
    out = np.stack(solutions).astype(np.int32)
    return np.unique(out, axis=0)
