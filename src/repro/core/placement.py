"""Workload-aware placement: heat-based shard boundaries + hot-range
replication (docs/federation.md, "Placement").

The legacy ``FederatedStore.build`` splits each index order's sorted key
space into equal contiguous shards, so a hot predicate's entire prefix
range lands on one shard while the others idle.  This module derives a
:class:`Placement` from observed traffic instead:

* :class:`HeatLog` -- a bounded log of per-key-range heat records
  (launches, streamed candidate rows, planned window pages), fed by the
  selectors as they plan windows.  Bounded means it is a sliding window
  over recent traffic, which is what a re-partitioner should follow.
* :func:`weighted_boundaries` -- a weighted-quantile split over the
  packed int64 key space that equalizes *expected launches per shard*
  instead of byte counts, computed per index order because the POS/OSP
  mirrors have their own hot ranges.
* :func:`plan_placement` -- boundaries plus :class:`ReplicaRange`s: the
  hottest sub-range of any shard still hot after re-balancing is copied
  onto the coldest shard(s), so the routed launch path can serve it from
  the least-loaded owner.  Dedup is the router's job (exactly one owner
  streams a replicated range per launch); this module only decides who
  holds copies.

Everything here is host-side numpy -- no jax imports -- so placements can
be planned from traces offline as well as from a live server.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .store import _ORDERS, _pack

__all__ = [
    "HeatRecord",
    "HeatLog",
    "ReplicaRange",
    "Placement",
    "dataset_keys",
    "equal_boundaries",
    "heat_weights",
    "weighted_boundaries",
    "plan_placement",
]


@dataclasses.dataclass(frozen=True)
class HeatRecord:
    """One observed launch burst over a key range of one index order.

    ``lo_key``/``hi_key`` are *inclusive* packed-key bounds of the
    planned candidate range (the selector's ``plan.lo_key``/``hi_key``).
    """

    order: str
    lo_key: int
    hi_key: int
    launches: int = 1
    rows: int = 0
    pages: int = 0


class HeatLog:
    """Bounded log of :class:`HeatRecord`s (oldest evicted first)."""

    def __init__(self, capacity: int = 4096) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._records: Deque[HeatRecord] = deque(maxlen=self.capacity)

    def record(
        self,
        order: str,
        lo_key: int,
        hi_key: int,
        launches: int = 1,
        rows: int = 0,
        pages: int = 0,
    ) -> None:
        self._records.append(
            HeatRecord(
                order=str(order),
                lo_key=int(lo_key),
                hi_key=int(hi_key),
                launches=int(launches),
                rows=int(rows),
                pages=int(pages),
            )
        )

    def records(self, order: Optional[str] = None) -> List[HeatRecord]:
        if order is None:
            return list(self._records)
        return [r for r in self._records if r.order == order]

    def merge(self, other: "HeatLog") -> None:
        for rec in other._records:
            self._records.append(rec)

    def clear(self) -> None:
        self._records.clear()

    def __len__(self) -> int:
        return len(self._records)

    @property
    def total_launches(self) -> int:
        return sum(r.launches for r in self._records)


@dataclasses.dataclass(frozen=True)
class ReplicaRange:
    """A replicated key sub-range: ``home`` owns the primary copy, every
    shard in ``replicas`` holds a byte-identical copy.  Bounds are
    inclusive packed keys."""

    order: str
    lo_key: int
    hi_key: int
    home: int
    replicas: Tuple[int, ...]

    @property
    def holders(self) -> Tuple[int, ...]:
        return (self.home,) + tuple(s for s in self.replicas if s != self.home)


@dataclasses.dataclass
class Placement:
    """Per-order shard boundaries + replicated hot ranges.

    ``boundaries[order]`` is a sorted int64 array of ``shards - 1`` cut
    keys; a key ``k`` lives on shard ``searchsorted(bounds, k, "right")``
    (cut keys start the shard to their right).  Orders without an entry
    fall back to an equal-count contiguous split at build time.
    """

    boundaries: Dict[str, np.ndarray]
    replicas: Dict[str, Tuple[ReplicaRange, ...]] = dataclasses.field(
        default_factory=dict
    )

    def shard_of(self, order: str, keys: np.ndarray) -> np.ndarray:
        bounds = np.asarray(self.boundaries[order], dtype=np.int64)
        return np.searchsorted(bounds, np.asarray(keys, dtype=np.int64), side="right")

    @property
    def has_replicas(self) -> bool:
        return any(self.replicas.values())


def dataset_keys(triples_np: np.ndarray) -> Dict[str, np.ndarray]:
    """Sorted packed keys per index order for a host triple array."""
    triples_np = np.asarray(triples_np)
    out: Dict[str, np.ndarray] = {}
    for name, comp in _ORDERS.items():
        keys = _pack(
            triples_np[:, comp[0]], triples_np[:, comp[1]], triples_np[:, comp[2]]
        )
        out[name] = np.sort(keys)
    return out


def equal_boundaries(keys_sorted: np.ndarray, shards: int) -> np.ndarray:
    """Equal-count contiguous cut keys (the workload-blind fallback)."""
    keys_sorted = np.asarray(keys_sorted, dtype=np.int64)
    if shards <= 1 or keys_sorted.size == 0:
        return np.empty((0,), dtype=np.int64)
    idx = np.arange(1, shards) * keys_sorted.size // shards
    idx = np.clip(idx, 0, keys_sorted.size - 1)
    return keys_sorted[idx].astype(np.int64)


def heat_weights(
    keys_sorted: np.ndarray,
    records: Iterable[HeatRecord],
    base: float = 1.0,
) -> np.ndarray:
    """Per-key expected-launch weights from heat records.

    Each record's launches are spread uniformly over the keys inside its
    ``[lo_key, hi_key]`` range (difference-array accumulation, so cost is
    O(records + keys)).  ``base`` gives every key a small uniform weight
    so cold ranges still split sanely when the log is sparse.
    """
    keys_sorted = np.asarray(keys_sorted, dtype=np.int64)
    w = np.full(keys_sorted.shape, float(base), dtype=np.float64)
    if keys_sorted.size == 0:
        return w
    diff = np.zeros(keys_sorted.size + 1, dtype=np.float64)
    for rec in records:
        i0 = int(np.searchsorted(keys_sorted, rec.lo_key, side="left"))
        i1 = int(np.searchsorted(keys_sorted, rec.hi_key, side="right"))
        if i1 <= i0:
            continue
        per_key = float(rec.launches) / (i1 - i0)
        diff[i0] += per_key
        diff[i1] -= per_key
    w += np.cumsum(diff[:-1])
    return w


def weighted_boundaries(
    keys_sorted: np.ndarray, weights: Sequence[float], shards: int
) -> np.ndarray:
    """Weighted-quantile cut keys equalizing per-shard weight mass.

    Returns ``shards - 1`` sorted cut keys under the same convention as
    :meth:`Placement.shard_of` (a cut key starts the shard to its right).
    """
    keys_sorted = np.asarray(keys_sorted, dtype=np.int64)
    if shards <= 1 or keys_sorted.size == 0:
        return np.empty((0,), dtype=np.int64)
    w = np.asarray(weights, dtype=np.float64)
    if w.shape != keys_sorted.shape:
        raise ValueError(f"weights shape {w.shape} != keys shape {keys_sorted.shape}")
    cum = np.cumsum(w)
    total = float(cum[-1])
    if total <= 0.0:
        return equal_boundaries(keys_sorted, shards)
    cuts = total * np.arange(1, shards, dtype=np.float64) / shards
    idx = np.searchsorted(cum, cuts, side="left")
    idx = np.clip(idx, 0, keys_sorted.size - 1)
    return keys_sorted[idx].astype(np.int64)


def _shard_spans(
    bounds: np.ndarray, shards: int
) -> List[Tuple[int, int]]:
    """Inclusive key span owned by each shard under ``bounds``."""
    lo = np.iinfo(np.int64).min
    hi = np.iinfo(np.int64).max
    edges = [lo] + [int(b) for b in bounds] + [hi + 0]
    spans = []
    for s in range(shards):
        s_lo = edges[s]
        s_hi = edges[s + 1] - 1 if s < shards - 1 else hi
        spans.append((s_lo, s_hi))
    return spans


def plan_placement(
    heat: HeatLog,
    keys_by_order: Dict[str, np.ndarray],
    shards: int,
    base_weight: float = 0.05,
    hot_factor: float = 1.25,
    max_replicas: int = 1,
) -> Placement:
    """Plan boundaries + replication from a heat log.

    Per order: weighted-quantile boundaries from :func:`heat_weights`;
    then, if the hottest shard still carries more than ``hot_factor``
    times the mean weight (an un-splittable hot range, e.g. all heat on
    a handful of keys), its hottest observed sub-range is replicated
    onto the ``max_replicas`` coldest shards so the routed launch path
    can serve it from the least-loaded owner.

    ``base_weight`` is the *fraction of the observed heat mass* spread
    uniformly over all keys (cold ranges still split sanely); it is
    normalized per order so a long log can never drown the signal the
    way an absolute per-key constant would on a large key space.
    """
    boundaries: Dict[str, np.ndarray] = {}
    replicas: Dict[str, Tuple[ReplicaRange, ...]] = {}
    for name in _ORDERS:
        keys = np.asarray(keys_by_order.get(name, np.empty(0)), dtype=np.int64)
        recs = heat.records(name)
        mass = float(sum(r.launches for r in recs))
        per_key_base = (base_weight * max(mass, 1.0) / max(keys.size, 1))
        w = heat_weights(keys, recs, base=per_key_base)
        bounds = weighted_boundaries(keys, w, shards)
        boundaries[name] = bounds
        if shards <= 1 or keys.size == 0 or not recs:
            continue
        assign = np.searchsorted(bounds, keys, side="right")
        shard_w = np.bincount(assign, weights=w, minlength=shards)[:shards]
        mean_w = float(shard_w.sum()) / shards
        if mean_w <= 0.0:
            continue
        hot = int(np.argmax(shard_w))
        if float(shard_w[hot]) <= hot_factor * mean_w:
            continue
        span_lo, span_hi = _shard_spans(bounds, shards)[hot]
        best = None
        for rec in recs:
            lo = max(rec.lo_key, span_lo)
            hi = min(rec.hi_key, span_hi)
            if hi < lo:
                continue
            if best is None or rec.launches > best.launches:
                best = HeatRecord(name, lo, hi, rec.launches, rec.rows, rec.pages)
        if best is None:
            continue
        cold = [int(s) for s in np.argsort(shard_w, kind="stable") if int(s) != hot]
        targets = tuple(cold[: max(1, int(max_replicas))])
        if not targets:
            continue
        replicas[name] = (
            ReplicaRange(
                order=name,
                lo_key=int(best.lo_key),
                hi_key=int(best.hi_key),
                home=hot,
                replicas=targets,
            ),
        )
    return Placement(boundaries=boundaries, replicas=replicas)
