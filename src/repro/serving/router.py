"""Front-end replica router: one edge, N origin-server replicas.

The ROADMAP's "millions of users" item needs more than one origin
process behind the wire. :class:`ReplicaRouter` owns N replicas -- each
a :class:`~repro.core.server.BrTPFServer` built from the SAME
:class:`~repro.core.config.ServerConfig`, wrapped in its own
:class:`~repro.core.batching.AsyncBrTPFServer` batching window -- over
one shared :class:`~repro.core.store.TripleStore` (the dataset is one
HDT image; what a replica owns privately is its unified
:class:`~repro.core.fragments.FragmentStore` and its batching queue).

Routing policies:

* ``"pattern"`` (default) -- **fragment affinity**: a stable hash of
  the triple pattern pins every request for a pattern to one replica,
  the same way :meth:`~repro.core.federation.FederatedStore.plan_windows`
  pins window pages to the shard that owns their key range. Affinity is
  what makes a replica's fragment store *converge*: repeat requests for
  a pattern always land where its fragments are resident, so the
  launches-skipped rate of a fleet matches a single server's instead of
  dividing by N.
* ``"round_robin"`` -- pure load spreading; each replica sees 1/N of
  every pattern, which maximizes batching-window mixing but fragments
  residency. Kept as the baseline the affinity policy is measured
  against.

The router presents the same async backend surface as a single front
end (``handle`` / ``metrics_snapshot`` / ``note_mappings`` / ``max_mpr``
/ ``aclose``), so :class:`~repro.serving.http.BrTPFApp` and both
transports work unchanged over a fleet; ``metrics_snapshot`` merges the
replicas' counters into the canonical schema with per-replica detail
under ``"replicas"``.
"""
from __future__ import annotations

import asyncio
import dataclasses
from typing import List, Optional, Tuple

from ..core.batching import (DEFAULT_BATCH_WINDOW_S, DEFAULT_MAX_BATCH,
                             AsyncBrTPFServer)
from ..core.config import ServerConfig
from ..core.metrics import METRICS_VERSION, Counters
from ..core.selectors import Fragment
from ..core.server import Request

POLICIES = ("pattern", "round_robin")


def stable_replica_index(pattern_tuple: Tuple[int, int, int],
                         n: int) -> int:
    """Deterministic pattern -> replica assignment (process-independent,
    unlike ``hash()``): an FNV-1a mix over the three components."""
    acc = 0x811C9DC5
    for c in pattern_tuple:
        acc = ((acc ^ (c & 0xFFFFFFFF)) * 0x01000193) & 0xFFFFFFFF
    return acc % n


@dataclasses.dataclass
class RouterStats:
    requests: int = 0
    per_replica: List[int] = dataclasses.field(default_factory=list)


class ReplicaRouter:
    """Fan requests across N async server replicas (shared store)."""

    def __init__(self, store, config: Optional[ServerConfig] = None, *,
                 replicas: int = 2, policy: str = "pattern",
                 batch_window_s: float = DEFAULT_BATCH_WINDOW_S,
                 max_batch: int = DEFAULT_MAX_BATCH) -> None:
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        if policy not in POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}")
        self.config = config or ServerConfig()
        self.policy = policy
        self.replicas = [
            AsyncBrTPFServer.from_config(store, self.config,
                                         batch_window_s=batch_window_s,
                                         max_batch=max_batch)
            for _ in range(replicas)]
        self.stats = RouterStats(per_replica=[0] * replicas)
        self._rr = 0

    @property
    def max_mpr(self) -> int:
        return self.config.max_mpr

    # -- routing -------------------------------------------------------------

    def route(self, req: Request) -> int:
        """Replica index for a request (non-advancing for affinity;
        advances the round-robin pointer)."""
        if self.policy == "pattern":
            return stable_replica_index(req.pattern.as_tuple(),
                                        len(self.replicas))
        idx = self._rr
        self._rr = (self._rr + 1) % len(self.replicas)
        return idx

    def note_mappings(self, req: Request) -> None:
        """Wire-boundary mappings accounting; attributed to the replica
        the pattern is pinned to (round-robin attribution lands on the
        current pointer -- the merged counters are exact either way)."""
        if self.policy == "pattern":
            idx = stable_replica_index(req.pattern.as_tuple(),
                                       len(self.replicas))
        else:
            idx = self._rr
        self.replicas[idx].note_mappings(req)

    async def handle(self, req: Request) -> Fragment:
        idx = self.route(req)
        self.stats.requests += 1
        self.stats.per_replica[idx] += 1
        return await self.replicas[idx].handle(req)

    async def aclose(self) -> None:
        await asyncio.gather(*[front.aclose() for front in self.replicas])

    # -- observability -------------------------------------------------------

    def metrics_snapshot(self) -> dict:
        """Merged canonical snapshot: fleet-total counters and layer
        sums at the top level (same keys as a single server's
        ``metrics_snapshot``), per-replica envelopes under
        ``"replicas"``."""
        merged = Counters()
        snaps = [front.metrics_snapshot() for front in self.replicas]
        for front in self.replicas:
            merged.merge(front.server.counters)
        out = {
            "v": METRICS_VERSION,
            "counters": dataclasses.asdict(merged),
            "launches_skipped": sum(
                s["launches_skipped"] for s in snaps),
            "selector_memo": _sum_layer(snaps, "selector_memo"),
            "range_memo": _sum_layer(snaps, "range_memo"),
            "router": {
                "policy": self.policy,
                "replicas": len(self.replicas),
                "requests": self.stats.requests,
                "requests_per_replica": list(self.stats.per_replica),
            },
            "replicas": snaps,
        }
        if any("http" in s for s in snaps):
            out["http"] = _sum_layer([s for s in snaps if "http" in s],
                                     "http")
        return out


def _sum_layer(snaps: List[dict], layer: str) -> dict:
    hits = sum(s[layer]["hits"] for s in snaps)
    misses = sum(s[layer]["misses"] for s in snaps)
    out = {"hits": hits, "misses": misses,
           "hit_rate": hits / max(hits + misses, 1)}
    if all("entries" in s[layer] for s in snaps):
        out["entries"] = sum(s[layer]["entries"] for s in snaps)
    return out
