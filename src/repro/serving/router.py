"""Front-end replica router: one edge, N origin-server replicas.

The ROADMAP's "millions of users" item needs more than one origin
process behind the wire. :class:`ReplicaRouter` owns N replicas -- each
a :class:`~repro.core.server.BrTPFServer` built from the SAME
:class:`~repro.core.config.ServerConfig`, wrapped in its own
:class:`~repro.core.batching.AsyncBrTPFServer` batching window -- over
one shared :class:`~repro.core.store.TripleStore` (the dataset is one
HDT image; what a replica owns privately is its unified
:class:`~repro.core.fragments.FragmentStore` and its batching queue).

Routing policies:

* ``"pattern"`` (default) -- **fragment affinity**: a stable hash of
  the triple pattern pins every request for a pattern to one replica,
  the same way :meth:`~repro.core.federation.FederatedStore.plan_windows`
  pins window pages to the shard that owns their key range. Affinity is
  what makes a replica's fragment store *converge*: repeat requests for
  a pattern always land where its fragments are resident, so the
  launches-skipped rate of a fleet matches a single server's instead of
  dividing by N.
* ``"round_robin"`` -- pure load spreading; each replica sees 1/N of
  every pattern, which maximizes batching-window mixing but fragments
  residency. Kept as the baseline the affinity policy is measured
  against.

Health-gated failover (docs/resilience.md): each replica sits behind a
:class:`CircuitBreaker`. Affinity gives the *preferred* replica; when
its breaker is open the request degrades to the next healthy replica in
``(preferred + k) % n`` order instead of failing -- trading fragment
residency for availability, exactly the brTPF availability argument.
A stalled replica is detected through the client's own deadline: the
bounded await cancels the in-flight ``handle``, the router counts the
cancellation as a replica failure, and enough consecutive failures open
the breaker. After ``reset_after_s`` one half-open probe is admitted;
success re-closes the breaker, failure re-opens it.

The router presents the same async backend surface as a single front
end (``handle`` / ``metrics_snapshot`` / ``note_mappings`` / ``max_mpr``
/ ``aclose``), so :class:`~repro.serving.http.BrTPFApp` and both
transports work unchanged over a fleet; ``metrics_snapshot`` merges the
replicas' counters into the canonical schema with per-replica detail
under ``"replicas"`` and breaker/shed accounting under
``"resilience"``.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import List, Optional, Tuple

from ..core.batching import (DEFAULT_BATCH_WINDOW_S, DEFAULT_MAX_BATCH,
                             AsyncBrTPFServer)
from ..core.config import ServerConfig
from ..core.metrics import METRICS_VERSION, Counters, resilience_section
from ..core.selectors import Fragment
from ..core.server import MaxMprExceeded, Request
from ..core.wire import WireError
from .faults import FaultyBackend

POLICIES = ("pattern", "round_robin")

BREAKER_CLOSED = "closed"
BREAKER_OPEN = "open"
BREAKER_HALF_OPEN = "half_open"

DEFAULT_FAILURE_THRESHOLD = 5
DEFAULT_RESET_AFTER_S = 1.0


def stable_replica_index(pattern_tuple: Tuple[int, int, int],
                         n: int) -> int:
    """Deterministic pattern -> replica assignment (process-independent,
    unlike ``hash()``): an FNV-1a mix over the three components."""
    acc = 0x811C9DC5
    for c in pattern_tuple:
        acc = ((acc ^ (c & 0xFFFFFFFF)) * 0x01000193) & 0xFFFFFFFF
    return acc % n


class CircuitBreaker:
    """Per-replica consecutive-failure breaker (docs/resilience.md).

    closed -> open after ``failure_threshold`` consecutive failures;
    open -> half-open after ``reset_after_s`` (the next ``allow()``
    admits ONE probe); half-open -> closed on probe success, -> open on
    probe failure. ``clock`` is injectable for deterministic tests.
    """

    def __init__(self, failure_threshold: int = DEFAULT_FAILURE_THRESHOLD,
                 reset_after_s: float = DEFAULT_RESET_AFTER_S,
                 clock=time.monotonic) -> None:
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if reset_after_s <= 0:
            raise ValueError("reset_after_s must be > 0")
        self.failure_threshold = failure_threshold
        self.reset_after_s = reset_after_s
        self._clock = clock
        self.state = BREAKER_CLOSED
        self.transitions = 0   # every state change
        self.opens = 0         # transitions INTO open
        self._consecutive = 0
        self._opened_at = 0.0

    def allow(self) -> bool:
        """May a request be sent to this replica right now? Open
        breakers flip to half-open (admitting this one probe) once the
        reset window has elapsed; a half-open breaker admits nothing
        further until the in-flight probe resolves."""
        if self.state == BREAKER_CLOSED:
            return True
        if (self.state == BREAKER_OPEN
                and self._clock() - self._opened_at >= self.reset_after_s):
            self._transition(BREAKER_HALF_OPEN)
            return True
        return False

    def record_success(self) -> None:
        self._consecutive = 0
        if self.state != BREAKER_CLOSED:
            self._transition(BREAKER_CLOSED)

    def record_failure(self) -> None:
        self._consecutive += 1
        if self.state == BREAKER_HALF_OPEN or (
                self.state == BREAKER_CLOSED
                and self._consecutive >= self.failure_threshold):
            self._transition(BREAKER_OPEN)
            self.opens += 1
            self._opened_at = self._clock()

    def _transition(self, state: str) -> None:
        self.state = state
        self.transitions += 1

    def snapshot(self) -> dict:
        return {"state": self.state, "transitions": self.transitions,
                "opens": self.opens,
                "consecutive_failures": self._consecutive}


@dataclasses.dataclass
class RouterStats:
    requests: int = 0
    failovers: int = 0       # served off the preferred replica
    replica_failures: int = 0  # infra failures charged to a breaker
    per_replica: List[int] = dataclasses.field(default_factory=list)


class ReplicaRouter:
    """Fan requests across N async server replicas (shared store)."""

    def __init__(self, store, config: Optional[ServerConfig] = None, *,
                 replicas: int = 2, policy: str = "pattern",
                 batch_window_s: float = DEFAULT_BATCH_WINDOW_S,
                 max_batch: int = DEFAULT_MAX_BATCH,
                 failure_threshold: int = DEFAULT_FAILURE_THRESHOLD,
                 reset_after_s: float = DEFAULT_RESET_AFTER_S,
                 fault_plan=None) -> None:
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        if policy not in POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}")
        self.config = config or ServerConfig()
        self.policy = policy
        self.batch_window_s = float(batch_window_s)
        backends = [
            AsyncBrTPFServer.from_config(store, self.config,
                                         batch_window_s=batch_window_s,
                                         max_batch=max_batch)
            for _ in range(replicas)]
        if fault_plan is not None:
            # seeded chaos (serving/faults.py): wrap each replica in its
            # deterministic fault schedule -- behind the router, so the
            # breaker/failover machinery sees exactly these failures
            backends = [FaultyBackend(b, fault_plan.for_replica(i))
                        for i, b in enumerate(backends)]
        self.replicas = backends
        self.breakers = [CircuitBreaker(failure_threshold,
                                        reset_after_s)
                         for _ in range(replicas)]
        self.stats = RouterStats(per_replica=[0] * replicas)
        self._rr = 0

    @property
    def max_mpr(self) -> int:
        return self.config.max_mpr

    # -- routing -------------------------------------------------------------

    def route(self, req: Request) -> int:
        """Preferred replica index for a request (non-advancing for
        affinity; advances the round-robin pointer)."""
        if self.policy == "pattern":
            return stable_replica_index(req.pattern.as_tuple(),
                                        len(self.replicas))
        idx = self._rr
        self._rr = (self._rr + 1) % len(self.replicas)
        return idx

    def _pick(self, preferred: int) -> int:
        """Health gate: the preferred replica if its breaker admits,
        else the next healthy one in ``(preferred + k) % n`` order; if
        every breaker refuses, fail fast on the preferred (its error
        keeps feeding the breaker that will eventually half-open)."""
        n = len(self.replicas)
        for k in range(n):
            cand = (preferred + k) % n
            if self.breakers[cand].allow():
                return cand
        return preferred

    def note_mappings(self, req: Request) -> None:
        """Wire-boundary mappings accounting; attributed to the replica
        the pattern is pinned to (round-robin attribution lands on the
        current pointer -- the merged counters are exact either way)."""
        if self.policy == "pattern":
            idx = stable_replica_index(req.pattern.as_tuple(),
                                       len(self.replicas))
        else:
            idx = self._rr
        self.replicas[idx].note_mappings(req)

    async def handle(self, req: Request) -> Fragment:
        preferred = self.route(req)
        idx = self._pick(preferred)
        if idx != preferred:
            self.stats.failovers += 1
        self.stats.requests += 1
        self.stats.per_replica[idx] += 1
        breaker = self.breakers[idx]
        try:
            frag = await self.replicas[idx].handle(req)
        except asyncio.CancelledError:
            # the caller's deadline cancelled a still-pending await --
            # the signature of a stalled replica; charge the breaker
            # before propagating the cancellation
            breaker.record_failure()
            self.stats.replica_failures += 1
            raise
        except (MaxMprExceeded, WireError):
            # the CLIENT's fault -- says nothing about replica health
            raise
        except Exception:
            breaker.record_failure()
            self.stats.replica_failures += 1
            raise
        breaker.record_success()
        return frag

    async def aclose(self) -> None:
        await asyncio.gather(*[front.aclose() for front in self.replicas])

    # -- observability -------------------------------------------------------

    def breaker_section(self) -> dict:
        """The ``"breaker"`` sub-section of the resilience metrics."""
        return {
            "states": [b.state for b in self.breakers],
            "transitions": sum(b.transitions for b in self.breakers),
            "opens": sum(b.opens for b in self.breakers),
            "open_now": sum(1 for b in self.breakers
                            if b.state != BREAKER_CLOSED),
            "failovers": self.stats.failovers,
            "replica_failures": self.stats.replica_failures,
        }

    def metrics_snapshot(self) -> dict:
        """Merged canonical snapshot: fleet-total counters and layer
        sums at the top level (same keys as a single server's
        ``metrics_snapshot``), per-replica envelopes under
        ``"replicas"``, breaker + summed shed under ``"resilience"``."""
        merged = Counters()
        snaps = [front.metrics_snapshot() for front in self.replicas]
        for front in self.replicas:
            merged.merge(front.server.counters)
        out = {
            "v": METRICS_VERSION,
            "counters": dataclasses.asdict(merged),
            "launches_skipped": sum(
                s["launches_skipped"] for s in snaps),
            "selector_memo": _sum_layer(snaps, "selector_memo"),
            "range_memo": _sum_layer(snaps, "range_memo"),
            "router": {
                "policy": self.policy,
                "replicas": len(self.replicas),
                "requests": self.stats.requests,
                "requests_per_replica": list(self.stats.per_replica),
            },
            "resilience": resilience_section(
                shed=sum(s.get("resilience", {}).get("shed", 0)
                         for s in snaps),
                breaker=self.breaker_section()),
            "replicas": snaps,
        }
        faults = [getattr(front, "faults", None) for front in self.replicas]
        if any(f is not None for f in faults):
            out["faults"] = [f.summary() if f is not None else None
                             for f in faults]
        if any("http" in s for s in snaps):
            out["http"] = _sum_layer([s for s in snaps if "http" in s],
                                     "http")
        return out


def _sum_layer(snaps: List[dict], layer: str) -> dict:
    hits = sum(s[layer]["hits"] for s in snaps)
    misses = sum(s[layer]["misses"] for s in snaps)
    out = {"hits": hits, "misses": misses,
           "hit_rate": hits / max(hits + misses, 1)}
    if all("entries" in s[layer] for s in snaps):
        out["entries"] = sum(s[layer]["entries"] for s in snaps)
    return out
