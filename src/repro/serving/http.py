"""ASGI transport for the combined TPF/brTPF server (brtpf/v1 wire API).

The paper's whole argument is about *network* load, yet before PR 7
every benchmark called ``BrTPFServer.handle`` in-process. This module
gives the async front end a real HTTP boundary:

* ``GET  /``          -- service description (version, endpoints, maxMpR);
* ``GET  /fragment``  -- TPF and brTPF page requests via query params
  (``s``/``p``/``o`` pattern ints, ``page``, optional ``omega`` as a
  JSON list of int lists -- the GET-parameter encoding of the paper's
  request URL);
* ``POST /fragment``  -- the same request as a brtpf/v1 ``request``
  envelope body (``core/wire.py``);
* ``GET  /metrics``   -- the canonical metrics snapshot
  (``core/metrics.py``), same keys over the wire as in-process, plus a
  transport-only ``routes`` section: server-side per-endpoint latency
  quantiles over a bounded window of recent requests, in the SAME
  ``latency_summary()`` schema the closed-loop load generator reports
  client-side -- so an SLO gate can read either side of the wire.

An over-maxMpR request maps to **HTTP 414** (the paper's URL-length
rationale for maxMpR made literal); malformed envelopes map to 400.
Responses are brtpf/v1 ``fragment`` envelopes, byte-identical in
content to an in-process ``handle`` call on every selector backend
(tests/test_transport.py asserts this).

The app is a plain ASGI-3 callable -- no framework required. When
``starlette``/``uvicorn`` are installed (the ``serving`` extra in
pyproject.toml) the same app runs under a real server via
:func:`run_app`; :class:`TestClient` drives it fully in-process for
tests and the closed-loop load generator, mirroring the
``starlette.testclient`` surface (sage-engine's test shape) without
the dependency.
"""
from __future__ import annotations

import asyncio
import json
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple
from urllib.parse import parse_qs

from ..core.batching import (DEFAULT_BATCH_WINDOW_S, DEFAULT_MAX_BATCH,
                             AsyncBrTPFServer, DeadlineExceeded,
                             QueueSaturated)
from ..core.metrics import latency_summary
from ..core.server import MaxMprExceeded
from ..core.wire import (WIRE_VERSION, KIND_REQUEST, WireError, dumps,
                         envelope, error_to_wire, fragment_to_wire, loads,
                         request_from_wire)

_JSON_HEADERS = [(b"content-type", b"application/json")]

# Per-route latency window: how many recent request durations each
# endpoint retains. Bounded so a long-lived server cannot grow metrics
# state without bound; 2048 samples keep p99 meaningful (nearest-rank
# needs ~100+ samples) while costing a few KiB per route.
ROUTE_SAMPLE_CAP = 2048

# Endpoints whose latency is recorded (unknown paths are not: an
# attacker probing random URLs must not mint unbounded route labels).
_ROUTED_PATHS = ("/", "/fragment", "/metrics")


class RouteLatency:
    """Server-side per-endpoint latency recorder.

    Keeps the last :data:`ROUTE_SAMPLE_CAP` request durations per
    ``"METHOD /path"`` label and summarizes them through the shared
    :func:`~repro.core.metrics.latency_summary` schema -- p50/p95/p99/
    mean milliseconds plus ``req_per_s`` -- so ``GET /metrics`` exposes
    the same quantile keys server-side that ``benchmarks/latency.py``
    measures client-side. ``req_per_s`` is computed over the wall time
    since the route's first recorded request (the SLO-relevant arrival
    rate, not the sum of service times).
    """

    def __init__(self, cap: int = ROUTE_SAMPLE_CAP) -> None:
        self._cap = int(cap)
        self._samples: Dict[str, Deque[float]] = {}
        self._started: Dict[str, float] = {}

    def record(self, route: str, seconds: float, now: float) -> None:
        window = self._samples.get(route)
        if window is None:
            window = self._samples[route] = deque(maxlen=self._cap)
            self._started[route] = now - seconds
        window.append(seconds)

    def summary(self, now: Optional[float] = None) -> dict:
        now = time.perf_counter() if now is None else now
        return {route: latency_summary(
                    list(window),
                    wall_s=max(now - self._started[route], 1e-9))
                for route, window in sorted(self._samples.items())}


class BrTPFApp:
    """ASGI-3 application over an async brTPF backend.

    ``backend`` is anything with ``async handle(Request) -> Fragment``,
    ``metrics_snapshot()``, ``note_mappings(Request)``, ``max_mpr`` and
    ``async aclose()`` -- an :class:`~repro.core.batching.AsyncBrTPFServer`
    (one origin) or a :class:`~repro.serving.router.ReplicaRouter`
    (a replica fleet). Everything the handlers await is async; the
    origin's kernel work runs inside the backend's batching flush.
    """

    def __init__(self, backend) -> None:
        self.backend = backend
        self.route_latency = RouteLatency()

    @property
    def max_mpr(self) -> int:
        return self.backend.max_mpr

    async def aclose(self) -> None:
        await self.backend.aclose()

    # -- ASGI entry ----------------------------------------------------------

    async def __call__(self, scope, receive, send) -> None:
        if scope["type"] == "lifespan":
            await self._lifespan(receive, send)
            return
        if scope["type"] != "http":
            raise RuntimeError(f"unsupported ASGI scope {scope['type']!r}")
        method = scope["method"]
        path = scope["path"]
        start = time.perf_counter()
        try:
            if path == "/fragment" and method in ("GET", "POST"):
                await self._fragment(scope, receive, send, method)
            elif path == "/metrics" and method == "GET":
                await self._send_json(send, 200, self._metrics())
            elif path == "/" and method == "GET":
                await self._send_json(send, 200, self._describe())
            elif path in _ROUTED_PATHS:
                await self._send_json(
                    send, 405, error_to_wire(405, f"method {method} not "
                                                  f"allowed on {path}",
                                             code="METHOD_NOT_ALLOWED"))
            else:
                await self._send_json(
                    send, 404, error_to_wire(404, f"unknown path {path!r}",
                                             code="NOT_FOUND"))
        finally:
            if path in _ROUTED_PATHS:
                now = time.perf_counter()
                self.route_latency.record(f"{method} {path}",
                                          now - start, now)

    async def _lifespan(self, receive, send) -> None:
        while True:
            message = await receive()
            if message["type"] == "lifespan.startup":
                await send({"type": "lifespan.startup.complete"})
            elif message["type"] == "lifespan.shutdown":
                await self.backend.aclose()
                await send({"type": "lifespan.shutdown.complete"})
                return

    # -- handlers ------------------------------------------------------------

    def _metrics(self) -> dict:
        """Backend snapshot plus the transport-only per-route latency
        section. ``routes`` is added HERE and not in metrics_snapshot:
        only the wire layer has routes, and the in-process snapshot
        must stay byte-comparable across surfaces that have none."""
        snap = self.backend.metrics_snapshot()
        snap["routes"] = self.route_latency.summary()
        return snap

    def _describe(self) -> dict:
        return envelope(
            "description",
            endpoints={"fragment": ["GET", "POST"], "metrics": ["GET"]},
            max_mpr=self.backend.max_mpr,
        )

    async def _fragment(self, scope, receive, send, method: str) -> None:
        try:
            if method == "POST":
                body = await self._read_body(receive)
                req = request_from_wire(loads(body))
            else:
                req = request_from_wire(
                    _query_to_request_envelope(scope["query_string"]))
        except WireError as exc:
            await self._send_json(send, 400, error_to_wire(
                400, str(exc), code="BAD_REQUEST"))
            return
        # The wire boundary charges the attached mappings (in-process
        # clients charge Counters.mappings_sent themselves).
        self.backend.note_mappings(req)
        try:
            frag = await self.backend.handle(req)
        except MaxMprExceeded as exc:
            # the paper's maxMpR bound exists because Omega rides the
            # request URL: too many mappings = URI too long
            await self._send_json(send, 414, error_to_wire(
                414, str(exc), code="MAX_MPR_EXCEEDED"))
            return
        except QueueSaturated as exc:
            # admission control (docs/serving.md): the batching queue is
            # full; retryable -- it drains within one batching window,
            # which is exactly the retry_after_ms floor advertised here
            window_s = getattr(self.backend, "batch_window_s", None)
            await self._send_json(
                send, 503, error_to_wire(
                    503, str(exc), retryable=True, code="QUEUE_SATURATED",
                    retry_after_ms=(None if window_s is None
                                    else max(window_s, 0.0) * 1e3)))
            return
        except DeadlineExceeded as exc:
            # deadline-aware shedding (docs/resilience.md): the request's
            # budget expired in the batching queue; retryable -- the next
            # attempt may hit a resident page or a healthier replica
            await self._send_json(
                send, 504, error_to_wire(504, str(exc), retryable=True,
                                         code="DEADLINE_EXCEEDED"))
            return
        await self._send_json(send, 200, fragment_to_wire(frag))

    # -- ASGI plumbing -------------------------------------------------------

    @staticmethod
    async def _read_body(receive) -> bytes:
        chunks: List[bytes] = []
        while True:
            message = await receive()
            if message["type"] != "http.request":
                raise WireError("connection closed before body complete")
            chunks.append(message.get("body", b""))
            if not message.get("more_body", False):
                return b"".join(chunks)

    @staticmethod
    async def _send_json(send, status: int, obj: dict) -> None:
        body = dumps(obj)
        await send({
            "type": "http.response.start",
            "status": status,
            "headers": _JSON_HEADERS
            + [(b"content-length", str(len(body)).encode("ascii"))],
        })
        await send({"type": "http.response.body", "body": body})


def _query_to_request_envelope(query_string: bytes) -> dict:
    """GET-parameter encoding -> brtpf/v1 request envelope.

    The decode then flows through the SAME ``request_from_wire`` as the
    POST body path, so validation and semantics cannot diverge between
    the two encodings.
    """
    params = parse_qs(query_string.decode("utf-8"), keep_blank_values=True)

    def one(name: str, default: Optional[str] = None) -> Optional[str]:
        vals = params.get(name)
        if not vals:
            if default is None and name in ("s", "p", "o"):
                raise WireError(f"missing query param {name!r}")
            return default
        if len(vals) > 1:
            raise WireError(f"duplicate query param {name!r}")
        return vals[0]

    def as_int(name: str, raw: str) -> int:
        try:
            return int(raw)
        except ValueError:
            raise WireError(f"query param {name!r} must be an int, "
                            f"got {raw!r}") from None

    pattern = [as_int(n, one(n)) for n in ("s", "p", "o")]
    page = as_int("page", one("page", "0"))
    omega = None
    omega_vars = None
    raw_omega = one("omega", "")
    if raw_omega:
        try:
            omega = json.loads(raw_omega)
        except ValueError as exc:
            raise WireError(f"query param 'omega' must be JSON: "
                            f"{exc}") from None
        if omega is not None and not isinstance(omega, list):
            raise WireError("query param 'omega' must be a JSON list")
    raw_vars = one("omega_vars", "")
    if raw_vars:
        omega_vars = as_int("omega_vars", raw_vars)
    elif isinstance(omega, list) and omega:
        omega_vars = len(omega[0]) if isinstance(omega[0], list) else None
    return {"v": WIRE_VERSION, "kind": KIND_REQUEST, "pattern": pattern,
            "omega": omega, "omega_vars": omega_vars, "page": page}


# ---------------------------------------------------------------------------
# App factories
# ---------------------------------------------------------------------------


def create_app(backend) -> BrTPFApp:
    """Wrap an existing async backend (front end or router) as ASGI."""
    return BrTPFApp(backend)


def app_from_config(store, config=None, *,
                    batch_window_s: float = DEFAULT_BATCH_WINDOW_S,
                    max_batch: int = DEFAULT_MAX_BATCH,
                    cache=None, replicas: int = 1,
                    policy: str = "pattern") -> BrTPFApp:
    """Build the full serving edge from one
    :class:`~repro.core.config.ServerConfig` -- the same value object
    ``BrTPFServer`` and ``AsyncBrTPFServer`` take, so the in-process
    servers the tests compare against are provably configured
    identically. ``replicas > 1`` puts a
    :class:`~repro.serving.router.ReplicaRouter` behind the app.
    """
    if replicas > 1:
        from .router import ReplicaRouter
        return BrTPFApp(ReplicaRouter(
            store, config, replicas=replicas, policy=policy,
            batch_window_s=batch_window_s, max_batch=max_batch))
    return BrTPFApp(AsyncBrTPFServer.from_config(
        store, config, batch_window_s=batch_window_s,
        max_batch=max_batch, cache=cache))


def run_app(app: BrTPFApp, host: str = "127.0.0.1",
            port: int = 8000, **uvicorn_kwargs) -> None:
    """Serve the app with uvicorn (optional dependency: install the
    ``serving`` extra). Import is gated so the rest of the serving edge
    -- TestClient, transports, the load generator -- works without it."""
    try:
        import uvicorn
    except ImportError as exc:  # pragma: no cover - env without extras
        raise RuntimeError(
            "uvicorn is not installed; pip install 'repro[serving]' "
            "to serve over a real socket (the in-process TestClient "
            "and transports work without it)") from exc
    uvicorn.run(app, host=host, port=port, **uvicorn_kwargs)


# ---------------------------------------------------------------------------
# In-process test client
# ---------------------------------------------------------------------------


class TestResponse:
    """Minimal response surface (status_code / headers / content /
    json()), shaped after ``starlette.testclient`` responses."""

    __test__ = False  # library class, not a pytest collection target

    def __init__(self, status_code: int,
                 headers: List[Tuple[bytes, bytes]],
                 content: bytes) -> None:
        self.status_code = status_code
        self.headers = {k.decode("latin-1"): v.decode("latin-1")
                        for k, v in headers}
        self.content = content

    def json(self):
        return json.loads(self.content.decode("utf-8"))


async def request_asgi(app, method: str, path: str,
                       params: Optional[dict] = None,
                       body: Optional[bytes] = None) -> TestResponse:
    """Drive one request through an ASGI app inside the running loop
    (the transport layer and concurrent load generators call this
    directly; the sync :class:`TestClient` wraps it)."""
    from urllib.parse import urlencode
    query = urlencode(params or {}, doseq=True)
    scope = {
        "type": "http",
        "asgi": {"version": "3.0"},
        "http_version": "1.1",
        "method": method,
        "scheme": "http",
        "path": path,
        "raw_path": path.encode("utf-8"),
        "query_string": query.encode("utf-8"),
        "headers": _JSON_HEADERS if body is not None else [],
        "client": ("testclient", 50000),
        "server": ("testserver", 80),
    }
    sent = {"body": body or b"", "done": body is None}
    messages: List[dict] = []

    async def receive():
        if sent["done"]:
            return {"type": "http.disconnect"}
        sent["done"] = True
        return {"type": "http.request", "body": sent["body"],
                "more_body": False}

    async def send(message):
        messages.append(message)

    await app(scope, receive, send)
    status, headers, chunks = 500, [], []
    for message in messages:
        if message["type"] == "http.response.start":
            status = message["status"]
            headers = list(message.get("headers", []))
        elif message["type"] == "http.response.body":
            chunks.append(message.get("body", b""))
    return TestResponse(status, headers, b"".join(chunks))


class TestClient:
    """Synchronous in-process client for :class:`BrTPFApp`.

    Owns ONE event loop for its lifetime: the async front end behind
    the app binds its locks/timers to the first loop that touches them,
    so every request must run on the same loop (what starlette's
    TestClient achieves with a portal thread).
    """

    __test__ = False  # library class, not a pytest collection target

    def __init__(self, app) -> None:
        self.app = app
        self._loop = asyncio.new_event_loop()

    def request(self, method: str, path: str,
                params: Optional[dict] = None,
                json_body: Optional[dict] = None) -> TestResponse:
        body = None if json_body is None else dumps(json_body)
        return self._loop.run_until_complete(
            request_asgi(self.app, method, path, params=params, body=body))

    def get(self, path: str, params: Optional[dict] = None) -> TestResponse:
        return self.request("GET", path, params=params)

    def post(self, path: str,
             json_body: Optional[dict] = None) -> TestResponse:
        return self.request("POST", path, json_body=json_body)

    def close(self) -> None:
        if not self._loop.is_closed():
            self._loop.run_until_complete(self.app.aclose())
            self._loop.close()

    def __enter__(self) -> "TestClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
