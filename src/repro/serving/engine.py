"""Batched serving engine: prefill + greedy decode with a KV cache.

A deliberately small but real engine: fixed-size batch slots, bucketed
prompt padding, jit'd prefill and decode steps, per-request accounting.
The dry-run shapes (``prefill_32k``/``decode_32k``/``long_500k``) lower
exactly these step functions on the production meshes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import ModelDef


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # [new_tokens]
    prompt_len: int
    steps: int


class ServingEngine:
    def __init__(self, model: ModelDef, params: Any, max_batch: int,
                 max_seq: int, eos_id: Optional[int] = None) -> None:
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id

        self._prefill = jax.jit(
            lambda p, t: model.prefill(p, t, max_seq=max_seq))
        self._decode = jax.jit(
            lambda p, c, tok, pos: model.decode_step(p, c, tok, pos),
            donate_argnums=(1,))

    def generate(self, prompts: Sequence[np.ndarray],
                 max_new_tokens: int = 32) -> List[GenerationResult]:
        """Greedy generation for a batch of prompts (left-padded to a
        common length; right side reserved for generation)."""
        assert len(prompts) <= self.max_batch
        b = self.max_batch
        plen = max(len(p) for p in prompts)
        assert plen + max_new_tokens <= self.max_seq
        toks = np.zeros((b, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p  # left pad with 0

        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

        out = np.zeros((b, max_new_tokens), np.int32)
        pos = plen
        for step in range(max_new_tokens):
            out[:, step] = np.asarray(next_tok)
            logits, cache = self._decode(
                self.params, cache, next_tok[:, None], jnp.int32(pos))
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(
                jnp.int32)
            pos += 1
            if (self.eos_id is not None
                    and bool((out[: len(prompts), : step + 1]
                              == self.eos_id).any(axis=1).all())):
                break

        results = []
        for i, p in enumerate(prompts):
            gen = out[i]
            if self.eos_id is not None:
                hits = np.nonzero(gen == self.eos_id)[0]
                if hits.size:
                    gen = gen[: hits[0] + 1]
            results.append(GenerationResult(tokens=gen,
                                            prompt_len=len(p),
                                            steps=pos - plen))
        return results
