"""Deterministic, seeded fault injection for the serving stack.

The brTPF argument is about server availability under load; to *test*
availability you must be able to make servers fail on demand, the same
way every run. A :class:`FaultPlan` is a seeded, per-replica schedule of
failure modes:

* ``delay_s`` -- add fixed latency to every request (slow replica);
* ``error_rate`` -- fail that fraction of requests with a transport
  error (``error_status``, default 503 retryable);
* ``drop_rate`` -- swallow that fraction: the backend never answers
  within any finite deadline (modeled as an un-cancelled stall, so only
  a client deadline gets the caller out);
* ``stall_after`` / ``stall_s`` -- after K served requests, every
  subsequent request hangs for ``stall_s`` before being served (a
  wedged replica: the client's deadline expires first, and repeated
  expiries open the router's circuit breaker);
* ``crash_after`` -- after K served requests, every subsequent request
  fails hard with a non-retryable-looking 500 (a dead replica).

Determinism: each replica draws from its own ``random.Random`` seeded
as ``seed * 1000003 + replica``, and decisions are made per *perturb
call* in arrival order -- so a (plan seed, request order) pair replays
the identical fault sequence in tests, benchmarks and CI.

Three injection points wrap the three layers of the stack with the same
:class:`ReplicaFaults` schedule:

* :class:`FaultyBackend` wraps an async backend (a replica inside
  :class:`~repro.serving.router.ReplicaRouter`, via its ``fault_plan``
  argument) -- faults *behind* the router, which is what the breaker
  and failover logic see;
* :class:`FaultyTransport` wraps a client-side transport -- faults on
  the path, which is what retry/backoff sees;
* :class:`FaultyApp` wraps the ASGI app -- faults at the HTTP edge,
  answered as proper brtpf/v1 error envelopes, which is what the
  AsgiTransport error decoding sees.
"""
from __future__ import annotations

import asyncio
import dataclasses
import random
from typing import Dict, Optional

from ..core.wire import dumps, error_to_wire
from .transport import TransportError


class InjectedFault(TransportError):
    """A failure manufactured by a :class:`FaultPlan` (subclasses
    :class:`TransportError` so client code cannot tell it from a real
    one -- that is the point)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One replica's failure schedule. The default instance is a no-op."""

    delay_s: float = 0.0
    error_rate: float = 0.0
    error_status: int = 503
    drop_rate: float = 0.0
    stall_after: Optional[int] = None
    stall_s: float = 30.0
    crash_after: Optional[int] = None

    def __post_init__(self) -> None:
        for name in ("error_rate", "drop_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if self.delay_s < 0 or self.stall_s < 0:
            raise ValueError("delay_s/stall_s must be >= 0")
        for name in ("stall_after", "crash_after"):
            v = getattr(self, name)
            if v is not None and v < 0:
                raise ValueError(f"{name} must be >= 0 (or None)")

    @property
    def is_noop(self) -> bool:
        return (self.delay_s == 0 and self.error_rate == 0
                and self.drop_rate == 0 and self.stall_after is None
                and self.crash_after is None)


@dataclasses.dataclass
class FaultStats:
    calls: int = 0
    delays: int = 0
    errors: int = 0
    drops: int = 0
    stalls: int = 0
    crashes: int = 0


class ReplicaFaults:
    """One replica's live fault state: the spec plus its seeded RNG and
    served-request counter. ``perturb()`` is awaited before the real
    handler runs; it either returns (possibly after sleeping) or raises
    :class:`InjectedFault`."""

    # a drop is "never answers": long enough that only a deadline ends
    # the wait, short enough that a test without deadlines still ends
    DROP_STALL_S = 600.0

    def __init__(self, spec: FaultSpec, seed: int) -> None:
        self.spec = spec
        self.seed = seed
        self.rng = random.Random(seed)
        self.stats = FaultStats()

    async def perturb(self) -> None:
        spec = self.spec
        self.stats.calls += 1
        served = self.stats.calls
        if (spec.crash_after is not None
                and served > spec.crash_after):
            self.stats.crashes += 1
            raise InjectedFault(500, f"injected crash (seed={self.seed}, "
                                     f"after {spec.crash_after} served)",
                                code="INTERNAL")
        if spec.drop_rate and self.rng.random() < spec.drop_rate:
            self.stats.drops += 1
            await asyncio.sleep(self.DROP_STALL_S)
            return
        if (spec.stall_after is not None
                and served > spec.stall_after):
            self.stats.stalls += 1
            await asyncio.sleep(spec.stall_s)
        if spec.error_rate and self.rng.random() < spec.error_rate:
            self.stats.errors += 1
            raise InjectedFault(
                spec.error_status,
                f"injected error (seed={self.seed})",
                retryable=spec.error_status in (500, 502, 503, 504),
                code=("QUEUE_SATURATED" if spec.error_status == 503
                      else "INTERNAL"))
        if spec.delay_s:
            self.stats.delays += 1
            await asyncio.sleep(spec.delay_s)

    def summary(self) -> dict:
        return dataclasses.asdict(self.stats)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded fleet-wide fault schedule: ``default`` applies to every
    replica without an entry in ``per_replica``. Frozen so a plan can be
    shared across the A/B arms of a chaos run; live state lives in the
    :class:`ReplicaFaults` handed out by :meth:`for_replica`."""

    seed: int = 0
    default: FaultSpec = FaultSpec()
    per_replica: Dict[int, FaultSpec] = dataclasses.field(
        default_factory=dict)

    def spec_for(self, replica: int) -> FaultSpec:
        return self.per_replica.get(replica, self.default)

    def for_replica(self, replica: int) -> ReplicaFaults:
        # distinct, deterministic stream per replica: two replicas with
        # the same spec still fail on different requests
        return ReplicaFaults(self.spec_for(replica),
                             seed=self.seed * 1000003 + replica)


class FaultyBackend:
    """Wrap an async backend (``AsyncBrTPFServer`` or compatible) so
    every ``handle`` is perturbed first. Everything else (metrics,
    ``note_mappings``, ``max_mpr``, ``aclose``, ``server`` ...)
    delegates to the wrapped backend unchanged."""

    def __init__(self, inner, faults: ReplicaFaults) -> None:
        self.inner = inner
        self.faults = faults

    async def handle(self, req):
        await self.faults.perturb()
        return await self.inner.handle(req)

    def __getattr__(self, name):
        return getattr(self.inner, name)


class FaultyTransport:
    """Wrap a client-side transport (Loopback/Asgi/Resilient) the same
    way -- the injection point for client-path faults."""

    def __init__(self, inner, faults: ReplicaFaults) -> None:
        self.inner = inner
        self.faults = faults

    @property
    def max_mpr(self) -> int:
        return self.inner.max_mpr

    async def handle(self, req):
        await self.faults.perturb()
        return await self.inner.handle(req)

    async def metrics(self) -> dict:
        return await self.inner.metrics()

    async def aclose(self) -> None:
        await self.inner.aclose()


class FaultyApp:
    """ASGI middleware injecting faults at the HTTP edge: an injected
    fault becomes a real brtpf/v1 error envelope with the fault's
    status, so the client-side decoding path (AsgiTransport ->
    ``error_from_wire`` -> TransportError) is exercised end to end.
    Only ``/fragment`` traffic is perturbed; ``/metrics`` stays clean so
    observability survives the chaos it is observing."""

    def __init__(self, app, faults: ReplicaFaults) -> None:
        self.app = app
        self.faults = faults

    def __getattr__(self, name):
        return getattr(self.app, name)

    async def __call__(self, scope, receive, send) -> None:
        if (scope.get("type") == "http"
                and scope.get("path") == "/fragment"):
            try:
                await self.faults.perturb()
            except InjectedFault as exc:
                body = dumps(error_to_wire(exc.status, str(exc),
                                           retryable=exc.retryable,
                                           code=exc.code))
                await send({
                    "type": "http.response.start",
                    "status": exc.status,
                    "headers": [(b"content-type", b"application/json"),
                                (b"content-length",
                                 str(len(body)).encode("ascii"))],
                })
                await send({"type": "http.response.body", "body": body})
                return
        await self.app(scope, receive, send)
