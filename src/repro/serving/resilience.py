"""Client-side resilience: retries, backoff + jitter, hedging, deadlines.

Fragment requests are idempotent reads -- the response to (pattern,
Omega, page) is a pure function of the dataset -- so retrying them is
always safe; what needs care is retrying the *right* failures with the
*right* pacing:

* :func:`is_retryable` is the ONE predicate deciding what is transient
  (repro-lint RS001 enforces that every retry loop consults it): 503
  admission control, transport 5xx, timeouts/deadline expiries. 400/404
  /414 are the client's own fault and retrying them would loop forever.
* :class:`RetryPolicy` paces attempts with exponential backoff and FULL
  jitter (``uniform(0, min(cap, base * 2^attempt))``): under a
  correlated failure (a replica stalls, a queue saturates) full jitter
  de-synchronizes the retry herd, while a ``retry_after_ms`` hint from
  the server (one batching window on 503) floors the pause.
* Hedging cuts tail latency: once enough latency samples exist, a
  second identical request is fired after the observed p95 and the
  first response wins. brTPF fragments are cheap and idempotent, so the
  cost of a duplicate is one wasted page -- the classic "tied requests"
  trade.
* Deadlines: the policy (or the caller, via ``Request.timeout_ms``)
  fixes a total per-request budget; every attempt is stamped with the
  REMAINING budget, so the server's deadline-aware shedding
  (core/batching.py) and the transports' bounded awaits see exactly how
  much patience the client has left.

All counters surface through ``metrics()`` as the ``"resilience"``
section of the canonical snapshot (core/metrics.py
``resilience_section``).
"""
from __future__ import annotations

import asyncio
import dataclasses
import random
import time
from collections import deque
from typing import Optional

from ..core.batching import DeadlineExceeded, QueueSaturated
from ..core.metrics import resilience_section
from .transport import TransportError

# Transport statuses worth retrying even without a retryable flag on
# the envelope: transient server/gateway conditions on an idempotent GET.
RETRYABLE_STATUSES = (408, 500, 502, 503, 504)


def is_retryable(exc: BaseException) -> bool:
    """Central retry predicate (docs/resilience.md; repro-lint RS001).

    Retryable: admission-control 503 (:class:`QueueSaturated`), deadline
    expiries (:class:`DeadlineExceeded` -- the NEXT attempt may hit a
    resident page or a healthy replica), timeouts, and transport errors
    that are flagged retryable or carry a transient 5xx/408 status.
    Everything else (malformed envelope, 414 maxMpR, client bugs) is
    permanent and must surface immediately.
    """
    if isinstance(exc, (QueueSaturated, DeadlineExceeded,
                        asyncio.TimeoutError)):
        return True
    if isinstance(exc, TransportError):
        return exc.retryable or exc.status in RETRYABLE_STATUSES
    return False


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Pacing knobs for :class:`ResilientTransport`.

    ``deadline_ms`` is the default per-request budget applied when the
    request itself carries none; ``None`` means unbounded (retries still
    stop at ``max_attempts``). ``attempt_timeout_ms`` caps what ONE
    attempt may burn of that budget: against a stalled replica it is
    the difference between "first attempt eats the whole deadline" and
    "fail fast, feed the breaker, retry elsewhere with budget to
    spare". ``hedge_after_s`` pins the hedge delay;
    when ``None`` it is derived as the p95 of observed latencies once
    ``hedge_min_samples`` have been collected (no hedging before that
    -- a cold client has no tail to cut).
    """

    max_attempts: int = 4
    base_backoff_s: float = 0.005
    max_backoff_s: float = 0.25
    deadline_ms: Optional[float] = None
    attempt_timeout_ms: Optional[float] = None
    hedge: bool = False
    hedge_after_s: Optional[float] = None
    hedge_min_samples: int = 32

    def __post_init__(self) -> None:
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.base_backoff_s < 0 or self.max_backoff_s < 0:
            raise ValueError("backoff bounds must be >= 0")
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError("deadline_ms must be > 0 (or None)")
        if (self.attempt_timeout_ms is not None
                and self.attempt_timeout_ms <= 0):
            raise ValueError("attempt_timeout_ms must be > 0 (or None)")
        if self.hedge_min_samples < 1:
            raise ValueError("hedge_min_samples must be >= 1")

    def backoff_s(self, attempt: int, rng: random.Random) -> float:
        """Full-jitter exponential backoff for the given (1-based)
        failed-attempt count."""
        cap = min(self.max_backoff_s,
                  self.base_backoff_s * (2 ** (attempt - 1)))
        return rng.uniform(0.0, cap)


@dataclasses.dataclass
class ResilienceStats:
    attempts: int = 0
    retries: int = 0
    hedges: int = 0
    hedge_wins: int = 0
    deadline_exceeded: int = 0
    giveups: int = 0


class ResilientTransport:
    """Retry/hedge/deadline wrapper around any transport.

    Stacks on :class:`~repro.serving.transport.LoopbackTransport`,
    :class:`~repro.serving.transport.AsgiTransport` or a fault-injecting
    wrapper, and presents the same transport surface, so
    :class:`~repro.core.client.AsyncBrTPFClient` plugs in unchanged.
    ``seed`` makes the jitter stream reproducible for tests/benchmarks.
    """

    LATENCY_WINDOW = 512

    def __init__(self, inner, policy: Optional[RetryPolicy] = None,
                 seed: int = 0) -> None:
        self.inner = inner
        self.policy = policy or RetryPolicy()
        self.stats = ResilienceStats()
        self._rng = random.Random(seed)
        self._samples = deque(maxlen=self.LATENCY_WINDOW)

    @property
    def max_mpr(self) -> int:
        return self.inner.max_mpr

    # -- request path --------------------------------------------------------

    async def handle(self, req):
        budget_ms = (req.timeout_ms if req.timeout_ms is not None
                     else self.policy.deadline_ms)
        deadline = (None if budget_ms is None
                    else time.monotonic() + budget_ms / 1e3)
        failures = 0
        while True:
            remaining_s = (None if deadline is None
                           else deadline - time.monotonic())
            if remaining_s is not None and remaining_s <= 0:
                self.stats.deadline_exceeded += 1
                raise DeadlineExceeded(
                    f"client budget of {budget_ms:.1f}ms exhausted "
                    f"after {failures} failed attempt(s)")
            attempt_ms = (None if remaining_s is None
                          else remaining_s * 1e3)
            cap = self.policy.attempt_timeout_ms
            if cap is not None:
                attempt_ms = cap if attempt_ms is None \
                    else min(attempt_ms, cap)
            stamped = (req if attempt_ms is None else
                       dataclasses.replace(req, timeout_ms=attempt_ms))
            self.stats.attempts += 1
            try:
                return await self._attempt(stamped, remaining_s)
            except Exception as exc:
                if not is_retryable(exc):
                    raise
                failures += 1
                if failures >= self.policy.max_attempts:
                    self.stats.giveups += 1
                    raise
                self.stats.retries += 1
                pause = self.policy.backoff_s(failures, self._rng)
                hint = getattr(exc, "retry_after_ms", None)
                if hint:
                    pause = max(pause, hint / 1e3)
                if remaining_s is not None:
                    pause = min(pause, remaining_s)
                if pause > 0:
                    await asyncio.sleep(pause)

    async def _attempt(self, req, remaining_s: Optional[float]):
        """One timed attempt (possibly hedged); successes feed the
        latency window the hedge delay derives from."""
        t0 = time.perf_counter()
        delay = self._hedge_delay_s()
        if delay is None:
            frag = await self.inner.handle(req)
        else:
            frag = await self._hedged(req, delay)
        self._samples.append(time.perf_counter() - t0)
        return frag

    async def _hedged(self, req, delay_s: float):
        """Primary attempt; if it is still unresolved after ``delay_s``
        fire an identical hedge and take whichever answers first (first
        *success* wins; a failure waits for the slower sibling before
        surfacing). Losers are cancelled -- an abandoned hedge must not
        keep a replica busy."""
        primary = asyncio.ensure_future(self.inner.handle(req))
        tasks = {primary}
        try:
            done, _ = await asyncio.wait({primary}, timeout=delay_s)
            if primary in done:
                return await primary  # already resolved: result or raise
            self.stats.hedges += 1
            backup = asyncio.ensure_future(self.inner.handle(req))
            tasks.add(backup)
            last_exc: Optional[BaseException] = None
            waiting = set(tasks)
            while waiting:
                done, waiting = await asyncio.wait(
                    waiting, return_when=asyncio.FIRST_COMPLETED)
                for task in done:
                    if task.exception() is None:
                        if task is backup:
                            self.stats.hedge_wins += 1
                        return await task  # done: yields the fragment
                    last_exc = task.exception()
            assert last_exc is not None
            raise last_exc
        finally:
            for task in tasks:
                if not task.done():
                    task.cancel()
            for task in tasks:
                if not task.done():
                    try:
                        await task
                    except (Exception, asyncio.CancelledError):
                        pass

    def _hedge_delay_s(self) -> Optional[float]:
        if not self.policy.hedge:
            return None
        if self.policy.hedge_after_s is not None:
            return self.policy.hedge_after_s
        if len(self._samples) < self.policy.hedge_min_samples:
            return None
        ordered = sorted(self._samples)
        idx = min(len(ordered) - 1, int(0.95 * len(ordered)))
        return ordered[idx]

    # -- observability / lifecycle -------------------------------------------

    async def metrics(self) -> dict:
        """The inner snapshot with this client's retry/hedge counters
        overlaid on its ``"resilience"`` section (server-side ``shed``
        and router ``breaker`` numbers pass through untouched)."""
        snap = await self.inner.metrics()
        section = snap.setdefault("resilience", resilience_section())
        section["retries"] = (section.get("retries", 0)
                              + self.stats.retries)
        section["hedges"] = section.get("hedges", 0) + self.stats.hedges
        section["hedge_wins"] = (section.get("hedge_wins", 0)
                                 + self.stats.hedge_wins)
        section["deadline_exceeded"] = (
            section.get("deadline_exceeded", 0)
            + self.stats.deadline_exceeded)
        section["giveups"] = (section.get("giveups", 0)
                              + self.stats.giveups)
        return snap

    async def aclose(self) -> None:
        await self.inner.aclose()
