"""Client-side transports speaking the brtpf/v1 wire schema.

A transport is what :class:`~repro.core.client.AsyncBrTPFClient` plugs
into instead of a raw ``AsyncBrTPFServer``: anything with
``async handle(Request) -> Fragment``, ``async metrics() -> dict``,
``max_mpr`` and ``async aclose()``. Two implementations:

* :class:`LoopbackTransport` -- in-process, but every request and
  response round-trips through the SAME brtpf/v1 envelope serialization
  the HTTP path uses (``core/wire.py``: ``to_wire -> bytes ->
  from_wire`` both ways). It is the parity anchor: if the HTTP path and
  the loopback path disagree, the bug is in the transport, not the
  schema -- and it is what the CI-gated ``loopback:*`` latency budgets
  measure, because it prices the serialization boundary without socket
  noise.
* :class:`AsgiTransport` -- drives a :class:`~repro.serving.http.BrTPFApp`
  through real ASGI messages (``POST /fragment``), fully in-process but
  through the complete HTTP layer: status codes (414 ->
  :class:`~repro.core.server.MaxMprExceeded`), headers, body framing.
  Point :func:`repro.serving.http.run_app` at the same app and the
  identical bytes go over a socket.

Both charge ``mappings_sent`` at the wire boundary via the backend's
``note_mappings`` -- the in-process client path charges it client-side,
so the two never double-count.

Deadline semantics are identical across the two (docs/resilience.md):
a request carrying ``timeout_ms`` bounds the await on the backend with
that budget, and expiry surfaces as
:class:`~repro.core.batching.DeadlineExceeded` on either path -- whether
the budget ran out client-side (the bounded await fired) or server-side
(the batching front end shed the request / the ASGI app answered 504).
Retryability travels on :class:`TransportError` (``retryable`` /
``code`` / ``retry_after_ms``, decoded from the error envelope), which
is what the central ``is_retryable()`` predicate in
``serving/resilience.py`` consults.
"""
from __future__ import annotations

import asyncio
from typing import Optional

from ..core.batching import DeadlineExceeded
from ..core.selectors import Fragment
from ..core.server import MaxMprExceeded, Request
from ..core.wire import (WireError, dumps, error_from_wire,
                         fragment_from_wire, fragment_to_wire, loads,
                         request_from_wire, request_to_wire)
from .http import BrTPFApp, request_asgi


class TransportError(RuntimeError):
    """Non-414 HTTP failure surfaced by a transport.

    ``retryable`` / ``code`` / ``retry_after_ms`` carry the error
    envelope's resilience fields (core/wire.py ``error_to_wire``) so the
    retry policy can branch on the condition, not on message text.
    """

    def __init__(self, status: int, message: str,
                 retryable: bool = False, code: Optional[str] = None,
                 retry_after_ms: Optional[float] = None) -> None:
        super().__init__(f"HTTP {status}: {message}")
        self.status = status
        self.retryable = retryable
        self.code = code
        self.retry_after_ms = retry_after_ms


async def _bounded(awaitable, timeout_ms: Optional[float]):
    """Await with the request's remaining deadline budget (if any);
    expiry raises :class:`DeadlineExceeded` -- the one deadline
    implementation both transports share, so loopback and ASGI cannot
    drift."""
    if timeout_ms is None:
        return await awaitable
    try:
        return await asyncio.wait_for(awaitable, timeout_ms / 1e3)
    except asyncio.TimeoutError:
        raise DeadlineExceeded(
            f"no response within timeout_ms={timeout_ms:.1f}") from None


class LoopbackTransport:
    """In-process transport over an ``AsyncBrTPFServer`` (or a
    ``ReplicaRouter``) with full wire-envelope round-trips."""

    def __init__(self, front) -> None:
        self.front = front

    @property
    def max_mpr(self) -> int:
        return self.front.max_mpr

    async def handle(self, req: Request) -> Fragment:
        # serialize -> bytes -> parse: the request the origin sees is
        # exactly what an HTTP server would have decoded
        wire_req = request_from_wire(loads(dumps(request_to_wire(req))))
        self.front.note_mappings(wire_req)
        frag = await _bounded(self.front.handle(wire_req),
                              wire_req.timeout_ms)  # MaxMprExceeded raises
        return fragment_from_wire(loads(dumps(fragment_to_wire(frag))))

    async def metrics(self) -> dict:
        return loads(dumps(self.front.metrics_snapshot()))

    async def aclose(self) -> None:
        await self.front.aclose()


class AsgiTransport:
    """Transport over a :class:`~repro.serving.http.BrTPFApp` via real
    ASGI request/response messages (the HTTP path minus the socket)."""

    def __init__(self, app: BrTPFApp) -> None:
        self.app = app

    @property
    def max_mpr(self) -> int:
        return self.app.max_mpr

    async def handle(self, req: Request) -> Fragment:
        resp = await _bounded(
            request_asgi(self.app, "POST", "/fragment",
                         body=dumps(request_to_wire(req))),
            req.timeout_ms)
        if resp.status_code == 200:
            return fragment_from_wire(loads(resp.content))
        err = _error_fields(resp)
        message = err["error"]
        if resp.status_code == 414:
            raise MaxMprExceeded(message)
        if resp.status_code == 400:
            raise WireError(message)
        if resp.status_code == 504 or err["code"] == "DEADLINE_EXCEEDED":
            # server-side shed: same exception type as a client-side
            # expiry, so callers see ONE deadline condition
            raise DeadlineExceeded(message)
        raise TransportError(resp.status_code, message,
                             retryable=err["retryable"],
                             code=err["code"],
                             retry_after_ms=err["retry_after_ms"])

    async def metrics(self) -> dict:
        resp = await request_asgi(self.app, "GET", "/metrics")
        if resp.status_code != 200:
            raise TransportError(resp.status_code,
                                 _error_fields(resp)["error"])
        return loads(resp.content)

    async def aclose(self) -> None:
        await self.app.aclose()


def _error_fields(resp) -> dict:
    """Best-effort decode of an error response body into the normalized
    ``error_from_wire`` dict; a non-envelope body (proxy HTML, truncated
    bytes) degrades to a message-only dict instead of masking the
    original HTTP failure with a WireError."""
    try:
        return error_from_wire(loads(resp.content))
    except WireError:
        return {"status": resp.status_code,
                "error": resp.content.decode("utf-8", "replace"),
                "retryable": False, "code": None, "retry_after_ms": None}


def _error_message(resp) -> str:
    return _error_fields(resp)["error"]
