"""Client-side transports speaking the brtpf/v1 wire schema.

A transport is what :class:`~repro.core.client.AsyncBrTPFClient` plugs
into instead of a raw ``AsyncBrTPFServer``: anything with
``async handle(Request) -> Fragment``, ``async metrics() -> dict``,
``max_mpr`` and ``async aclose()``. Two implementations:

* :class:`LoopbackTransport` -- in-process, but every request and
  response round-trips through the SAME brtpf/v1 envelope serialization
  the HTTP path uses (``core/wire.py``: ``to_wire -> bytes ->
  from_wire`` both ways). It is the parity anchor: if the HTTP path and
  the loopback path disagree, the bug is in the transport, not the
  schema -- and it is what the CI-gated ``loopback:*`` latency budgets
  measure, because it prices the serialization boundary without socket
  noise.
* :class:`AsgiTransport` -- drives a :class:`~repro.serving.http.BrTPFApp`
  through real ASGI messages (``POST /fragment``), fully in-process but
  through the complete HTTP layer: status codes (414 ->
  :class:`~repro.core.server.MaxMprExceeded`), headers, body framing.
  Point :func:`repro.serving.http.run_app` at the same app and the
  identical bytes go over a socket.

Both charge ``mappings_sent`` at the wire boundary via the backend's
``note_mappings`` -- the in-process client path charges it client-side,
so the two never double-count.
"""
from __future__ import annotations

from ..core.server import MaxMprExceeded, Request
from ..core.selectors import Fragment
from ..core.wire import (WireError, dumps, fragment_from_wire,
                         fragment_to_wire, loads, request_from_wire,
                         request_to_wire)
from .http import BrTPFApp, request_asgi


class TransportError(RuntimeError):
    """Non-414 HTTP failure surfaced by a transport."""

    def __init__(self, status: int, message: str) -> None:
        super().__init__(f"HTTP {status}: {message}")
        self.status = status


class LoopbackTransport:
    """In-process transport over an ``AsyncBrTPFServer`` (or a
    ``ReplicaRouter``) with full wire-envelope round-trips."""

    def __init__(self, front) -> None:
        self.front = front

    @property
    def max_mpr(self) -> int:
        return self.front.max_mpr

    async def handle(self, req: Request) -> Fragment:
        # serialize -> bytes -> parse: the request the origin sees is
        # exactly what an HTTP server would have decoded
        wire_req = request_from_wire(loads(dumps(request_to_wire(req))))
        self.front.note_mappings(wire_req)
        frag = await self.front.handle(wire_req)   # MaxMprExceeded raises
        return fragment_from_wire(loads(dumps(fragment_to_wire(frag))))

    async def metrics(self) -> dict:
        return loads(dumps(self.front.metrics_snapshot()))

    async def aclose(self) -> None:
        await self.front.aclose()


class AsgiTransport:
    """Transport over a :class:`~repro.serving.http.BrTPFApp` via real
    ASGI request/response messages (the HTTP path minus the socket)."""

    def __init__(self, app: BrTPFApp) -> None:
        self.app = app

    @property
    def max_mpr(self) -> int:
        return self.app.max_mpr

    async def handle(self, req: Request) -> Fragment:
        resp = await request_asgi(self.app, "POST", "/fragment",
                                  body=dumps(request_to_wire(req)))
        if resp.status_code == 200:
            return fragment_from_wire(loads(resp.content))
        message = _error_message(resp)
        if resp.status_code == 414:
            raise MaxMprExceeded(message)
        if resp.status_code == 400:
            raise WireError(message)
        raise TransportError(resp.status_code, message)

    async def metrics(self) -> dict:
        resp = await request_asgi(self.app, "GET", "/metrics")
        if resp.status_code != 200:
            raise TransportError(resp.status_code, _error_message(resp))
        return loads(resp.content)

    async def aclose(self) -> None:
        await self.app.aclose()


def _error_message(resp) -> str:
    try:
        return loads(resp.content).get("error", "")
    except WireError:
        return resp.content.decode("utf-8", "replace")
