"""Serving: the brTPF HTTP edge + the KV-cache LM engine.

* ``repro.serving.http`` -- ASGI app over the async brTPF front end
  (GET/POST /fragment, GET /metrics), ``TestClient``, ``run_app``.
* ``repro.serving.transport`` -- client-side transports speaking the
  brtpf/v1 wire schema (in-process loopback and ASGI/HTTP).
* ``repro.serving.router`` -- front-end router fanning requests across
  N server replicas, with per-replica circuit breakers and health-gated
  failover (docs/resilience.md).
* ``repro.serving.resilience`` -- client-side retry/backoff, hedged
  requests and deadline budgets over any transport.
* ``repro.serving.faults`` -- deterministic seeded fault injection
  (delay / error / drop / stall / crash) for chaos tests and
  ``benchmarks/chaos.py``.
* ``repro.serving.engine`` -- the LM serving engine (jax; imported
  lazily so the brTPF edge stays usable without an accelerator stack).
"""
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover
    from .engine import GenerationResult, ServingEngine

__all__ = ["GenerationResult", "ServingEngine"]


def __getattr__(name: str):
    # Lazy: engine.py imports jax at module scope; the HTTP edge and its
    # tests must not pay (or require) that import.
    if name in __all__:
        from . import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
