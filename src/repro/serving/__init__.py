"""Serving: KV-cache engine with batched prefill/decode."""
from .engine import GenerationResult, ServingEngine

__all__ = ["GenerationResult", "ServingEngine"]
