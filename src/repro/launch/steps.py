"""jit-able train / prefill / serve steps for every architecture.

``make_*_step`` return pure functions closed over the model; the dry-run
and the real launchers attach shardings via ShapeDtypeStruct inputs (see
``specs.py``) and ``.lower().compile()`` them on the production mesh.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..models.model import ModelDef
from ..train.optimizer import AdamW, apply_updates


def make_train_step(model: ModelDef, optimizer: AdamW,
                    grad_accum: int = 1,
                    grad_axes=None) -> Callable:
    """Build the jit-able train step.

    ``grad_accum > 1`` runs the global batch as a scan over microbatches
    with an fp32 gradient accumulator -- the activation working set
    scales 1/grad_accum, which is what lets the 34B+ dense models fit a
    16 GB chip at global_batch=256. ``grad_axes`` (the model's logical-
    axes pytree) additionally ZeRO-shards the accumulator over the data
    axis (each microbatch's grads reduce-scatter instead of all-reduce).
    """
    from ..sharding.rules import constrain

    def zero_constrain(tree):
        if grad_axes is None:
            return tree

        def leaf(g, axes):
            if not isinstance(axes, tuple):
                return g
            ax = list(axes) + [None] * (g.ndim - len(axes))
            for i, a in enumerate(ax):
                if a is None or a == "embed":
                    ax[i] = "zero"
                    break
            return constrain(g, *ax)

        return jax.tree.map(
            leaf, tree, grad_axes,
            is_leaf=lambda a: a is None or isinstance(a, tuple))

    def train_step(params, opt_state, batch):
        def loss_fn(p, microbatch):
            loss, metrics = model.loss(p, microbatch)
            return loss, metrics

        if grad_accum <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads = zero_constrain(grads)
        else:
            def split(x):
                return x.reshape((grad_accum, x.shape[0] // grad_accum)
                                 + x.shape[1:])

            micro = jax.tree.map(split, batch)
            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            acc0 = zero_constrain(acc0)

            def body(carry, mb):
                acc, loss_sum = carry
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                # Accumulate the raw fp32 *sum*; the mean weighting is
                # applied once after the scan. Dividing inside the loop
                # rounds every microbatch contribution for non-power-of-
                # two grad_accum.
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
                acc = zero_constrain(acc)
                return (acc, loss_sum + loss), metrics

            (grads, loss), metrics_stack = jax.lax.scan(
                body, (acc0, jnp.zeros((), jnp.float32)), micro)
            inv = jnp.float32(1.0 / grad_accum)
            grads = jax.tree.map(lambda a: a * inv, grads)
            loss = loss * inv
            metrics = jax.tree.map(lambda m: m[-1], metrics_stack)

        updates, opt_state, opt_metrics = optimizer.update(
            grads, opt_state, params)
        params = apply_updates(params, updates)
        out_metrics = {"loss": loss, **metrics, **opt_metrics}
        return params, opt_state, out_metrics

    # jit here so the grad_accum=1 and grad_accum=k paths run the same
    # compiled backward numerics (eager per-op dispatch reassociates
    # reductions differently from the scan body XLA compiles, which is
    # visible through Adam's eps on near-cancelling gradients). Callers
    # that re-wrap with jax.jit(..., donate_argnums) just inline this.
    return jax.jit(train_step)


def make_grad_step(model: ModelDef) -> Callable:
    """Gradient-only step (microbatching / accumulation building block)."""
    def grad_step(params, batch):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        return grads, {"loss": loss, **metrics}

    return grad_step


def make_prefill_step(model: ModelDef, max_seq: Optional[int] = None
                      ) -> Callable:
    if model.cfg.encoder_layers:
        def prefill_step(params, tokens, enc_input):
            return model.prefill(params, tokens, enc_input,
                                 max_seq=max_seq)
    else:
        def prefill_step(params, tokens):
            return model.prefill(params, tokens, max_seq=max_seq)
    return prefill_step


def make_serve_step(model: ModelDef) -> Callable:
    """One decode step: (params, cache, token, pos[, enc_out]) ->
    (logits, cache). This is what ``decode_*``/``long_*`` shapes lower."""
    if model.cfg.encoder_layers:
        def serve_step(params, cache, token, pos, enc_out):
            return model.decode_step(params, cache, token, pos,
                                     enc_out=enc_out)
    else:
        def serve_step(params, cache, token, pos):
            return model.decode_step(params, cache, token, pos)
    return serve_step
