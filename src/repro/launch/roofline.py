"""Roofline analysis from compiled dry-run artifacts.

Computes the three roofline terms per (arch x shape x mesh):

    compute    = FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / link_bw

Two sources are combined:

* ``compiled.cost_analysis()`` -- BUT XLA's HloCostAnalysis counts each
  while-loop body ONCE, and every model here scans over layers (and over
  sequence chunks), so its raw numbers undercount by the trip count.
  We therefore parse the compiled per-device HLO text with a
  **trip-count-aware walker**: jax scans lower to while-loops whose
  condition compares the induction variable against a constant, which
  the parser recovers, multiplying nested body costs correctly.
* collective bytes are not in cost_analysis at all: the walker sums the
  result-shape bytes of every all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute / ragged-all-to-all instruction (times
  its loop multiplier).

The compiled module is the post-SPMD per-device program, so all numbers
are per-chip; the brief's formulas (global / chips) reduce to exactly
these quantities.

Hardware model (TPU v5e-like, per brief): 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                "all-to-all", "collective-permute", "ragged-all-to-all")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "tanh", "rsqrt", "sqrt", "log", "negate", "abs",
    "power", "logistic", "select", "compare", "and", "or", "xor",
    "cosine", "sine", "floor", "ceil", "sign", "atan2", "remainder",
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_counts: Dict[str, int] = dataclasses.field(default_factory=dict)

    def scaled(self, k: float) -> "CompCost":
        return CompCost(self.flops * k, self.bytes * k,
                        self.coll_bytes * k,
                        {n: int(c * k) for n, c in
                         self.coll_counts.items()})

    def add(self, other: "CompCost") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        self.coll_bytes += other.coll_bytes
        for n, c in other.coll_counts.items():
            self.coll_counts[n] = self.coll_counts.get(n, 0) + c


_DEF_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|"
    + _SHAPE_RE.pattern + r")(?:\{[^}]*\})?\s+([\w\-]+)\(")


class HloAnalyzer:
    """Trip-count-aware cost walker over (post-SPMD, per-device) HLO.

    The printed HLO omits operand shapes, so each computation first
    builds a symbol table (instr name -> shape string) and operand sizes
    are resolved through it. Fusions contribute their internal FLOPs but
    not internal bytes (fused intermediates never touch HBM); while
    bodies contribute everything times the recovered trip count.
    """

    def __init__(self, hlo_text: str) -> None:
        self.computations = self._split(hlo_text)
        self._entry = self._find_entry(hlo_text)
        self._memo: Dict[str, CompCost] = {}
        self._symtabs: Dict[str, Dict[str, str]] = {}

    @staticmethod
    def _split(text: str) -> Dict[str, List[str]]:
        comps: Dict[str, List[str]] = {}
        name: Optional[str] = None
        body: List[str] = []
        for line in text.splitlines():
            stripped = line.strip()
            # computation headers: `%name (params...) -> result { `
            # params may contain nested parens (tuple types)
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$",
                         stripped)
            # instruction lines contain " = "; header parameter lists only
            # contain '=' inside /*index=N*/ comments
            if m and not stripped.startswith("ROOT") and " = " not in \
                    stripped.split("->")[0]:
                name = m.group(1)
                body = []
                comps[name] = body
            elif stripped == "}":
                name = None
            elif name is not None and stripped:
                body.append(stripped)
        return comps

    @staticmethod
    def _find_entry(text: str) -> Optional[str]:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
        return m.group(1) if m else None

    def _symtab(self, comp: str) -> Dict[str, str]:
        tab = self._symtabs.get(comp)
        if tab is None:
            tab = {}
            for ln in self.computations.get(comp, []):
                m = re.match(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
                             r"(\([^)]*\)|[\w.]+\[[0-9,]*\])", ln)
                if m:
                    tab[m.group(1)] = m.group(2)
            self._symtabs[comp] = tab
        return tab

    # -- trip count recovery --------------------------------------------------

    def _trip_count(self, cond_name: str) -> float:
        """jax scans: condition is `compare(iv, constant(N)), LT`."""
        lines = self.computations.get(cond_name, [])
        consts: Dict[str, int] = {}
        for ln in lines:
            m = re.match(r"%?([\w.\-]+)\s*=\s*\w+\[\]\s*constant\((\d+)\)",
                         ln)
            if m:
                consts[m.group(1)] = int(m.group(2))
        for ln in lines:
            if "compare(" in ln and ("direction=LT" in ln
                                     or "direction=GT" in ln):
                for cname, val in consts.items():
                    if re.search(r"%?" + re.escape(cname) + r"\b",
                                 ln.split("compare(", 1)[1]):
                        return float(val)
        if consts:
            return float(max(consts.values()))
        return 1.0

    # -- per-instruction costs -------------------------------------------------

    @staticmethod
    def _bytes_of(shape_str: str) -> int:
        return sum(_shape_bytes(d, s)
                   for d, s in _SHAPE_RE.findall(shape_str))

    @staticmethod
    def _elems_of(shape_str: str) -> int:
        return sum(_shape_elems(s)
                   for _, s in _SHAPE_RE.findall(shape_str))

    def _operand_shapes(self, ln: str, op: str,
                        tab: Dict[str, str]) -> List[str]:
        tail = ln.split(f" {op}(", 1)
        if len(tail) < 2:
            return []
        args = tail[1].split(")")[0]
        out = []
        for tok in re.findall(r"%([\w.\-]+)", args):
            if tok in tab:
                out.append(tab[tok])
        return out

    def _instr_cost(self, ln: str, comp: str
                    ) -> Tuple[CompCost, List[Tuple[str, float]]]:
        cost = CompCost()
        m = _DEF_RE.match(ln)
        if not m:
            return cost, []
        instr_name = m.group(1)
        result_shape = m.group(2)
        op = m.group(m.lastindex)
        tab = self._symtab(comp)

        result_bytes = self._bytes_of(result_shape)
        result_elems = self._elems_of(result_shape)
        operand_shapes = self._operand_shapes(ln, op, tab)
        operand_bytes = sum(self._bytes_of(s) for s in operand_shapes)

        if op in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast"):
            pass
        elif op in ("dynamic-slice", "slice", "gather"):
            # reads only the sliced/gathered elements, not the operand
            cost.bytes += 2.0 * result_bytes
        elif op == "dynamic-update-slice":
            # in-place update: traffic ~ the update operand (read+write);
            # the full-buffer result shape is aliased, not copied
            upd = (self._bytes_of(operand_shapes[1])
                   if len(operand_shapes) >= 2 else result_bytes)
            cost.bytes += 2.0 * upd
        elif op == "scatter":
            upd = (self._bytes_of(operand_shapes[-1])
                   if operand_shapes else result_bytes)
            cost.bytes += 2.0 * upd
        elif op == "fusion":
            # fusion HBM traffic != sum of operand shapes: slice-rooted
            # fusions read only slices, DUS-rooted ones alias the big
            # buffer in place. XLA's instruction names record the roots.
            ob = [self._bytes_of(s) for s in operand_shapes]
            if "dynamic-update-slice" in instr_name:
                big = max(ob) if ob else 0
                cost.bytes += 2.0 * max(sum(ob) - big, result_bytes
                                        if result_bytes < big else 0)
            elif "dynamic-slice" in instr_name or "gather" in instr_name:
                cost.bytes += 2.0 * result_bytes
            else:
                cost.bytes += result_bytes + sum(
                    min(b, result_bytes) for b in ob)
        else:
            cost.bytes += result_bytes + operand_bytes

        if op == "dot":
            mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ln)
            if mc and operand_shapes:
                dims_m = _SHAPE_RE.findall(operand_shapes[0])
                if dims_m:
                    lhs_dims = (dims_m[0][1].split(",")
                                if dims_m[0][1] else [])
                    k = 1
                    for c in [int(x) for x in mc.group(1).split(",")
                              if x]:
                        if c < len(lhs_dims):
                            k *= int(lhs_dims[c])
                    cost.flops += 2.0 * result_elems * k
        elif op == "convolution":
            if len(operand_shapes) >= 2:
                kern = self._elems_of(operand_shapes[1])
                cost.flops += 2.0 * result_elems * kern
        elif op in _ELEMENTWISE:
            cost.flops += result_elems
        elif op in ("reduce", "reduce-window"):
            if operand_shapes:
                cost.flops += self._elems_of(operand_shapes[0])

        if op in _COLLECTIVES:
            cost.coll_bytes += result_bytes
            cost.coll_counts[op] = cost.coll_counts.get(op, 0) + 1

        calls: List[Tuple[str, float]] = []
        if op == "while":
            mb = re.search(r"body=%?([\w.\-]+)", ln)
            # XLA records the inferred trip count in backend_config
            mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ln)
            mc2 = re.search(r"condition=%?([\w.\-]+)", ln)
            if mb:
                if mt:
                    trips = float(mt.group(1))
                elif mc2:
                    trips = self._trip_count(mc2.group(1))
                else:
                    trips = 1.0
                calls.append((mb.group(1), trips))
        elif op in ("fusion", "call"):
            mcalls = re.search(r"calls=%?([\w.\-]+)", ln)
            if mcalls:
                calls.append((mcalls.group(1), 1.0))
        elif op == "conditional":
            mcond = re.search(r"branch_computations=\{([^}]*)\}", ln)
            if mcond:
                for b in mcond.group(1).split(","):
                    calls.append((b.strip().lstrip("%"), 1.0))
        return cost, calls

    def computation_cost(self, name: str,
                         inside_fusion: bool = False) -> CompCost:
        key = name + ("#f" if inside_fusion else "")
        if key in self._memo:
            return self._memo[key]
        total = CompCost()
        self._memo[key] = total  # break cycles
        for ln in self.computations.get(name, []):
            cost, calls = self._instr_cost(ln, name)
            if inside_fusion:
                cost.bytes = 0.0  # fused intermediates stay on-chip
            total.add(cost)
            for callee, mult in calls:
                if callee not in self.computations:
                    continue
                callee_fused = inside_fusion or "fused" in callee
                sub = self.computation_cost(callee, callee_fused)
                total.add(sub.scaled(mult))
        return total

    def entry_cost(self) -> CompCost:
        entry = self._entry
        if entry is None or entry not in self.computations:
            for name in self.computations:
                if name.split(".")[0] == "main":
                    entry = name
                    break
            else:
                entry = next(iter(self.computations), None)
        if entry is None:
            return CompCost()
        return self.computation_cost(entry)


# ---------------------------------------------------------------------------
# Roofline report
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_counts: Dict[str, int]
    model_flops: float           # 6*N*D (train) / 2*N*D (decode), global
    memory_per_device_gb: float  # from compiled.memory_analysis()

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound time: how close the step is to the
        compute roofline for its *model* flops."""
        ideal = self.model_flops / self.chips / PEAK_FLOPS
        return ideal / self.bound_s if self.bound_s else 0.0

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "coll_counts": self.coll_counts,
            "model_flops": self.model_flops,
            "memory_per_device_gb": self.memory_per_device_gb,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, shape) -> float:
    """Analytic useful FLOPs per step: 6*N*D for training, 2*N*D per
    generated token for decode (N = active params)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def explain_hlo(hlo_text: str, top: int = 12) -> str:
    """Perf-debug view: top computations by (multiplier-weighted) bytes
    and flops, with their while-loop trip multipliers."""
    a = HloAnalyzer(hlo_text)
    entry = a._entry
    rows = []

    def walk(name: str, mult: float, depth: int, seen):
        if depth > 6 or name in seen:
            return
        for ln in a.computations.get(name, []):
            cost, calls = a._instr_cost(ln, name)
            for callee, m in calls:
                if callee in a.computations:
                    sub = a.computation_cost(
                        callee, "fused" in callee)
                    rows.append((callee, mult * m, sub.flops * mult * m,
                                 sub.bytes * mult * m))
                    walk(callee, mult * m, depth + 1, seen | {name})

    walk(entry, 1.0, 0, set())
    rows.sort(key=lambda r: -r[3])
    out = [f"{'computation':58s} {'mult':>8s} {'Tflop':>8s} {'TB':>9s}"]
    for name, mult, fl, by in rows[:top]:
        out.append(f"{name[:58]:58s} {mult:8.0f} {fl/1e12:8.2f} "
                   f"{by/1e12:9.3f}")
    return "\n".join(out)


def analyze_compiled(arch: str, shape_name: str, mesh_name: str,
                     chips: int, hlo_text: str, model_flops: float,
                     memory_analysis=None) -> Roofline:
    cost = HloAnalyzer(hlo_text).entry_cost()
    mem_gb = 0.0
    if memory_analysis is not None:
        try:
            mem_gb = (memory_analysis.temp_size_in_bytes
                      + memory_analysis.argument_size_in_bytes
                      + memory_analysis.output_size_in_bytes) / 1e9
        except AttributeError:
            pass
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_chip=cost.flops, bytes_per_chip=cost.bytes,
        coll_bytes_per_chip=cost.coll_bytes,
        coll_counts=cost.coll_counts,
        model_flops=model_flops, memory_per_device_gb=mem_gb)
