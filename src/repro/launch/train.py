"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs any assigned architecture (reduced or full config) through the full
runtime: brTPF data plane -> sharded train step -> AdamW -> async
checkpoints with failure recovery. On this CPU container use ``--smoke``
for a reduced config; full configs are exercised via the dry-run.
"""
from __future__ import annotations

import argparse
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import all_archs, get_arch, reduced_for_smoke
from repro.data.pipeline import BrTPFDataPipeline, SyntheticCorpus
from repro.launch.steps import make_train_step
from repro.models.model import build_model
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import AdamW, warmup_cosine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=sorted(all_archs().keys()))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--selection",
                    default="?d hasDomain code\n?d hasQuality q0")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduced_for_smoke(cfg)
    model = build_model(cfg)
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    corpus = SyntheticCorpus.generate(
        num_docs=300, vocab_size=cfg.vocab_size, seed=0)
    pipe = BrTPFDataPipeline(corpus, args.selection,
                             batch_size=args.batch, seq_len=args.seq)
    print(f"[data] brTPF selection: {pipe.stats.selected_docs} docs, "
          f"{pipe.stats.num_requests} requests")

    params, _ = model.init(jax.random.PRNGKey(0))
    opt = AdamW(learning_rate=warmup_cosine(args.lr, 10, args.steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))

    def batches():
        for b in pipe:
            extra = {}
            if cfg.encoder_layers:
                extra["enc_input"] = jnp.asarray(
                    np.random.default_rng(0).normal(
                        size=(args.batch, 8, cfg.d_model)),
                    jnp.float32)
            yield {**{k: jnp.asarray(v) for k, v in b.items()}, **extra}

    ckpt_dir = args.ckpt_dir or os.path.join(
        tempfile.gettempdir(), f"repro_{cfg.name}")
    trainer = Trainer(TrainerConfig(total_steps=args.steps,
                                    ckpt_dir=ckpt_dir, ckpt_every=25),
                      step_fn, params, opt_state)
    if trainer.try_resume():
        print(f"[ckpt] resumed at step {trainer.step}")
    report = trainer.train(batches())
    print(f"[done] steps={report.steps_run} restarts={report.restarts} "
          f"loss {report.losses[0]:.3f} -> {report.final_loss:.3f}")


if __name__ == "__main__":
    main()
