"""Launchers: mesh construction, step builders, dry-run, roofline."""
