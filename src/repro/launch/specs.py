"""ShapeDtypeStruct input specs for every (arch x shape) cell.

``input_specs``-style builders produce weak-type-correct, sharded
stand-ins for every model input -- no device allocation -- so the
dry-run can ``jit(step).lower(**specs).compile()`` the production
meshes. Param and optimizer-state specs come from ``jax.eval_shape``
over the init functions plus the logical-axes pytrees (axes are static
python built during tracing, captured by closure).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeSpec
from ..models.model import ModelDef
from ..sharding.rules import param_shardings, spec_for
from ..train.optimizer import AdamW, AdamWState

# [audio]/[vlm] frontend stub: precomputed frame/patch embeddings length.
ENC_FRAMES = 1024


def _sds(shape, dtype, mesh, rules, axes) -> jax.ShapeDtypeStruct:
    """Sharded stand-in with the same divisibility guard as constrain
    (e.g. global_batch=1 decode cannot shard batch over 'data')."""
    spec = spec_for(axes, rules)
    parts = list(spec) + [None] * (len(shape) - len(spec))
    fixed = []
    for dim, part in zip(shape, parts, strict=True):
        if part is not None:
            names = (part,) if isinstance(part, str) else tuple(part)
            size = 1
            for n in names:
                size *= mesh.shape[n]
            if dim % size != 0:
                part = None
        fixed.append(part)
    while fixed and fixed[-1] is None:
        fixed.pop()
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(mesh, P(*fixed)))


def _shapes_and_aux(fn, *args):
    """eval_shape a function returning (arrays_pytree, static_aux)."""
    box: Dict[str, Any] = {}

    def wrapped(*a):
        out, aux = fn(*a)
        box["aux"] = aux
        return out

    shapes = jax.eval_shape(wrapped, *args)
    return shapes, box["aux"]


def batch_specs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                rules: Dict) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    toks = _sds((b, s), jnp.int32, mesh, rules, ("batch", "seq"))
    out = {"tokens": toks, "targets": toks}
    if cfg.encoder_layers:
        out["enc_input"] = _sds((b, ENC_FRAMES, cfg.d_model), jnp.float32,
                                mesh, rules, ("batch", None, "act_embed"))
    return out


def param_specs(model: ModelDef, mesh: Mesh, rules: Dict):
    """(sharded param ShapeDtypeStructs, axes pytree)."""
    shapes, axes = _shapes_and_aux(model.init, jax.random.PRNGKey(0))
    shardings = param_shardings(axes, mesh, rules, shapes)
    specs = jax.tree.map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                             sharding=sh),
        shapes, shardings)
    return specs, axes


def zero_extend_axes(axes_tree):
    """Replace each leaf\'s first replicated ('embed'/None) dim with the
    'zero' logical axis (ZeRO optimizer-state sharding over data)."""
    def leaf(axes):
        if not isinstance(axes, tuple):
            return axes
        ax = list(axes)
        for i, a in enumerate(ax):
            if a is None or a == "embed":
                ax[i] = "zero"
                return tuple(ax)
        return axes

    return jax.tree.map(
        leaf, axes_tree,
        is_leaf=lambda a: a is None or isinstance(a, tuple))


def opt_state_specs(param_spec_tree, mesh: Mesh, axes_tree=None,
                    rules=None) -> AdamWState:
    """AdamW state mirrors params (fp32 moments). With ``axes_tree`` +
    ``rules`` the moments are additionally ZeRO-sharded over data; the
    step counter is replicated."""
    if axes_tree is not None and rules is not None:
        shardings = param_shardings(zero_extend_axes(axes_tree), mesh,
                                    rules, param_spec_tree)
    else:
        shardings = jax.tree.map(lambda sds: sds.sharding,
                                 param_spec_tree)

    def moment(sds, sh):
        return jax.ShapeDtypeStruct(sds.shape, jnp.float32, sharding=sh)

    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32,
                                  sharding=NamedSharding(mesh, P())),
        mu=jax.tree.map(moment, param_spec_tree, shardings),
        nu=jax.tree.map(moment, param_spec_tree, shardings))


def cache_specs(model: ModelDef, shape: ShapeSpec, mesh: Mesh,
                rules: Dict):
    """Decode-cache ShapeDtypeStructs (KV cache of seq_len per brief)."""
    shapes, axes = _shapes_and_aux(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    shardings = param_shardings(axes, mesh, rules, shapes)
    return jax.tree.map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                             sharding=sh),
        shapes, shardings)


def serve_input_specs(model: ModelDef, shape: ShapeSpec, mesh: Mesh,
                      rules: Dict) -> Tuple:
    """(cache, token, pos[, enc_out]) specs for serve_step."""
    cfg = model.cfg
    b = shape.global_batch
    cache = cache_specs(model, shape, mesh, rules)
    token = _sds((b, 1), jnp.int32, mesh, rules, ("batch", None))
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    if cfg.encoder_layers:
        # enc_out is the encoder's output: model compute dtype
        enc_out = _sds((b, ENC_FRAMES, cfg.d_model), model.dtype, mesh,
                       rules, ("batch", None, "act_embed"))
        return cache, token, pos, enc_out
    return cache, token, pos


def prefill_input_specs(model: ModelDef, shape: ShapeSpec, mesh: Mesh,
                        rules: Dict) -> Tuple:
    cfg = model.cfg
    b, s = shape.global_batch, shape.seq_len
    tokens = _sds((b, s), jnp.int32, mesh, rules, ("batch", "seq"))
    if cfg.encoder_layers:
        enc_input = _sds((b, ENC_FRAMES, cfg.d_model), jnp.float32, mesh,
                         rules, ("batch", None, "act_embed"))
        return tokens, enc_input
    return (tokens,)
