"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state -- the dry-run sets XLA_FLAGS before any jax
initialization and only then calls ``make_production_mesh``.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16).
    Multi-pod: 2 pods x 256 chips as (pod=2, data=16, model=16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a 1-D 'data' mesh (tests/examples)."""
    import numpy as np
    devs = np.array(jax.devices())
    return jax.sharding.Mesh(devs, ("data",))
