"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape x mesh) cell this lowers and
compiles the real step function (train_step for training shapes,
prefill/serve steps for inference shapes) against ShapeDtypeStruct
inputs -- no allocation -- on the production meshes:

  single-pod: (data=16, model=16)            = 256 chips
  multi-pod:  (pod=2, data=16, model=16)     = 512 chips

and records memory_analysis / cost_analysis / roofline terms as JSON
artifacts under ``artifacts/dryrun/``.
"""
# The VERY FIRST lines, before ANY other import (jax locks the device
# count on first init):
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.compat import cost_analysis
from repro.configs.base import (ALL_SHAPES, all_archs, get_arch,
                                shapes_for, skipped_shapes_for)
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (batch_specs, opt_state_specs, param_specs,
                                prefill_input_specs, serve_input_specs)
from repro.launch.steps import (make_prefill_step, make_serve_step,
                                make_train_step)
from repro.models.model import build_model
from repro.sharding.rules import default_rules, use_rules
from repro.train.optimizer import AdamW, constant_lr

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def lower_cell(arch_name: str, shape_name: str, multi_pod: bool,
               step_override: str = "", save_hlo: bool = False,
               mini: bool = False, rules_override=None):
    """Lower + compile one (arch, shape, mesh) cell; returns the record.

    ``mini``: reduced config on a (2,2[,2]) mesh with scaled shapes --
    the CI-runnable version of the same code path."""
    import dataclasses as _dc
    from repro.configs.base import reduced_for_smoke

    cfg = get_arch(arch_name)
    shape = {s.name: s for s in ALL_SHAPES}[shape_name]
    if mini:
        cfg = _dc.replace(reduced_for_smoke(cfg), name=cfg.name)
        shape = _dc.replace(shape, seq_len=256,
                            global_batch=8 if shape.global_batch > 1
                            else 1)
        shp = (2, 2, 2) if multi_pod else (2, 2)
        axes = (("pod", "data", "model") if multi_pod
                else ("data", "model"))
        mesh = jax.make_mesh(shp, axes)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = ("pod2x16x16" if multi_pod else "pod16x16")
    if mini:
        mesh_name = "mini" + ("2x2x2" if multi_pod else "2x2")
    chips = mesh.size
    rules = rules_override or default_rules(multi_pod=multi_pod)
    rules.update(dict(cfg.sharding_overrides))
    model = build_model(cfg, dtype=jnp.bfloat16)

    t0 = time.time()
    with use_rules(mesh, rules):
        p_specs, p_axes = param_specs(model, mesh, rules)
        kind = step_override or shape.kind
        if kind == "train":
            optimizer = AdamW(learning_rate=constant_lr(1e-4))
            # microbatching: keep per-microbatch local batch ~2-8 rows
            # so activations fit 16 GB HBM (EXPERIMENTS.md SPerf)
            data_shards = mesh.shape.get("data", 1) * mesh.shape.get(
                "pod", 1)
            local_b = max(shape.global_batch // data_shards, 1)
            target = 1 if cfg.d_model >= 8192 else (
                2 if cfg.d_model >= 4096 else 4)
            grad_accum = max(1, local_b // target)
            while shape.global_batch % (grad_accum) != 0:
                grad_accum //= 2
            step = make_train_step(model, optimizer,
                                   grad_accum=grad_accum,
                                   grad_axes=p_axes)
            o_specs = opt_state_specs(p_specs, mesh, p_axes, rules)
            b_specs = batch_specs(cfg, shape, mesh, rules)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                p_specs, o_specs, b_specs)
        elif kind == "prefill":
            step = make_prefill_step(model, max_seq=shape.seq_len)
            ins = prefill_input_specs(model, shape, mesh, rules)
            lowered = jax.jit(step).lower(p_specs, *ins)
        else:  # decode
            step = make_serve_step(model)
            ins = serve_input_specs(model, shape, mesh, rules)
            lowered = jax.jit(step, donate_argnums=(1,)).lower(
                p_specs, *ins)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = cost_analysis(compiled)
    hlo_text = compiled.as_text()

    rl = RL.analyze_compiled(
        arch_name, shape_name, mesh_name, chips, hlo_text,
        RL.model_flops_for(cfg, shape), memory_analysis=mem)

    record = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "kind": kind, "chips": chips,
        "grad_accum": locals().get("grad_accum", 1),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": {
            "argument_size_gb": mem.argument_size_in_bytes / 1e9,
            "output_size_gb": mem.output_size_in_bytes / 1e9,
            "temp_size_gb": mem.temp_size_in_bytes / 1e9,
            "generated_code_size_mb":
                mem.generated_code_size_in_bytes / 1e6,
        },
        "cost_analysis": {
            "flops_raw": cost.get("flops", 0.0),
            "bytes_accessed_raw": cost.get("bytes accessed", 0.0),
        },
        "roofline": rl.to_dict(),
        "hlo_bytes": len(hlo_text),
    }
    if save_hlo:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        with open(os.path.join(
                ARTIFACT_DIR,
                f"{arch_name}__{shape_name}__{mesh_name}.hlo.txt"),
                "w") as f:
            f.write(hlo_text)
    return record


def cell_list(multi_pod: bool):
    cells = []
    for name, cfg in sorted(all_archs().items()):
        for shape in shapes_for(cfg):
            cells.append((name, shape.name))
    return cells


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--mini", action="store_true",
                    help="reduced configs on a tiny mesh (CI)")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default=os.path.join("artifacts", "dryrun"))
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells = cell_list(False)
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]

    failures = 0
    for multi_pod in meshes:
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
        if args.mini:
            mesh_name = "mini" + ("2x2x2" if multi_pod else "2x2")
        for arch_name, shape_name in cells:
            out_path = os.path.join(
                args.out, f"{arch_name}__{shape_name}__{mesh_name}.json")
            if os.path.exists(out_path):
                print(f"[skip] {arch_name} x {shape_name} x {mesh_name}"
                      " (artifact exists)", flush=True)
                continue
            print(f"[dryrun] {arch_name} x {shape_name} x {mesh_name}",
                  flush=True)
            try:
                rec = lower_cell(arch_name, shape_name, multi_pod,
                                 save_hlo=args.save_hlo, mini=args.mini)
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
                r = rec["roofline"]
                print(f"  ok: compile={rec['compile_s']}s "
                      f"mem(temp)={rec['memory_analysis']['temp_size_gb']:.2f}GB "
                      f"compute={r['compute_s']:.4f}s "
                      f"memory={r['memory_s']:.4f}s "
                      f"coll={r['collective_s']:.4f}s "
                      f"dominant={r['dominant']}", flush=True)
            except Exception:
                failures += 1
                print(f"  FAILED:\n{traceback.format_exc()}", flush=True)
    # record the per-brief skips
    skips = []
    for name, cfg in sorted(all_archs().items()):
        for shape, reason in skipped_shapes_for(cfg):
            skips.append({"arch": name, "shape": shape.name,
                          "reason": reason})
    with open(os.path.join(args.out, "skips.json"), "w") as f:
        json.dump(skips, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
