"""Serving launcher: ``python -m repro.launch.serve --arch <id> --smoke``

Loads (or initializes) a model, then serves batched generation requests
through the KV-cache engine -- prefill + greedy decode, the same step
functions the dry-run lowers on the production meshes.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import all_archs, get_arch, reduced_for_smoke
from repro.models.model import build_model
from repro.serving.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=sorted(all_archs().keys()))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--max-seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduced_for_smoke(cfg)
    if cfg.encoder_layers:
        raise SystemExit("enc-dec serving demo: use examples/ instead")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_batch=args.batch,
                           max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=rng.integers(4, args.prompt_len + 1))
               .astype(np.int32) for _ in range(args.batch)]
    t0 = time.perf_counter()
    results = engine.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.perf_counter() - t0
    total_new = sum(r.steps for r in results[:1]) * len(results)
    print(f"[serve] {cfg.name}: batch={args.batch} "
          f"prompt<= {args.prompt_len} new={args.new_tokens}")
    print(f"[serve] {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s on CPU)")
    for i, r in enumerate(results):
        print(f"  req{i}: prompt_len={r.prompt_len} "
              f"generated={r.tokens[:8].tolist()}...")


if __name__ == "__main__":
    main()
