"""Dry-run + roofline for the brTPF engine itself (§Perf (D)).

Lowers the distributed bind-join request step on the production mesh
with a ~1B-triple sharded store (ShapeDtypeStruct only -- no data):

* ``baseline``  -- the paper-faithful path: every shard streams its whole
  partition through the bind-join kernel; full (capacity, 3) pages are
  all-gathered back.
* ``windowed``  -- beyond-paper: shard-local sorted-range window scan +
  unbound-column projection of the response.

Writes ``artifacts/dryrun/engine__{variant}.json`` with the same
roofline record as the model cells.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.compat import enable_x64
from repro.core.federation import FederatedStore
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from jax.sharding import NamedSharding, PartitionSpec as P

TOTAL_TRIPLES = 1 << 30          # ~1.07B global
MAX_MPR = 64
CAPACITY = 4096
WINDOW = 1 << 17                 # 131,072-row shard window


def specs(mesh, shard_n):
    n = shard_n * mesh.shape["data"]
    sh = lambda spec: NamedSharding(mesh, spec)
    return dict(
        triples=jax.ShapeDtypeStruct((n, 3), jnp.int32,
                                     sharding=sh(P("data", None))),
        valid=jax.ShapeDtypeStruct((n,), jnp.bool_,
                                   sharding=sh(P("data"))),
        keys=jax.ShapeDtypeStruct((n,), jnp.int64,
                                  sharding=sh(P("data"))),
        pats=jax.ShapeDtypeStruct((MAX_MPR, 3), jnp.int32,
                                  sharding=sh(P())),
        pat_valid=jax.ShapeDtypeStruct((MAX_MPR,), jnp.int32,
                                       sharding=sh(P())),
        base_vec=jax.ShapeDtypeStruct((8,), jnp.int32, sharding=sh(P())),
        lo=jax.ShapeDtypeStruct((), jnp.int64, sharding=sh(P())),
        hi=jax.ShapeDtypeStruct((), jnp.int64, sharding=sh(P())),
        page=jax.ShapeDtypeStruct((), jnp.int32, sharding=sh(P())),
    )


def lower_variant(variant: str, out_dir: str):
    mesh = make_production_mesh()
    shard_n = TOTAL_TRIPLES // mesh.shape["data"] // mesh.shape["model"] \
        * mesh.shape["model"]
    # store sharded over 'data' only (one federation member per data row)
    shard_n = TOTAL_TRIPLES // mesh.shape["data"]
    fed = FederatedStore(mesh=mesh, axis="data", triples=None,
                         valid=None, keys=None, shard_n=shard_n)
    sp = specs(mesh, shard_n)

    t0 = time.time()
    with enable_x64(True):
        if variant == "baseline":
            fn = fed.lowerable(CAPACITY)
            lowered = fn.lower(sp["triples"], sp["valid"], sp["pats"],
                               sp["pat_valid"], sp["base_vec"])
        else:
            fn = fed.lowerable_windowed(CAPACITY, WINDOW,
                                        wild_cols=(1, 2))
            lowered = fn.lower(sp["triples"], sp["valid"], sp["keys"],
                               sp["pats"], sp["pat_valid"],
                               sp["base_vec"], sp["lo"], sp["hi"],
                               sp["page"])
        compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    rl = RL.analyze_compiled("brtpf-engine", variant, "pod16x16",
                             mesh.size, hlo, model_flops=0.0,
                             memory_analysis=mem)
    rec = {
        "arch": "brtpf-engine", "shape": variant, "mesh": "pod16x16",
        "chips": mesh.size, "compile_s": round(t_compile, 2),
        "total_triples": TOTAL_TRIPLES, "max_mpr": MAX_MPR,
        "capacity": CAPACITY, "window": WINDOW,
        "memory_analysis": {
            "argument_size_gb": mem.argument_size_in_bytes / 1e9,
            "temp_size_gb": mem.temp_size_in_bytes / 1e9,
        },
        "roofline": rl.to_dict(),
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"engine__{variant}.json"),
              "w") as f:
        json.dump(rec, f, indent=1)
    r = rec["roofline"]
    print(f"[engine:{variant}] compile={t_compile:.1f}s "
          f"compute={r['compute_s']:.5f}s memory={r['memory_s']:.5f}s "
          f"coll={r['collective_s']:.6f}s dominant={r['dominant']}",
          flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join("artifacts", "dryrun"))
    ap.add_argument("--variant", default="",
                    choices=["", "baseline", "windowed"])
    args = ap.parse_args()
    variants = [args.variant] if args.variant else ["baseline",
                                                    "windowed"]
    for v in variants:
        lower_variant(v, args.out)


if __name__ == "__main__":
    main()
