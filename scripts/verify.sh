#!/usr/bin/env bash
# Tier-1 verification: the one command that must stay green.
#
# Usage:
#   scripts/verify.sh          # full tier-1 suite (ROADMAP.md command)
#   scripts/verify.sh --fast   # tier1-marked tests only (quick gate)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# repro-lint first (docs/analysis.md): the static pass is cheap and
# catches invariant violations before the suite spends minutes on jax.
python -m repro.analysis

if [[ "${1:-}" == "--fast" ]]; then
    exec python -m pytest -x -q -m tier1
fi
exec python -m pytest -x -q
