"""Kernel selector backend vs the numpy selector oracle.

The contract is *byte identity*: the Pallas bind-join selector path must
produce exactly the data-triple sequence (values AND order) and cnt
estimate of ``selectors.brtpf_select_with_cnt``, for every pattern/omega
shape, so that paging through ``BrTPFServer.handle`` is bit-for-bit
independent of the selector backend.
"""
import numpy as np
import pytest

from repro.core import (BrTPFServer, Request, ServerConfig, TriplePattern,
                        TripleStore, UNBOUND, brtpf_select_with_cnt,
                        encode_var)
from repro.core.kernel_selectors import KernelSelector

V = encode_var

pytestmark = pytest.mark.tier1


def make_store(seed=0, n=500, terms=15):
    rng = np.random.default_rng(seed)
    return TripleStore(np.unique(
        rng.integers(0, terms, size=(n, 3)).astype(np.int32), axis=0))


def rand_omega(rng, m, v=2, terms=15, unbound_frac=0.3):
    om = rng.integers(0, terms, size=(m, v)).astype(np.int32)
    om[rng.random((m, v)) < unbound_frac] = UNBOUND
    return om


def assert_identical(store, tp, omega):
    got, gcnt = KernelSelector(store).select_with_cnt(tp, omega)
    want, wcnt = brtpf_select_with_cnt(store, tp, omega)
    assert got.dtype == want.dtype
    assert got.shape == want.shape
    np.testing.assert_array_equal(got, want)
    assert gcnt == wcnt


class TestSelectorParity:
    def test_empty_omega_is_tpf_selector(self):
        assert_identical(make_store(), TriplePattern(V(0), 3, V(1)), None)
        assert_identical(make_store(), TriplePattern(V(0), 3, V(1)),
                         np.empty((0, 2), np.int32))

    def test_full_wildcard_pattern(self):
        rng = np.random.default_rng(1)
        assert_identical(make_store(1), TriplePattern(V(0), V(1), V(2)),
                         rand_omega(rng, 6, v=3))

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_typical_patterns(self, seed):
        rng = np.random.default_rng(seed)
        store = make_store(seed)
        for tp in [TriplePattern(V(0), 3, V(1)),
                   TriplePattern(5, V(0), V(1)),
                   TriplePattern(V(0), V(1), 7),
                   TriplePattern(5, 3, V(0))]:
            assert_identical(store, tp, rand_omega(rng, 6))

    def test_repeated_variable_patterns(self):
        rng = np.random.default_rng(4)
        store = make_store(4)
        assert_identical(store, TriplePattern(V(0), 2, V(0)),
                         rand_omega(rng, 5, v=1))
        assert_identical(store, TriplePattern(V(0), V(0), V(1)),
                         rand_omega(rng, 5))
        assert_identical(store, TriplePattern(V(0), V(0), V(0)),
                         rand_omega(rng, 5, v=1))

    def test_single_mapping_changes_stream_index(self):
        # One instantiated pattern whose chosen index differs from the
        # base pattern's: the stream order is the instantiation's index
        # order, which the kernel epilogue must reproduce.
        store = make_store(5)
        om = np.array([[5, UNBOUND]], np.int32)
        assert_identical(store, TriplePattern(V(0), 3, V(1)), om)

    def test_max_mpr_sized_omega(self):
        rng = np.random.default_rng(6)
        assert_identical(make_store(6, n=800),
                         TriplePattern(V(0), 3, V(1)), rand_omega(rng, 30))

    def test_no_matches_and_empty_store(self):
        rng = np.random.default_rng(7)
        assert_identical(make_store(7), TriplePattern(V(0), 14, 9999),
                         rand_omega(rng, 6))
        empty = TripleStore(np.empty((0, 3), np.int32))
        assert_identical(empty, TriplePattern(V(0), 3, V(1)),
                         rand_omega(rng, 6))

    def test_duplicate_mappings_dedup(self):
        store = make_store(8)
        om = np.array([[2, UNBOUND], [2, UNBOUND], [UNBOUND, 4],
                       [2, UNBOUND]], np.int32)
        assert_identical(store, TriplePattern(V(0), 3, V(1)), om)

    def test_cnt_counts_cross_stream_duplicates(self):
        # cnt sums per-stream sizes (Definition 2 over-count), while the
        # data sequence dedups -- both must match the oracle exactly.
        store = TripleStore(np.array(
            [[1, 2, 3], [1, 2, 4], [5, 2, 3]], np.int32))
        om = np.array([[1, UNBOUND], [UNBOUND, 3]], np.int32)
        tp = TriplePattern(V(0), 2, V(1))
        got, cnt = KernelSelector(store).select_with_cnt(tp, om)
        want, wcnt = brtpf_select_with_cnt(store, tp, om)
        np.testing.assert_array_equal(got, want)
        assert cnt == wcnt
        assert cnt > got.shape[0]  # (1,2,3) is in both streams


class TestBatchedSelector:
    def test_batch_matches_solo(self):
        rng = np.random.default_rng(9)
        store = make_store(9, n=700)
        tp = TriplePattern(V(0), 3, V(1))
        omegas = [None, rand_omega(rng, 6), rand_omega(rng, 30),
                  np.array([[5, UNBOUND]], np.int32)]
        sel = KernelSelector(store)
        results = sel.select_same_pattern(tp, omegas)
        assert len(sel.launches) == 1
        assert sel.launches[0].groups == len(omegas)
        for (data, cnt), om in zip(results, omegas, strict=True):
            want, wcnt = brtpf_select_with_cnt(store, tp, om)
            np.testing.assert_array_equal(data, want)
            assert cnt == wcnt


class TestServerBackendParity:
    def _servers(self, seed=10):
        store = make_store(seed, n=900)
        return (BrTPFServer(store, page_size=20,
                            selector_backend="numpy"),
                BrTPFServer(store, page_size=20,
                            selector_backend="kernel"))

    def test_paging_determinism_across_backends(self):
        rng = np.random.default_rng(11)
        s_np, s_k = self._servers()
        tp = TriplePattern(V(0), 3, V(1))
        om = rand_omega(rng, 8)
        om[0] = UNBOUND  # one unrestricted mapping -> full-match stream
        # (multi-page fragment, exercising paging determinism)
        page = 0
        while True:
            f_np = s_np.handle(Request(tp, om, page))
            f_k = s_k.handle(Request(tp, om, page))
            np.testing.assert_array_equal(f_np.data, f_k.data)
            assert f_np.cnt == f_k.cnt
            assert f_np.has_next == f_k.has_next
            assert f_np.triples_received == f_k.triples_received
            if not f_np.has_next:
                break
            page += 1
        assert page >= 1  # the fragment actually paged

    def test_tpf_requests_match_too(self):
        s_np, s_k = self._servers(12)
        tp = TriplePattern(V(0), 3, V(1))
        f_np = s_np.handle(Request(tp, None, 0))
        f_k = s_k.handle(Request(tp, None, 0))
        np.testing.assert_array_equal(f_np.data, f_k.data)
        assert f_np.cnt == f_k.cnt

    def test_handle_batch_parity_and_coalescing(self):
        rng = np.random.default_rng(13)
        store = make_store(13, n=900)
        tp_a = TriplePattern(V(0), 3, V(1))
        tp_b = TriplePattern(V(0), 5, V(1))
        reqs = [Request(tp_a, rand_omega(rng, 6), 0),
                Request(tp_a, rand_omega(rng, 6), 0),
                Request(tp_b, rand_omega(rng, 6), 0),
                Request(tp_a, None, 0)]

        solo = BrTPFServer(store, selector_backend="kernel")
        want = [solo.handle(r) for r in reqs]

        batched = BrTPFServer(store, selector_backend="kernel")
        got = batched.handle_batch(reqs)
        for f_w, f_g in zip(want, got, strict=True):
            np.testing.assert_array_equal(f_w.data, f_g.data)
            assert f_w.cnt == f_g.cnt
            assert f_w.has_next == f_g.has_next

        # cross-pattern fusion (docs/fusion.md): the tp_a group and the
        # tp_b solo segment share ONE fused launch vs 4 unbatched
        assert batched.counters.kernel_launches == 1
        assert batched.counters.fused_launches == 1
        assert batched.counters.fused_segments == 2
        assert solo.counters.kernel_launches == 4
        # every member rode the fused launch, tp_b solo included
        assert batched.counters.kernel_batched_requests == 4

        # with fusion off, handle_batch still coalesces same-pattern
        # requests: one grouped launch per pattern (the PR 1 contract)
        unfused = BrTPFServer(
            store, ServerConfig(selector_backend="kernel",
                                fuse_patterns=False))
        got_unfused = unfused.handle_batch(reqs)
        for f_w, f_g in zip(want, got_unfused, strict=True):
            np.testing.assert_array_equal(f_w.data, f_g.data)
        assert unfused.counters.kernel_launches == 2
        assert unfused.counters.fused_launches == 0
        assert unfused.counters.kernel_batched_requests == 3
        # identical transfer/request accounting either way
        assert (batched.counters.num_requests
                == solo.counters.num_requests)
        assert (batched.counters.data_received
                == solo.counters.data_received)
        assert (batched.counters.server_lookups
                == solo.counters.server_lookups)

    def test_handle_batch_numpy_backend_falls_through(self):
        rng = np.random.default_rng(14)
        store = make_store(14)
        server = BrTPFServer(store, selector_backend="numpy")
        tp = TriplePattern(V(0), 3, V(1))
        reqs = [Request(tp, rand_omega(rng, 4), 0),
                Request(tp, rand_omega(rng, 4), 0)]
        frags = server.handle_batch(reqs)
        for r, f in zip(reqs, frags, strict=True):
            want, wcnt = brtpf_select_with_cnt(store, tp, r.omega)
            np.testing.assert_array_equal(
                f.data, want[:server.page_size])
            assert f.cnt == wcnt
        assert server.counters.kernel_launches == 0
