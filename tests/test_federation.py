"""Distributed (shard_map) brTPF vs the host selector oracle."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import (TriplePattern, TripleStore, brtpf_select,
                        encode_var)
from repro.core.federation import FederatedStore

V = encode_var


def single_device_mesh():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_federated_matches_host_selector(seed):
    rng = np.random.default_rng(seed)
    triples = np.unique(
        rng.integers(0, 15, size=(400, 3)).astype(np.int32), axis=0)
    store = TripleStore(triples)
    mesh = single_device_mesh()
    fed = FederatedStore.build(store.triples, mesh)

    tp = TriplePattern(V(0), 3, V(1))
    omega = rng.integers(0, 15, size=(6, 2)).astype(np.int32)
    omega[rng.random((6, 2)) < 0.3] = -1

    got = fed.execute(tp, omega, max_mpr=16, capacity=512)
    want = brtpf_select(store, tp, omega)
    assert (set(map(tuple, got.tolist()))
            == set(map(tuple, want.tolist())))


def test_federated_repeated_variable():
    triples = np.array([[1, 2, 1], [1, 2, 3], [4, 2, 4], [5, 2, 6]],
                       np.int32)
    store = TripleStore(triples)
    fed = FederatedStore.build(store.triples, single_device_mesh())
    tp = TriplePattern(V(0), 2, V(0))  # s == o
    got = fed.execute(tp, None, max_mpr=8, capacity=64)
    want = brtpf_select(store, tp, None)
    assert (set(map(tuple, got.tolist()))
            == set(map(tuple, want.tolist())))
    assert set(map(tuple, got.tolist())) == {(1, 2, 1), (4, 2, 4)}


def test_federated_tpf_fallback_empty_omega():
    rng = np.random.default_rng(7)
    triples = np.unique(
        rng.integers(0, 10, size=(200, 3)).astype(np.int32), axis=0)
    store = TripleStore(triples)
    fed = FederatedStore.build(store.triples, single_device_mesh())
    tp = TriplePattern(V(0), 4, V(1))
    got = fed.execute(tp, None, max_mpr=4, capacity=256)
    want = store.match(tp)
    assert (set(map(tuple, got.tolist()))
            == set(map(tuple, want.tolist())))


@pytest.mark.parametrize("tp_spec", [
    (5, 2, "v0"), (7, "v0", "v1"), ("v0", 3, "v1"),
    (4, "v0", 9), ("v0", 2, "v0"), ("v0", "v1", "v2")])
def test_windowed_path_matches_host(tp_spec):
    """Windowed+projected request (the default path) is *byte-identical*
    to the host selector sequence, for every bound/unbound pattern shape
    (incl. window paging)."""
    comps = [encode_var(int(c[1:])) if isinstance(c, str) else c
             for c in tp_spec]
    tp = TriplePattern(*comps)
    rng = np.random.default_rng(5)
    triples = np.unique(
        rng.integers(0, 30, size=(3000, 3)).astype(np.int32), axis=0)
    store = TripleStore(triples)
    fed = FederatedStore.build(store.triples, single_device_mesh())
    omega = rng.integers(0, 30, size=(6, 2)).astype(np.int32)
    omega[rng.random((6, 2)) < 0.4] = -1
    got = fed.execute_windowed(tp, omega, max_mpr=16, capacity=2048,
                               window=512)
    want = brtpf_select(store, tp, omega)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Windowed-path parity satellites: vs store.match, edges, multi-page
# ---------------------------------------------------------------------------


def build_pair(seed=5, n=3000, terms=30):
    rng = np.random.default_rng(seed)
    triples = np.unique(
        rng.integers(0, terms, size=(n, 3)).astype(np.int32), axis=0)
    store = TripleStore(triples)
    fed = FederatedStore.build(store.triples, single_device_mesh())
    return store, fed, rng


def test_windowed_tpf_matches_store_match_byte_order():
    """Plain TPF (omega=None) through the windowed path == store.match
    exactly -- values AND order -- even when window << range."""
    store, fed, _ = build_pair()
    for tp in [TriplePattern(V(0), 3, V(1)),
               TriplePattern(7, V(0), V(1)),
               TriplePattern(V(0), V(1), V(2))]:
        got = fed.execute_windowed(tp, None, max_mpr=4, capacity=64,
                                   window=128)
        np.testing.assert_array_equal(got, store.match(tp))


def test_windowed_repeated_variable_multi_page():
    """Repeated-variable patterns across multiple window pages."""
    store, fed, rng = build_pair(seed=6)
    for tp in [TriplePattern(V(0), 2, V(0)),
               TriplePattern(V(0), V(0), V(1)),
               TriplePattern(V(0), V(0), V(0))]:
        got = fed.execute_windowed(tp, None, max_mpr=4, capacity=64,
                                   window=64)
        np.testing.assert_array_equal(got, store.match(tp))
        omega = rng.integers(0, 30, size=(5, 1)).astype(np.int32)
        got = fed.execute_windowed(tp, omega, max_mpr=8, capacity=64,
                                   window=64)
        want = brtpf_select(store, tp, omega)
        np.testing.assert_array_equal(got, want)


def test_windowed_omega_restricted_multi_page():
    """Omega-restricted requests where window < range length: disjoint
    page spans must neither drop nor duplicate triples."""
    store, fed, rng = build_pair(seed=7)
    tp = TriplePattern(V(0), 3, V(1))
    omega = rng.integers(0, 30, size=(10, 2)).astype(np.int32)
    omega[rng.random((10, 2)) < 0.5] = -1
    range_len = len(store.candidate_range(tp))
    window = max(range_len // 5, 1)     # force >= 5 window pages
    got = fed.execute_windowed(tp, omega, max_mpr=16, capacity=64,
                               window=window)
    want = brtpf_select(store, tp, omega)
    np.testing.assert_array_equal(got, want)


def test_windowed_fully_bound_pattern():
    """Fully-bound patterns: no unbound column exists to project, and
    the padding filter must not test a bound component (the pre-PR-3 bug
    projected column 0)."""
    store, fed, _ = build_pair(seed=8, n=500, terms=12)
    present = store.triples[3]
    tp_hit = TriplePattern(int(present[0]), int(present[1]),
                           int(present[2]))
    got = fed.execute_windowed(tp_hit, None, max_mpr=2, capacity=8,
                               window=32)
    np.testing.assert_array_equal(got, present.reshape(1, 3))
    tp_miss = TriplePattern(11, 11, 11)
    got = fed.execute_windowed(tp_miss, None, max_mpr=2, capacity=8,
                               window=32)
    np.testing.assert_array_equal(got, store.match(tp_miss))


def test_windowed_empty_range():
    """A bound prefix absent from the store: empty (0, 3) result, no
    error, regardless of window size vs shard size."""
    store, fed, _ = build_pair(seed=9, n=200, terms=10)
    tp = TriplePattern(9999, V(0), V(1))
    got = fed.execute_windowed(tp, None, max_mpr=2, capacity=8,
                               window=4096)   # window > shard_n too
    assert got.shape == (0, 3)
    om = np.array([[3]], np.int32)
    got = fed.execute_windowed(TriplePattern(9999, 1, V(0)), om,
                               max_mpr=2, capacity=8, window=16)
    assert got.shape == (0, 3)
