"""Distributed (shard_map) brTPF vs the host selector oracle."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import (TriplePattern, TripleStore, brtpf_select,
                        encode_var)
from repro.core.federation import FederatedStore

V = encode_var


def single_device_mesh():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_federated_matches_host_selector(seed):
    rng = np.random.default_rng(seed)
    triples = np.unique(
        rng.integers(0, 15, size=(400, 3)).astype(np.int32), axis=0)
    store = TripleStore(triples)
    mesh = single_device_mesh()
    fed = FederatedStore.build(store.triples, mesh)

    tp = TriplePattern(V(0), 3, V(1))
    omega = rng.integers(0, 15, size=(6, 2)).astype(np.int32)
    omega[rng.random((6, 2)) < 0.3] = -1

    got = fed.execute(tp, omega, max_mpr=16, capacity=512)
    want = brtpf_select(store, tp, omega)
    assert (set(map(tuple, got.tolist()))
            == set(map(tuple, want.tolist())))


def test_federated_repeated_variable():
    triples = np.array([[1, 2, 1], [1, 2, 3], [4, 2, 4], [5, 2, 6]],
                       np.int32)
    store = TripleStore(triples)
    fed = FederatedStore.build(store.triples, single_device_mesh())
    tp = TriplePattern(V(0), 2, V(0))  # s == o
    got = fed.execute(tp, None, max_mpr=8, capacity=64)
    want = brtpf_select(store, tp, None)
    assert (set(map(tuple, got.tolist()))
            == set(map(tuple, want.tolist())))
    assert set(map(tuple, got.tolist())) == {(1, 2, 1), (4, 2, 4)}


def test_federated_tpf_fallback_empty_omega():
    rng = np.random.default_rng(7)
    triples = np.unique(
        rng.integers(0, 10, size=(200, 3)).astype(np.int32), axis=0)
    store = TripleStore(triples)
    fed = FederatedStore.build(store.triples, single_device_mesh())
    tp = TriplePattern(V(0), 4, V(1))
    got = fed.execute(tp, None, max_mpr=4, capacity=256)
    want = store.match(tp)
    assert (set(map(tuple, got.tolist()))
            == set(map(tuple, want.tolist())))


@pytest.mark.parametrize("tp_spec", [
    (5, 2, "v0"), (7, "v0", "v1"), ("v0", 3, "v1"),
    (4, "v0", 9), ("v0", 2, "v0"), ("v0", "v1", "v2")])
def test_windowed_path_matches_host(tp_spec):
    """Windowed+projected request (the default path) is *byte-identical*
    to the host selector sequence, for every bound/unbound pattern shape
    (incl. window paging)."""
    comps = [encode_var(int(c[1:])) if isinstance(c, str) else c
             for c in tp_spec]
    tp = TriplePattern(*comps)
    rng = np.random.default_rng(5)
    triples = np.unique(
        rng.integers(0, 30, size=(3000, 3)).astype(np.int32), axis=0)
    store = TripleStore(triples)
    fed = FederatedStore.build(store.triples, single_device_mesh())
    omega = rng.integers(0, 30, size=(6, 2)).astype(np.int32)
    omega[rng.random((6, 2)) < 0.4] = -1
    got = fed.execute_windowed(tp, omega, max_mpr=16, capacity=2048,
                               window=512)
    want = brtpf_select(store, tp, omega)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Windowed-path parity satellites: vs store.match, edges, multi-page
# ---------------------------------------------------------------------------


def build_pair(seed=5, n=3000, terms=30):
    rng = np.random.default_rng(seed)
    triples = np.unique(
        rng.integers(0, terms, size=(n, 3)).astype(np.int32), axis=0)
    store = TripleStore(triples)
    fed = FederatedStore.build(store.triples, single_device_mesh())
    return store, fed, rng


def test_windowed_tpf_matches_store_match_byte_order():
    """Plain TPF (omega=None) through the windowed path == store.match
    exactly -- values AND order -- even when window << range."""
    store, fed, _ = build_pair()
    for tp in [TriplePattern(V(0), 3, V(1)),
               TriplePattern(7, V(0), V(1)),
               TriplePattern(V(0), V(1), V(2))]:
        got = fed.execute_windowed(tp, None, max_mpr=4, capacity=64,
                                   window=128)
        np.testing.assert_array_equal(got, store.match(tp))


def test_windowed_repeated_variable_multi_page():
    """Repeated-variable patterns across multiple window pages."""
    store, fed, rng = build_pair(seed=6)
    for tp in [TriplePattern(V(0), 2, V(0)),
               TriplePattern(V(0), V(0), V(1)),
               TriplePattern(V(0), V(0), V(0))]:
        got = fed.execute_windowed(tp, None, max_mpr=4, capacity=64,
                                   window=64)
        np.testing.assert_array_equal(got, store.match(tp))
        omega = rng.integers(0, 30, size=(5, 1)).astype(np.int32)
        got = fed.execute_windowed(tp, omega, max_mpr=8, capacity=64,
                                   window=64)
        want = brtpf_select(store, tp, omega)
        np.testing.assert_array_equal(got, want)


def test_windowed_omega_restricted_multi_page():
    """Omega-restricted requests where window < range length: disjoint
    page spans must neither drop nor duplicate triples."""
    store, fed, rng = build_pair(seed=7)
    tp = TriplePattern(V(0), 3, V(1))
    omega = rng.integers(0, 30, size=(10, 2)).astype(np.int32)
    omega[rng.random((10, 2)) < 0.5] = -1
    range_len = len(store.candidate_range(tp))
    window = max(range_len // 5, 1)     # force >= 5 window pages
    got = fed.execute_windowed(tp, omega, max_mpr=16, capacity=64,
                               window=window)
    want = brtpf_select(store, tp, omega)
    np.testing.assert_array_equal(got, want)


def test_windowed_fully_bound_pattern():
    """Fully-bound patterns: no unbound column exists to project, and
    the padding filter must not test a bound component (the pre-PR-3 bug
    projected column 0)."""
    store, fed, _ = build_pair(seed=8, n=500, terms=12)
    present = store.triples[3]
    tp_hit = TriplePattern(int(present[0]), int(present[1]),
                           int(present[2]))
    got = fed.execute_windowed(tp_hit, None, max_mpr=2, capacity=8,
                               window=32)
    np.testing.assert_array_equal(got, present.reshape(1, 3))
    tp_miss = TriplePattern(11, 11, 11)
    got = fed.execute_windowed(tp_miss, None, max_mpr=2, capacity=8,
                               window=32)
    np.testing.assert_array_equal(got, store.match(tp_miss))


def test_windowed_empty_range():
    """A bound prefix absent from the store: empty (0, 3) result, no
    error, regardless of window size vs shard size."""
    store, fed, _ = build_pair(seed=9, n=200, terms=10)
    tp = TriplePattern(9999, V(0), V(1))
    got = fed.execute_windowed(tp, None, max_mpr=2, capacity=8,
                               window=4096)   # window > shard_n too
    assert got.shape == (0, 3)
    om = np.array([[3]], np.int32)
    got = fed.execute_windowed(TriplePattern(9999, 1, V(0)), om,
                               max_mpr=2, capacity=8, window=16)
    assert got.shape == (0, 3)


# -- host-only planning under non-uniform boundaries ------------------------
#
# plan_windows / prefix_keys touch nothing device-side: only shard_n,
# the per-order host key copies, and static helpers. A stub mesh
# (mesh.shape[axis] is all FederatedStore.shards reads) lets these
# tests pin the planner's behavior under heat-skewed (non-uniform)
# shard boundaries without forcing a multi-device platform.

import types

from repro.core.federation import ShardIndex
from repro.core.store import _ORDERS, _pack

_PAD_KEY = np.iinfo(np.int64).max


def _host_only_fed(triples, splits, order_names=("spo",)):
    """FederatedStore stub with non-uniform per-shard key counts.

    ``splits`` are boundary *positions* into the sorted key array (e.g.
    ``[40, 52]`` puts 40/12/12 keys on the 3 shards); every shard is
    padded to the widest shard's width with +inf keys, exactly like the
    placed build path does.
    """
    triples = np.asarray(triples)
    shards = len(splits) + 1
    indexes = {}
    shard_n = 0
    parts_by_order = {}
    for name in order_names:
        comp = _ORDERS[name]
        keys = np.sort(_pack(triples[:, comp[0]], triples[:, comp[1]],
                             triples[:, comp[2]]).astype(np.int64))
        parts = np.split(keys, splits)
        parts_by_order[name] = parts
        shard_n = max(shard_n, max(p.size for p in parts))
    for name, parts in parts_by_order.items():
        hk = np.full((shards, shard_n), _PAD_KEY, dtype=np.int64)
        for s, p in enumerate(parts):
            hk[s, :p.size] = p
        indexes[name] = ShardIndex(name=name, triples=None, valid=None,
                                   keys=None, host_keys=hk)
    mesh = types.SimpleNamespace(shape={"data": shards})
    return FederatedStore(mesh=mesh, axis="data", triples=None,
                          valid=None, keys=None, shard_n=shard_n,
                          indexes=indexes)


def _block_triples(n_subj=8, per_subj=8):
    s = np.repeat(np.arange(n_subj), per_subj) + 10
    p = np.tile(np.arange(per_subj), n_subj) % 4 + 1
    o = np.arange(s.size) + 500
    return np.stack([s, p, o], axis=1).astype(np.int32)


def test_prefix_keys_bracket_exactly_the_prefix():
    triples = _block_triples()
    tp = TriplePattern(12, V(0), V(1))
    lo, hi = FederatedStore.prefix_keys(tp, "spo")
    keys = np.sort(_pack(triples[:, 0], triples[:, 1],
                         triples[:, 2]).astype(np.int64))
    inside = (keys >= lo) & (keys <= hi)
    assert inside.sum() == (triples[:, 0] == 12).sum()
    np.testing.assert_array_equal(
        np.sort(keys[inside]),
        np.sort(_pack(*[triples[triples[:, 0] == 12][:, i]
                        for i in range(3)]).astype(np.int64)))
    # POS mirror: a bound-predicate pattern brackets exactly that
    # predicate's rows under the pos packing
    tp_p = TriplePattern(V(0), 3, V(1))
    lo, hi = FederatedStore.prefix_keys(tp_p, "pos")
    pos_keys = _pack(triples[:, 1], triples[:, 2],
                     triples[:, 0]).astype(np.int64)
    inside = (pos_keys >= lo) & (pos_keys <= hi)
    assert inside.sum() == (triples[:, 1] == 3).sum()


def test_plan_windows_nonuniform_shard_bounds():
    """Unpruned plan over skewed shards: shard_bounds reproduce each
    shard's searchsorted range, pages_total follows the WIDEST shard's
    range (not the mean), and row accounting sums across shards."""
    triples = _block_triples()               # 64 rows, 8 per subject
    fed = _host_only_fed(triples, splits=[40, 52])   # 40 / 12 / 12
    tp = TriplePattern(12, V(0), V(1))       # subject block 2: keys 16..23
    plan = fed.plan_windows(tp, [tp], window=4)
    assert not plan.pruned and plan.order == "spo"
    # subject 12's 8 keys all live on shard 0 under these splits
    assert plan.shard_bounds == [(16, 24), (0, 0), (0, 0)]
    assert plan.range_rows == plan.candidate_rows == 8
    assert plan.pages_total == 2             # ceil(8 / 4), widest shard
    assert plan.pages == [0, 1]

    # a subject straddling the 40-key cut: rows split 0-offset on both
    tp_b = TriplePattern(15, V(0), V(1))     # keys 40..47 -> 0 / 8 / 0
    plan_b = fed.plan_windows(tp_b, [tp_b], window=4)
    assert plan_b.shard_bounds == [(40, 40), (0, 8), (0, 0)]
    assert plan_b.range_rows == 8
    assert plan_b.pages_total == 2


def test_plan_windows_pruned_nonuniform_spans():
    """Omega-restricted plan over skewed shards: shard_spans carry the
    per-shard live sub-ranges, candidate_rows counts only rows inside
    them, and provably match-free window pages are dropped."""
    triples = _block_triples()
    fed = _host_only_fed(triples, splits=[40, 52])
    tp = TriplePattern(12, V(0), V(1))
    insts = [TriplePattern(12, 1, V(1)), TriplePattern(12, 3, V(1))]
    plan = fed.plan_windows(tp, insts, window=2)
    assert plan.pruned and plan.order == "spo"
    assert plan.shard_bounds == [(16, 24), (0, 0), (0, 0)]
    # 2 of the 4 predicates live: 8 * 2/4 rows, on shard 0 only
    assert plan.candidate_rows == 4
    assert plan.range_rows == 8
    (s0, s1, s2) = plan.shard_spans
    assert s1.shape == (0, 2) and s2.shape == (0, 2)
    assert int(sum(hi - lo for lo, hi in s0)) == 4
    # pruning drops pages: 4 pages of 2 rows cover the range, but the
    # two live predicates sit in 2 row-pairs under the spo sort
    assert plan.pages_total == 4
    assert len(plan.pages) < plan.pages_total
    # pages must cover every live span (positions relative to start=16)
    covered = set()
    for pg in plan.pages:
        covered.update(range(16 + pg * 2, 16 + (pg + 1) * 2))
    for lo, hi in s0:
        assert set(range(int(lo), int(hi))) <= covered


def test_plan_windows_window_clamped_to_shard_width():
    triples = _block_triples()
    fed = _host_only_fed(triples, splits=[40, 52])
    tp = TriplePattern(12, V(0), V(1))
    plan = fed.plan_windows(tp, [tp], window=10_000)
    assert plan.pages_total == 1             # window clamps to shard_n
    plan1 = fed.plan_windows(tp, [tp], window=0)
    assert plan1.pages_total == 8            # clamps up to 1 row/window
