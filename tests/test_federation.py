"""Distributed (shard_map) brTPF vs the host selector oracle."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import (TriplePattern, TripleStore, brtpf_select,
                        encode_var)
from repro.core.federation import FederatedStore

V = encode_var


def single_device_mesh():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_federated_matches_host_selector(seed):
    rng = np.random.default_rng(seed)
    triples = np.unique(
        rng.integers(0, 15, size=(400, 3)).astype(np.int32), axis=0)
    store = TripleStore(triples)
    mesh = single_device_mesh()
    fed = FederatedStore.build(store.triples, mesh)

    tp = TriplePattern(V(0), 3, V(1))
    omega = rng.integers(0, 15, size=(6, 2)).astype(np.int32)
    omega[rng.random((6, 2)) < 0.3] = -1

    got = fed.execute(tp, omega, max_mpr=16, capacity=512)
    want = brtpf_select(store, tp, omega)
    assert (set(map(tuple, got.tolist()))
            == set(map(tuple, want.tolist())))


def test_federated_repeated_variable():
    triples = np.array([[1, 2, 1], [1, 2, 3], [4, 2, 4], [5, 2, 6]],
                       np.int32)
    store = TripleStore(triples)
    fed = FederatedStore.build(store.triples, single_device_mesh())
    tp = TriplePattern(V(0), 2, V(0))  # s == o
    got = fed.execute(tp, None, max_mpr=8, capacity=64)
    want = brtpf_select(store, tp, None)
    assert (set(map(tuple, got.tolist()))
            == set(map(tuple, want.tolist())))
    assert set(map(tuple, got.tolist())) == {(1, 2, 1), (4, 2, 4)}


def test_federated_tpf_fallback_empty_omega():
    rng = np.random.default_rng(7)
    triples = np.unique(
        rng.integers(0, 10, size=(200, 3)).astype(np.int32), axis=0)
    store = TripleStore(triples)
    fed = FederatedStore.build(store.triples, single_device_mesh())
    tp = TriplePattern(V(0), 4, V(1))
    got = fed.execute(tp, None, max_mpr=4, capacity=256)
    want = store.match(tp)
    assert (set(map(tuple, got.tolist()))
            == set(map(tuple, want.tolist())))


@pytest.mark.parametrize("tp_spec", [
    (5, 2, "v0"), (7, "v0", "v1"), ("v0", 3, "v1"),
    (4, "v0", 9), ("v0", 2, "v0"), ("v0", "v1", "v2")])
def test_windowed_path_matches_host(tp_spec):
    """Beyond-paper windowed+projected request == host selector, for
    every bound/unbound pattern shape (incl. window paging)."""
    comps = [encode_var(int(c[1:])) if isinstance(c, str) else c
             for c in tp_spec]
    tp = TriplePattern(*comps)
    rng = np.random.default_rng(5)
    triples = np.unique(
        rng.integers(0, 30, size=(3000, 3)).astype(np.int32), axis=0)
    store = TripleStore(triples)
    fed = FederatedStore.build(store.triples, single_device_mesh())
    omega = rng.integers(0, 30, size=(6, 2)).astype(np.int32)
    omega[rng.random((6, 2)) < 0.4] = -1
    got = fed.execute_windowed(tp, omega, max_mpr=16, capacity=2048,
                               window=512)
    want = brtpf_select(store, tp, omega)
    assert (set(map(tuple, got.tolist()))
            == set(map(tuple, want.tolist())))
