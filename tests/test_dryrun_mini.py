"""Mini dry-run: the multi-pod lowering code path on an 8-device host
mesh (subprocess, so the 512-device XLA flag never leaks into other
tests)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# one dense GQA, one MoE, the hybrid, the SSM, and the enc-dec family
ARCHS = ["qwen2-1.5b", "olmoe-1b-7b", "jamba-1.5-large-398b",
         "rwkv6-7b", "seamless-m4t-medium"]


def _run(args, timeout=520):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--mini",
         "--out", "/tmp/minidry_test"] + args,
        capture_output=True, text=True, timeout=timeout, env=env,
        cwd=REPO)


@pytest.mark.parametrize("arch", ARCHS)
def test_mini_dryrun_single_pod(arch, tmp_path):
    r = _run(["--arch", arch, "--out", str(tmp_path)])
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "FAILED" not in r.stdout


def test_mini_dryrun_multi_pod(tmp_path):
    """The pod axis shards: 2x2x2 mesh over the same step functions."""
    r = _run(["--arch", "qwen2-1.5b", "--multi-pod",
              "--out", str(tmp_path)])
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "FAILED" not in r.stdout
    # artifacts written for every runnable shape
    names = os.listdir(tmp_path)
    assert any("train_4k" in n for n in names)
    assert any("decode_32k" in n for n in names)
