"""Loss-path equivalences + launcher CLI smoke tests."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, reduced_for_smoke
from repro.models.model import build_model

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestChunkedCrossEntropy:
    def test_chunked_equals_unchunked(self):
        """The sequence-chunked CE must be exactly the plain CE."""
        cfg = reduced_for_smoke(all_archs()["qwen2-1.5b"])
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        b, s = 2, 12
        toks = jax.random.randint(jax.random.PRNGKey(1), (b, s + 1), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

        model.CE_CHUNK = 4          # force 3 chunks
        loss_chunked, _ = model.loss(params, batch)
        model.CE_CHUNK = s          # single chunk
        loss_plain, _ = model.loss(params, batch)
        np.testing.assert_allclose(float(loss_chunked),
                                   float(loss_plain), rtol=1e-6)

    def test_loss_mask_respected(self):
        cfg = reduced_for_smoke(all_archs()["qwen2-1.5b"])
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        b, s = 2, 8
        toks = jax.random.randint(jax.random.PRNGKey(1), (b, s + 1), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        full, _ = model.loss(params, batch)
        # masking everything but one position changes the loss
        mask = jnp.zeros((b, s), jnp.float32).at[:, 0].set(1.0)
        masked, _ = model.loss(params, {**batch, "loss_mask": mask})
        assert float(full) != pytest.approx(float(masked))


class TestGradAccum:
    def test_accumulated_grads_match_full_batch(self):
        """make_train_step(grad_accum=k) == grad_accum=1 up to fp error
        (same global batch, identical update)."""
        from repro.launch.steps import make_train_step
        from repro.train.optimizer import AdamW, constant_lr

        cfg = reduced_for_smoke(all_archs()["qwen2-1.5b"])
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        opt = AdamW(learning_rate=constant_lr(1e-2), weight_decay=0.0)
        opt_state = opt.init(params)
        b, s = 4, 8
        toks = jax.random.randint(jax.random.PRNGKey(1), (b, s + 1), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

        p1, _, m1 = make_train_step(model, opt, grad_accum=1)(
            params, opt_state, batch)
        p2, _, m2 = make_train_step(model, opt, grad_accum=4)(
            params, opt_state, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-5)
        flat1 = jax.tree.leaves(p1)
        flat2 = jax.tree.leaves(p2)
        for a, b_ in zip(flat1, flat2, strict=True):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=2e-3, atol=2e-5)


def _run_cli(args, timeout=300):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run([sys.executable, "-m"] + args,
                          capture_output=True, text=True,
                          timeout=timeout, env=env, cwd=REPO)


class TestCLIs:
    def test_train_cli_smoke(self, tmp_path):
        r = _run_cli(["repro.launch.train", "--arch", "qwen2-1.5b",
                      "--smoke", "--steps", "6", "--batch", "2",
                      "--seq", "32", "--ckpt-dir", str(tmp_path)])
        assert r.returncode == 0, r.stderr[-2000:]
        assert "[done] steps=6" in r.stdout

    def test_serve_cli_smoke(self):
        r = _run_cli(["repro.launch.serve", "--arch", "qwen2-1.5b",
                      "--smoke", "--batch", "2", "--new-tokens", "4",
                      "--max-seq", "32"])
        assert r.returncode == 0, r.stderr[-2000:]
        assert "tok/s" in r.stdout


class TestElasticResume:
    def test_checkpoint_restores_across_mesh_shapes(self, tmp_path):
        """Elastic scaling: a checkpoint written unsharded restores onto
        an explicit mesh sharding (the re-mesh path)."""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.train import checkpoint as ckpt

        tree = {"w": jnp.arange(16.0).reshape(4, 4),
                "b": jnp.ones((4,))}
        ckpt.save(str(tmp_path), 5, tree)
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))
        sh = {"w": NamedSharding(mesh, P("data", "model")),
              "b": NamedSharding(mesh, P("model"))}
        step, restored = ckpt.restore(str(tmp_path), tree, sh)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
        assert restored["w"].sharding == sh["w"]
