"""Semantics tests for the brTPF core engine against brute-force oracles."""
import numpy as np
import pytest

from repro.core import (BrTPFClient, BrTPFServer, TPFClient,
                        TriplePattern, TripleStore, UNBOUND,
                        brtpf_select, encode_var, evaluate_bgp_reference,
                        instantiate_patterns, parse_bgp, tpf_select,
                        MaxMprExceeded, Request, TermDictionary)

pytestmark = pytest.mark.tier1


def small_graph(seed=0, n=200, terms=12):
    rng = np.random.default_rng(seed)
    return np.unique(rng.integers(0, terms, size=(n, 3)), axis=0).astype(
        np.int32)


V = encode_var  # shorthand


# ---------------------------------------------------------------------------
# Store / TPF selector
# ---------------------------------------------------------------------------

class TestStore:
    def test_match_equals_bruteforce(self):
        triples = small_graph(1)
        store = TripleStore(triples)
        patterns = [
            TriplePattern(V(0), V(1), V(2)),       # all wildcards
            TriplePattern(3, V(0), V(1)),          # bound s
            TriplePattern(V(0), 5, V(1)),          # bound p
            TriplePattern(V(0), V(1), 7),          # bound o
            TriplePattern(3, 5, V(0)),             # bound s,p
            TriplePattern(V(0), 5, 7),             # bound p,o
            TriplePattern(3, V(0), 7),             # bound s,o (scan path)
            TriplePattern(int(triples[0, 0]), int(triples[0, 1]),
                          int(triples[0, 2])),     # fully bound
            TriplePattern(V(0), 5, V(0)),          # repeated variable
            TriplePattern(V(0), V(0), V(0)),       # all same variable
        ]
        for tp in patterns:
            got = store.match(tp)
            want = np.array([t for t in triples if tp.matches_triple(t)],
                            dtype=np.int32).reshape(-1, 3)
            got_s = set(map(tuple, got.tolist()))
            want_s = set(map(tuple, want.tolist()))
            assert got_s == want_s, tp

    def test_cardinality_contract(self):
        """Definition 2: cnt = 0 iff empty; otherwise within eps (here we
        additionally verify our estimates are exact for prefix patterns)."""
        store = TripleStore(small_graph(2))
        for tp in [TriplePattern(V(0), V(1), V(2)),
                   TriplePattern(4, V(0), V(1)),
                   TriplePattern(V(0), 2, 9),
                   TriplePattern(1, V(0), 6)]:
            cnt = store.cardinality(tp)
            true = store.match(tp).shape[0]
            assert (cnt == 0) == (true == 0)
            assert cnt == true  # our backend is exact at this scale

    def test_paging_deterministic_and_complete(self):
        store = TripleStore(small_graph(3, n=500))
        tp = TriplePattern(V(0), V(1), V(2))
        total = store.match(tp)
        pages, off = [], 0
        while True:
            page, cnt = store.match_range(tp, off, 64)
            assert cnt == total.shape[0]
            if page.shape[0] == 0:
                break
            pages.append(page)
            off += 64
        assert np.array_equal(np.concatenate(pages), total)

    def test_empty_store(self):
        store = TripleStore(np.empty((0, 3), np.int32))
        assert store.match(TriplePattern(V(0), V(1), V(2))).shape == (0, 3)
        assert store.cardinality(TriplePattern(1, 2, 3)) == 0

    def test_candidate_range_is_lazy_and_windowed(self):
        """A range holds no rows until materialized; window(page, size)
        gathers only its slice and tiles the range exactly."""
        store = TripleStore(small_graph(4, n=400))
        tp = TriplePattern(V(0), 5, V(1))
        rng = store.candidate_range(tp)
        assert rng.materialized_rows == 0
        w0 = rng.window(0, 7)
        assert w0.shape[0] == min(7, len(rng))
        assert rng.materialized_rows == 0      # windows never pin rows
        pages = []
        p = 0
        while True:
            w = rng.window(p, 7)
            if w.shape[0] == 0:
                break
            pages.append(w)
            p += 1
        full = rng.triples                     # now materialized + cached
        assert rng.materialized_rows == len(rng)
        assert np.array_equal(np.concatenate(pages) if pages
                              else np.empty((0, 3), np.int32), full)
        # out-of-range page is empty, not an error
        assert rng.window(p + 3, 7).shape == (0, 3)

    def test_lazy_materialization_still_bounded_by_row_cap(self):
        """Ranges materialized AFTER their lazy insert must still be
        trimmed by the row cap (re-enforced on every memo access)."""
        store = TripleStore(small_graph(6, n=500))
        store.range_memo_max_rows = 80
        pats = [TriplePattern(V(0), V(1), o) for o in range(8)]
        for tp in pats:            # lazy inserts: nothing pinned yet
            store.candidate_range(tp)
        for tp in pats:            # memo hits materialize full blocks
            store.match(tp)
        store.candidate_range(pats[-1])   # next access re-checks bound
        live = sum(r.materialized_rows
                   for r in store._range_memo.values())
        assert live <= store.range_memo_max_rows

    def test_match_reuses_memoized_range(self):
        """cardinality's fallback scan must not re-gather a range match
        already materialized (satellite: route match via the memo)."""
        store = TripleStore(small_graph(5, n=400))
        tp = TriplePattern(V(0), 5, V(0))      # repeated var -> scan fallback
        store.match(tp)
        misses0, hits0 = store.range_memo_misses, store.range_memo_hits
        store.cardinality(tp)                  # fallback scan
        assert store.range_memo_misses == misses0
        assert store.range_memo_hits > hits0


# ---------------------------------------------------------------------------
# brTPF selector (Definition 1)
# ---------------------------------------------------------------------------

def brtpf_oracle(triples, tp, omega):
    """Literal Definition 1: matching triples t such that the mapping
    mu with mu(tp) = t is compatible with some mu' in Omega."""
    from repro.core import mapping_from_triple, compatible
    out = []
    nv = max([v for c in tp.as_tuple() if c < 0
              for v in [-c - 1]] + [omega.shape[1] - 1]) + 1
    for t in triples:
        if not tp.matches_triple(t):
            continue
        mu = mapping_from_triple(tp, t, nv)
        if mu is None:
            continue
        for row in omega:
            r = np.full((nv,), UNBOUND, np.int32)
            r[: row.shape[0]] = row
            if compatible(mu, r):
                out.append(tuple(t))
                break
    return set(out)


class TestBrTPFSelector:
    def test_selector_matches_definition(self):
        triples = small_graph(4, n=300, terms=10)
        store = TripleStore(triples)
        rng = np.random.default_rng(5)
        tp = TriplePattern(V(0), 3, V(1))
        # Omega binds ?v0 (and sometimes ?v1)
        omega = rng.integers(0, 10, size=(8, 2)).astype(np.int32)
        omega[rng.random((8, 2)) < 0.4] = UNBOUND
        got = set(map(tuple, brtpf_select(store, tp, omega).tolist()))
        assert got == brtpf_oracle(triples, tp, omega)

    def test_empty_omega_is_tpf(self):
        store = TripleStore(small_graph(6))
        tp = TriplePattern(V(0), 2, V(1))
        a = brtpf_select(store, tp, None)
        b = tpf_select(store, tp)
        assert np.array_equal(a, b)

    def test_subset_of_tpf(self):
        """brTPF fragment is always a subset of the TPF fragment."""
        store = TripleStore(small_graph(7))
        tp = TriplePattern(V(0), V(1), 4)
        omega = np.array([[2, UNBOUND], [5, 1]], dtype=np.int32)
        br = set(map(tuple, brtpf_select(store, tp, omega).tolist()))
        tpf = set(map(tuple, tpf_select(store, tp).tolist()))
        assert br <= tpf

    def test_unbound_row_recovers_tpf(self):
        """A fully-unbound mapping is compatible with everything."""
        store = TripleStore(small_graph(8))
        tp = TriplePattern(V(0), 1, V(1))
        omega = np.full((1, 2), UNBOUND, np.int32)
        assert np.array_equal(brtpf_select(store, tp, omega),
                              tpf_select(store, tp))

    def test_instantiation_dedup(self):
        """Server algorithm step 3: duplicate instantiations collapse."""
        tp = TriplePattern(V(0), 7, V(1))
        omega = np.array([[3, UNBOUND], [3, UNBOUND], [4, UNBOUND]],
                         dtype=np.int32)
        insts = instantiate_patterns(tp, omega)
        assert len(insts) == 2
        assert insts[0].s == 3 and insts[1].s == 4


# ---------------------------------------------------------------------------
# Server: paging, maxMpR, accounting
# ---------------------------------------------------------------------------

class TestServer:
    def test_max_mpr_enforced(self):
        server = BrTPFServer(TripleStore(small_graph(9)), max_mpr=10)
        omega = np.zeros((11, 2), np.int32)
        with pytest.raises(MaxMprExceeded):
            server.handle(Request(TriplePattern(V(0), 1, V(1)), omega))

    def test_paging_covers_fragment(self):
        store = TripleStore(small_graph(10, n=400))
        server = BrTPFServer(store, page_size=50)
        tp = TriplePattern(V(0), V(1), V(2))
        got, page = [], 0
        while True:
            frag = server.handle(Request(tp, None, page))
            got.append(frag.data)
            if not frag.has_next:
                break
            page += 1
        got = np.concatenate(got)
        assert np.array_equal(got, store.match(tp))
        assert server.counters.num_requests == page + 1
        assert server.counters.data_received == (
            got.shape[0] + (page + 1) * server.meta_triples_per_page)

    def test_counters_accumulate(self):
        server = BrTPFServer(TripleStore(small_graph(11)), page_size=100)
        tp = TriplePattern(V(0), V(1), V(2))
        server.handle(Request(tp, None, 0))
        c1 = server.counters.num_requests
        server.handle(Request(tp, None, 0))
        assert server.counters.num_requests == c1 + 1


# ---------------------------------------------------------------------------
# Clients vs reference BGP evaluation
# ---------------------------------------------------------------------------

def _query_corpus(dictionary):
    return [
        "?x likes ?y\n?y type food",
        "?x likes ?y\n?x lives ?c\n?y type food",
        "?x type person\n?x likes ?y\n?y likes ?z",
        "a likes ?y\n?y likes ?z",
        "?x likes apple",
        "?x likes ?y\n?z likes ?y\n?x type person",
    ]


def _social_graph(dictionary, seed=12):
    rng = np.random.default_rng(seed)
    people = [f"p{i}" for i in range(15)]
    foods = ["apple", "soup", "cake", "rice"]
    cities = ["rome", "lima"]
    lines = []
    for p in people:
        lines.append(f"{p} type person")
        for f in rng.choice(foods, size=2, replace=False):
            lines.append(f"{p} likes {f}")
        if rng.random() < 0.7:
            lines.append(f"{p} likes {rng.choice(people)}")
        lines.append(f"{p} lives {rng.choice(cities)}")
    lines.append("a likes p1")
    for f in foods:
        lines.append(f"{f} type food")
    from repro.core import store_from_ntriples
    return store_from_ntriples(lines, dictionary)


@pytest.mark.parametrize("max_mpr", [1, 3, 30])
@pytest.mark.parametrize("page_size", [7, 100])
def test_clients_match_reference(max_mpr, page_size):
    d = TermDictionary()
    store = _social_graph(d)
    server = BrTPFServer(store, page_size=page_size, max_mpr=max_mpr)
    for q in _query_corpus(d):
        bgp = parse_bgp(q, d)
        want = evaluate_bgp_reference(store.triples, bgp)
        tpf_res = TPFClient(server).execute(bgp)
        br_res = BrTPFClient(server, max_mpr=max_mpr).execute(bgp)
        assert not tpf_res.timed_out and not br_res.timed_out
        assert np.array_equal(np.unique(tpf_res.solutions, axis=0), want), q
        assert np.array_equal(np.unique(br_res.solutions, axis=0), want), q


def test_brtpf_fewer_requests_on_joins():
    """The paper's headline effect at engine level: for join queries with
    non-trivial intermediate results, brTPF issues far fewer requests."""
    d = TermDictionary()
    store = _social_graph(d, seed=3)
    server = BrTPFServer(store, page_size=100, max_mpr=30)
    bgp = parse_bgp("?x likes ?y\n?y type food", d)
    t = TPFClient(server).execute(bgp)
    b = BrTPFClient(server).execute(bgp)
    assert b.num_requests < t.num_requests
    assert b.data_received <= t.data_received


def test_request_budget_times_out():
    d = TermDictionary()
    store = _social_graph(d, seed=4)
    server = BrTPFServer(store, page_size=5)
    bgp = parse_bgp("?x likes ?y\n?y type food\n?x type person", d)
    res = TPFClient(server, request_budget=3).execute(bgp)
    assert res.timed_out
