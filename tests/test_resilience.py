"""Fault-tolerant serving (PR 10): faults, retries, breakers, deadlines.

The contract under test (docs/resilience.md): seeded fault plans are
deterministic; client retries consult the central ``is_retryable``
predicate and never change result bytes; per-replica circuit breakers
open after consecutive failures and re-admit via a half-open probe;
deadlines propagate over the wire (``Request.timeout_ms``) and expire
identically on the loopback and ASGI transports; the batching front end
sheds work whose deadline cannot be met; and every counter surfaces in
the canonical ``resilience`` metrics section.
"""
import asyncio

import numpy as np
import pytest

from repro.core import (AsyncBrTPFClient, BrTPFServer, DeadlineExceeded,
                        QueueSaturated, Request, ServerConfig,
                        TriplePattern, TripleStore, WireError, encode_var,
                        fragment_to_wire)
from repro.core.batching import AsyncBrTPFServer
from repro.core.wire import dumps
from repro.serving.faults import (FaultPlan, FaultSpec, FaultyApp,
                                  FaultyBackend, InjectedFault)
from repro.serving.http import create_app
from repro.serving.resilience import (ResilientTransport, RetryPolicy,
                                      is_retryable)
from repro.serving.router import (BREAKER_CLOSED, BREAKER_HALF_OPEN,
                                  BREAKER_OPEN, CircuitBreaker,
                                  ReplicaRouter)
from repro.serving.transport import (AsgiTransport, LoopbackTransport,
                                     TransportError)

pytestmark = pytest.mark.tier1

V = encode_var


def make_store(seed=0, n=400, terms=16):
    rng = np.random.default_rng(seed)
    return TripleStore(rng.integers(0, terms, size=(n, 3)))


def sample_requests(store, seed=3, count=10, max_mpr=30):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(count):
        s, p, o = store.triples[rng.integers(len(store.triples))]
        m = int(rng.integers(1, max_mpr + 1))
        omega = np.full((m, 1), int(s), dtype=np.int32)
        out.append(Request(pattern=TriplePattern(V(0), int(p), int(o)),
                           omega=omega, page=0))
    return out


def run(coro):
    return asyncio.run(coro)


# ---------------------------------------------------------------------------
# is_retryable: the one predicate (repro-lint RS001)
# ---------------------------------------------------------------------------


class TestIsRetryable:
    def test_transient_conditions_are_retryable(self):
        assert is_retryable(QueueSaturated("full"))
        assert is_retryable(DeadlineExceeded("late"))
        assert is_retryable(asyncio.TimeoutError())
        assert is_retryable(TransportError(503, "busy", retryable=True))
        # transient statuses retry even without the envelope flag
        for status in (408, 500, 502, 503, 504):
            assert is_retryable(TransportError(status, "x"))

    def test_permanent_conditions_are_not(self):
        assert not is_retryable(TransportError(400, "bad envelope"))
        assert not is_retryable(TransportError(414, "over maxMpR"))
        assert not is_retryable(TransportError(404, "nope"))
        assert not is_retryable(WireError("garbled"))
        assert not is_retryable(ValueError("client bug"))


# ---------------------------------------------------------------------------
# Seeded fault plans: deterministic, per-replica streams
# ---------------------------------------------------------------------------


class TestFaultPlan:
    def _decisions(self, faults, n=40):
        async def main():
            out = []
            for _ in range(n):
                try:
                    await faults.perturb()
                    out.append("ok")
                except InjectedFault as exc:
                    out.append(f"err{exc.status}")
            return out
        return run(main())

    def test_same_seed_same_stream(self):
        plan = FaultPlan(seed=7, default=FaultSpec(error_rate=0.5))
        a = self._decisions(plan.for_replica(2))
        b = self._decisions(plan.for_replica(2))
        assert a == b
        assert "err503" in a and "ok" in a

    def test_replicas_draw_distinct_streams(self):
        plan = FaultPlan(seed=7, default=FaultSpec(error_rate=0.5))
        assert (self._decisions(plan.for_replica(0))
                != self._decisions(plan.for_replica(1)))

    def test_crash_after_is_a_cliff(self):
        plan = FaultPlan(per_replica={0: FaultSpec(crash_after=3)})
        got = self._decisions(plan.for_replica(0), n=6)
        assert got == ["ok"] * 3 + ["err500"] * 3

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            FaultSpec(error_rate=1.5)
        with pytest.raises(ValueError):
            FaultSpec(delay_s=-1)
        assert FaultSpec().is_noop


# ---------------------------------------------------------------------------
# Circuit breaker state machine (injected clock: fully deterministic)
# ---------------------------------------------------------------------------


class TestCircuitBreaker:
    def _breaker(self, threshold=3, reset=10.0):
        clock = {"t": 0.0}
        cb = CircuitBreaker(failure_threshold=threshold,
                            reset_after_s=reset,
                            clock=lambda: clock["t"])
        return cb, clock

    def test_opens_after_consecutive_failures(self):
        cb, _ = self._breaker(threshold=3)
        for _ in range(2):
            cb.record_failure()
        assert cb.state == BREAKER_CLOSED and cb.allow()
        cb.record_failure()
        assert cb.state == BREAKER_OPEN
        assert not cb.allow()
        assert cb.opens == 1

    def test_success_resets_the_consecutive_count(self):
        cb, _ = self._breaker(threshold=2)
        cb.record_failure()
        cb.record_success()
        cb.record_failure()
        assert cb.state == BREAKER_CLOSED

    def test_half_open_admits_one_probe(self):
        cb, clock = self._breaker(threshold=1, reset=5.0)
        cb.record_failure()
        assert not cb.allow()
        clock["t"] = 5.1
        assert cb.allow()                      # the probe
        assert cb.state == BREAKER_HALF_OPEN
        assert not cb.allow()                  # nothing else until it lands
        cb.record_success()
        assert cb.state == BREAKER_CLOSED and cb.allow()

    def test_failed_probe_reopens(self):
        cb, clock = self._breaker(threshold=1, reset=5.0)
        cb.record_failure()
        clock["t"] = 6.0
        assert cb.allow()
        cb.record_failure()
        assert cb.state == BREAKER_OPEN
        assert cb.opens == 2

    def test_invalid_construction(self):
        with pytest.raises(ValueError):
            CircuitBreaker(failure_threshold=0)
        with pytest.raises(ValueError):
            CircuitBreaker(reset_after_s=0)


# ---------------------------------------------------------------------------
# ResilientTransport: retries, giveups, deadlines, hedging
# ---------------------------------------------------------------------------


class _Flaky:
    """Fails the first ``failures`` calls, then delegates to ``inner``
    (or returns ``payload`` when there is nothing to delegate to)."""

    max_mpr = 30

    def __init__(self, failures, exc=None, inner=None, payload="frag"):
        self.remaining = failures
        self.exc = exc or TransportError(503, "busy", retryable=True)
        self.inner = inner
        self.payload = payload
        self.calls = 0

    async def handle(self, req):
        self.calls += 1
        if self.remaining > 0:
            self.remaining -= 1
            raise self.exc
        if self.inner is not None:
            return await self.inner.handle(req)
        return self.payload

    async def metrics(self):
        return {} if self.inner is None else await self.inner.metrics()

    async def aclose(self):
        if self.inner is not None:
            await self.inner.aclose()


FAST = dict(base_backoff_s=1e-4, max_backoff_s=1e-3)


class TestResilientTransport:
    def test_retry_to_success_preserves_bytes(self):
        store = make_store()
        cfg = ServerConfig(page_size=25)
        oracle = BrTPFServer(store, config=cfg)
        reqs = sample_requests(store, count=6, max_mpr=cfg.max_mpr)
        expected = [dumps(fragment_to_wire(oracle.handle(r)))
                    for r in reqs]

        async def main():
            inner = _Flaky(4, inner=LoopbackTransport(
                AsyncBrTPFServer.from_config(store, cfg,
                                             batch_window_s=1e-3)))
            tr = ResilientTransport(inner, RetryPolicy(max_attempts=6,
                                                       **FAST))
            try:
                frags = [await tr.handle(r) for r in reqs]
            finally:
                await tr.aclose()
            return frags, tr.stats

        frags, stats = run(main())
        assert [dumps(fragment_to_wire(f)) for f in frags] == expected
        assert stats.retries == 4
        assert stats.giveups == 0

    def test_non_retryable_raises_immediately(self):
        flaky = _Flaky(10, exc=TransportError(400, "bad envelope"))
        tr = ResilientTransport(flaky, RetryPolicy(max_attempts=5, **FAST))
        with pytest.raises(TransportError):
            run(tr.handle(Request(pattern=TriplePattern(1, 2, 3))))
        assert flaky.calls == 1
        assert tr.stats.retries == 0

    def test_gives_up_after_max_attempts(self):
        flaky = _Flaky(10)
        tr = ResilientTransport(flaky, RetryPolicy(max_attempts=3, **FAST))
        with pytest.raises(TransportError):
            run(tr.handle(Request(pattern=TriplePattern(1, 2, 3))))
        assert flaky.calls == 3
        assert tr.stats.retries == 2
        assert tr.stats.giveups == 1

    def test_deadline_budget_bounds_the_retry_loop(self):
        flaky = _Flaky(10 ** 6)
        tr = ResilientTransport(flaky, RetryPolicy(
            max_attempts=10 ** 6, base_backoff_s=0.01,
            max_backoff_s=0.02, deadline_ms=60.0))
        with pytest.raises(DeadlineExceeded):
            run(tr.handle(Request(pattern=TriplePattern(1, 2, 3))))
        assert tr.stats.deadline_exceeded == 1
        assert 1 <= flaky.calls < 100

    def test_retry_after_hint_floors_the_backoff(self):
        flaky = _Flaky(1, exc=TransportError(503, "busy", retryable=True,
                                             retry_after_ms=40.0))
        tr = ResilientTransport(flaky, RetryPolicy(max_attempts=3, **FAST))

        async def main():
            loop = asyncio.get_running_loop()
            t0 = loop.time()
            await tr.handle(Request(pattern=TriplePattern(1, 2, 3)))
            return loop.time() - t0

        assert run(main()) >= 0.04

    def test_hedge_cuts_a_slow_primary(self):
        class SlowFirst:
            max_mpr = 30

            def __init__(self):
                self.calls = 0

            async def handle(self, req):
                self.calls += 1
                if self.calls == 1:
                    await asyncio.sleep(0.5)
                    return "slow"
                return "fast"

            async def metrics(self):
                return {}

            async def aclose(self):
                pass

        tr = ResilientTransport(SlowFirst(), RetryPolicy(
            hedge=True, hedge_after_s=0.01, **FAST))
        got = run(tr.handle(Request(pattern=TriplePattern(1, 2, 3))))
        assert got == "fast"
        assert tr.stats.hedges == 1
        assert tr.stats.hedge_wins == 1

    def test_metrics_overlay_resilience_section(self):
        flaky = _Flaky(2)
        tr = ResilientTransport(flaky, RetryPolicy(max_attempts=5, **FAST))

        async def main():
            await tr.handle(Request(pattern=TriplePattern(1, 2, 3)))
            return await tr.metrics()

        section = run(main())["resilience"]
        assert section["retries"] == 2
        assert section["hedges"] == 0
        assert "giveups" in section and "deadline_exceeded" in section

    def test_policy_validation(self):
        with pytest.raises(ValueError):
            RetryPolicy(max_attempts=0)
        with pytest.raises(ValueError):
            RetryPolicy(deadline_ms=0)
        with pytest.raises(ValueError):
            RetryPolicy(attempt_timeout_ms=-1)


# ---------------------------------------------------------------------------
# Deadline propagation: loopback and ASGI expire identically
# ---------------------------------------------------------------------------


class TestDeadlineParity:
    def _slow_front(self, store, cfg):
        front = AsyncBrTPFServer.from_config(store, cfg,
                                             batch_window_s=1e-3)
        faults = FaultPlan(default=FaultSpec(delay_s=0.3)).for_replica(0)
        return FaultyBackend(front, faults), faults

    @pytest.mark.parametrize("kind", ["loopback", "asgi"])
    def test_tight_deadline_expires_on_both_transports(self, kind):
        store = make_store()
        cfg = ServerConfig(page_size=25)
        front, faults = self._slow_front(store, cfg)
        if kind == "loopback":
            tr = LoopbackTransport(front)
        else:
            tr = AsgiTransport(FaultyApp(create_app(front),
                                         faults))
        req = Request(pattern=TriplePattern(V(0), 2, V(1)),
                      timeout_ms=25.0)

        async def main():
            try:
                with pytest.raises(DeadlineExceeded):
                    await tr.handle(req)
            finally:
                await tr.aclose()

        run(main())

    def test_generous_deadline_succeeds(self):
        store = make_store()
        front = AsyncBrTPFServer.from_config(store, ServerConfig(),
                                             batch_window_s=1e-3)
        tr = LoopbackTransport(front)
        req = Request(pattern=TriplePattern(V(0), 2, V(1)),
                      timeout_ms=30_000.0)

        async def main():
            try:
                return await tr.handle(req)
            finally:
                await tr.aclose()

        assert run(main()).cnt >= 0


# ---------------------------------------------------------------------------
# Deadline-aware shedding in the batching front end
# ---------------------------------------------------------------------------


class TestShedding:
    def test_expired_work_is_shed_at_flush(self):
        store = make_store()
        front = AsyncBrTPFServer.from_config(store, ServerConfig(),
                                             batch_window_s=0.05)
        req = Request(pattern=TriplePattern(V(0), 2, V(1)),
                      timeout_ms=1.0)

        async def main():
            tr = LoopbackTransport(front)
            try:
                with pytest.raises(DeadlineExceeded):
                    await front.handle(req)
                return await tr.metrics()
            finally:
                await tr.aclose()

        snap = run(main())
        assert front.stats.shed == 1
        assert snap["batch"]["shed"] == 1
        assert snap["resilience"]["shed"] == 1

    def test_already_expired_request_is_shed_at_enqueue(self):
        store = make_store()
        front = AsyncBrTPFServer.from_config(store, ServerConfig(),
                                             batch_window_s=1e-3)
        req = Request(pattern=TriplePattern(V(0), 2, V(1)),
                      timeout_ms=0.0)

        async def main():
            try:
                with pytest.raises(DeadlineExceeded):
                    await front.handle(req)
            finally:
                await front.aclose()

        run(main())
        assert front.stats.shed == 1
        assert front.stats.flushes == 0

    def test_deadline_free_requests_never_shed(self):
        store = make_store()
        front = AsyncBrTPFServer.from_config(store, ServerConfig(),
                                             batch_window_s=1e-3)
        reqs = sample_requests(store, count=8)

        async def main():
            try:
                await asyncio.gather(*[front.handle(r) for r in reqs])
            finally:
                await front.aclose()

        run(main())
        assert front.stats.shed == 0


# ---------------------------------------------------------------------------
# Health-gated failover in the replica router
# ---------------------------------------------------------------------------


class TestRouterFailover:
    def test_breaker_detours_around_a_dead_replica(self):
        store = make_store(seed=13)
        cfg = ServerConfig(page_size=30)
        oracle = BrTPFServer(store, config=cfg)
        reqs = sample_requests(store, seed=17, count=12,
                               max_mpr=cfg.max_mpr)
        expected = [dumps(fragment_to_wire(oracle.handle(r)))
                    for r in reqs]
        # replica 0 fails every request from the start; the breaker
        # must open and affinity must degrade to the next healthy one
        plan = FaultPlan(seed=3,
                         per_replica={0: FaultSpec(crash_after=0)})

        async def main():
            router = ReplicaRouter(store, cfg, replicas=3,
                                   batch_window_s=1e-3,
                                   failure_threshold=2,
                                   reset_after_s=60.0,
                                   fault_plan=plan)
            # affinity must actually prefer the dead replica for some
            # of the traffic, else there is nothing to fail over from
            assert any(router.route(r) == 0 for r in reqs)
            tr = ResilientTransport(LoopbackTransport(router),
                                    RetryPolicy(max_attempts=6, **FAST))
            try:
                frags = [await tr.handle(r) for r in reqs]
                return frags, router.metrics_snapshot()
            finally:
                await tr.aclose()

        frags, snap = run(main())
        assert [dumps(fragment_to_wire(f)) for f in frags] == expected
        breaker = snap["resilience"]["breaker"]
        assert breaker["opens"] >= 1
        assert breaker["states"][0] == BREAKER_OPEN
        assert breaker["failovers"] > 0
        assert breaker["replica_failures"] >= 2
        faults = snap["faults"]
        assert faults[0]["crashes"] >= 2

    def test_half_open_probe_readmits_a_recovered_replica(self):
        store = make_store()
        cfg = ServerConfig()

        async def main():
            router = ReplicaRouter(store, cfg, replicas=2,
                                   batch_window_s=1e-3,
                                   failure_threshold=1,
                                   reset_after_s=0.02)
            try:
                # find a request whose affinity prefers replica 0, then
                # fail its breaker by hand (the replica is healthy --
                # the probe must succeed and close it again)
                req = next(
                    r for r in sample_requests(store, seed=23, count=32)
                    if router.route(r) == 0)
                breaker = router.breakers[0]
                breaker.record_failure()
                assert not breaker.allow()
                assert breaker.state == BREAKER_OPEN
                await asyncio.sleep(0.05)   # > reset_after_s
                await router.handle(req)    # the half-open probe
                return router.metrics_snapshot()
            finally:
                await router.aclose()

        snap = run(main())
        states = snap["resilience"]["breaker"]["states"]
        assert BREAKER_OPEN not in states
        assert states[0] == BREAKER_CLOSED

    def test_router_metrics_have_resilience_section(self):
        store = make_store()

        async def main():
            router = ReplicaRouter(store, ServerConfig(), replicas=2,
                                   batch_window_s=1e-3)
            try:
                await router.handle(
                    Request(pattern=TriplePattern(V(0), 2, V(1))))
                return router.metrics_snapshot()
            finally:
                await router.aclose()

        snap = run(main())
        section = snap["resilience"]
        assert section["breaker"]["states"] == [BREAKER_CLOSED] * 2
        assert section["breaker"]["opens"] == 0
        assert "faults" not in snap  # no plan -> no faults section


# ---------------------------------------------------------------------------
# Wire-level error surface over a real ASGI edge
# ---------------------------------------------------------------------------


class TestErrorSurfaceOverAsgi:
    def test_injected_503_decodes_with_code_and_retryable(self):
        store = make_store()
        front = AsyncBrTPFServer.from_config(store, ServerConfig(),
                                             batch_window_s=1e-3)
        faults = FaultPlan(default=FaultSpec(error_rate=1.0)) \
            .for_replica(0)
        tr = AsgiTransport(FaultyApp(create_app(front), faults))

        async def main():
            try:
                with pytest.raises(TransportError) as ei:
                    await tr.handle(
                        Request(pattern=TriplePattern(V(0), 2, V(1))))
                return ei.value
            finally:
                await tr.aclose()

        exc = run(main())
        assert exc.status == 503
        assert exc.retryable
        assert exc.code == "QUEUE_SATURATED"

    def test_queue_saturation_carries_retry_after_hint(self):
        store = make_store()
        front = AsyncBrTPFServer.from_config(store, ServerConfig(),
                                             batch_window_s=0.2,
                                             queue_depth=1)
        tr = AsgiTransport(create_app(front))
        r1, r2 = sample_requests(store, count=2)

        async def main():
            first = asyncio.ensure_future(tr.handle(r1))
            await asyncio.sleep(0.02)   # let it enqueue
            try:
                with pytest.raises(TransportError) as ei:
                    await tr.handle(r2)
                await first
                return ei.value
            finally:
                await tr.aclose()

        exc = run(main())
        assert exc.status == 503
        assert exc.retryable
        assert exc.code == "QUEUE_SATURATED"
        assert exc.retry_after_ms == pytest.approx(200.0)

    def test_resilient_client_rides_out_injected_errors(self):
        """End-to-end: AsyncBrTPFClient -> ResilientTransport -> ASGI
        edge with 30% injected 503s still returns correct solutions."""
        store = make_store(seed=5)
        cfg = ServerConfig(page_size=30)
        oracle = BrTPFServer(store, config=cfg)
        from repro.core import BrTPFClient, bgp_from_arrays
        bgp = bgp_from_arrays([(V(0), 2, V(1)), (V(1), 3, V(2))])
        want = BrTPFClient(oracle).execute(bgp).solutions

        front = AsyncBrTPFServer.from_config(store, cfg,
                                             batch_window_s=1e-3)
        faults = FaultPlan(seed=7, default=FaultSpec(error_rate=0.5)) \
            .for_replica(0)
        tr = ResilientTransport(
            AsgiTransport(FaultyApp(create_app(front), faults)),
            RetryPolicy(max_attempts=12, **FAST), seed=7)

        async def main():
            try:
                client = AsyncBrTPFClient(tr)
                return (await client.execute(bgp)).solutions
            finally:
                await tr.aclose()

        got = run(main())
        assert np.array_equal(np.unique(got, axis=0),
                              np.unique(want, axis=0))
        assert tr.stats.retries > 0
