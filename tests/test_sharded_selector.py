"""Sharded windowed selector backend vs the numpy selector oracle.

The contract mirrors ``tests/test_kernel_selectors.py``: the
mesh-sharded windowed path (``selector_backend="sharded"``) must produce
exactly the data-triple sequence (values AND order) and Definition-2
``cnt`` of ``selectors.brtpf_select_with_cnt``, for every pattern/omega
shape and for batched same-pattern requests through ``handle_batch`` --
so paging through ``BrTPFServer.handle`` is bit-for-bit independent of
whether the store lives on one host or is partitioned over a mesh.

It additionally pins the tentpole's perf contract: every sharded launch
streams exactly ``window`` candidate rows per device -- bounded by the
window, never by the range, store, or shard size.
"""
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import (BrTPFServer, Request, ServerConfig, TriplePattern,
                        TripleStore, UNBOUND, brtpf_select_with_cnt,
                        encode_var)
from repro.core.federation import FederatedStore, ShardedSelector

V = encode_var

pytestmark = pytest.mark.tier1


def make_store(seed=0, n=500, terms=15):
    rng = np.random.default_rng(seed)
    return TripleStore(np.unique(
        rng.integers(0, terms, size=(n, 3)).astype(np.int32), axis=0))


def make_fed(store):
    return FederatedStore.build(
        store.triples, Mesh(np.array(jax.devices()[:1]), ("data",)))


def rand_omega(rng, m, v=2, terms=15, unbound_frac=0.3):
    om = rng.integers(0, terms, size=(m, v)).astype(np.int32)
    om[rng.random((m, v)) < unbound_frac] = UNBOUND
    return om


def assert_identical(store, fed, tp, omega, window=64):
    got, gcnt = ShardedSelector(fed, window=window).select_with_cnt(
        tp, omega)
    want, wcnt = brtpf_select_with_cnt(store, tp, omega)
    assert got.dtype == want.dtype
    assert got.shape == want.shape
    np.testing.assert_array_equal(got, want)
    assert gcnt == wcnt


class TestShardedSelectorParity:
    def test_empty_omega_is_tpf_selector(self):
        store = make_store()
        fed = make_fed(store)
        assert_identical(store, fed, TriplePattern(V(0), 3, V(1)), None)
        assert_identical(store, fed, TriplePattern(V(0), 3, V(1)),
                         np.empty((0, 2), np.int32))

    @pytest.mark.parametrize("seed", [0, 1])
    def test_typical_patterns(self, seed):
        rng = np.random.default_rng(seed)
        store = make_store(seed)
        fed = make_fed(store)
        for tp in [TriplePattern(V(0), 3, V(1)),
                   TriplePattern(5, V(0), V(1)),
                   TriplePattern(V(0), V(1), 7),
                   TriplePattern(5, 3, V(0))]:
            assert_identical(store, fed, tp, rand_omega(rng, 6))

    def test_repeated_variable_patterns(self):
        rng = np.random.default_rng(4)
        store = make_store(4)
        fed = make_fed(store)
        assert_identical(store, fed, TriplePattern(V(0), 2, V(0)),
                         rand_omega(rng, 5, v=1))
        assert_identical(store, fed, TriplePattern(V(0), V(0), V(1)),
                         rand_omega(rng, 5))

    def test_no_matches_fully_bound_and_full_wildcard(self):
        rng = np.random.default_rng(7)
        store = make_store(7)
        fed = make_fed(store)
        assert_identical(store, fed, TriplePattern(V(0), 14, 9999),
                         rand_omega(rng, 6))
        t0 = store.triples[0]
        assert_identical(store, fed,
                         TriplePattern(int(t0[0]), int(t0[1]),
                                       int(t0[2])), None)
        assert_identical(store, fed, TriplePattern(V(0), V(1), V(2)),
                         rand_omega(rng, 4, v=3))

    def test_batched_groups_share_window_launches(self):
        """G same-pattern requests ride ONE sharded launch per window
        page, each response byte-identical to its solo evaluation."""
        rng = np.random.default_rng(9)
        store = make_store(9, n=700)
        fed = make_fed(store)
        tp = TriplePattern(V(0), 3, V(1))
        omegas = [None, rand_omega(rng, 6), rand_omega(rng, 12),
                  np.array([[5, UNBOUND]], np.int32)]
        sel = ShardedSelector(fed, window=128)
        results = sel.select_same_pattern(tp, omegas)
        # launches = window pages of the shard-local range under the
        # plan's chosen order (the POS mirror: an unbound subject no
        # longer forces a whole-shard SPO scan), NOT pages * groups
        all_insts = [p for om in omegas
                     for p in ([tp] if om is None else
                               [tp.instantiate(r) for r in om])]
        plan = fed.plan_windows(tp, all_insts, 128)
        assert len(sel.launches) == len(plan.pages)
        assert len(plan.pages) < -(-fed.shard_n // 128)  # mirror win
        for rec in sel.launches:
            assert rec.groups == len(omegas)
            assert rec.cand_streamed == 128     # bounded by the window
        for (data, cnt), om in zip(results, omegas, strict=True):
            want, wcnt = brtpf_select_with_cnt(store, tp, om)
            np.testing.assert_array_equal(data, want)
            assert cnt == wcnt

    def test_launch_stream_bounded_by_window_not_range(self):
        """The tentpole claim: per-launch per-device candidate rows ==
        window, independent of how large the range/store is."""
        store = make_store(10, n=900)
        fed = make_fed(store)
        tp = TriplePattern(V(0), V(1), V(2))    # range == whole store
        for window in (64, 256):
            sel = ShardedSelector(fed, window=window)
            sel.select_with_cnt(tp, None)
            assert all(rec.cand_streamed == window
                       for rec in sel.launches)


class TestServerShardedBackendParity:
    def _servers(self, seed=10, window=128):
        store = make_store(seed, n=900)
        return (BrTPFServer(store, page_size=20,
                            selector_backend="numpy"),
                BrTPFServer(store, page_size=20,
                            selector_backend="sharded",
                            shard_window=window))

    def test_paging_determinism_across_backends(self):
        rng = np.random.default_rng(11)
        s_np, s_sh = self._servers()
        tp = TriplePattern(V(0), 3, V(1))
        om = rand_omega(rng, 8)
        om[0] = UNBOUND  # one unrestricted mapping -> full-match stream
        page = 0
        while True:
            f_np = s_np.handle(Request(tp, om, page))
            f_sh = s_sh.handle(Request(tp, om, page))
            np.testing.assert_array_equal(f_np.data, f_sh.data)
            assert f_np.cnt == f_sh.cnt
            assert f_np.has_next == f_sh.has_next
            assert f_np.triples_received == f_sh.triples_received
            if not f_np.has_next:
                break
            page += 1
        assert page >= 1  # the fragment actually paged

    def test_tpf_requests_match_too(self):
        s_np, s_sh = self._servers(12)
        tp = TriplePattern(V(0), 3, V(1))
        f_np = s_np.handle(Request(tp, None, 0))
        f_sh = s_sh.handle(Request(tp, None, 0))
        np.testing.assert_array_equal(f_np.data, f_sh.data)
        assert f_np.cnt == f_sh.cnt

    def test_handle_batch_parity_and_coalescing(self):
        """Batched same-pattern requests: responses byte-identical to
        the numpy oracle AND to sequential sharded handling, with the
        grouped geometry cutting launches."""
        rng = np.random.default_rng(13)
        store = make_store(13, n=900)
        tp_a = TriplePattern(V(0), 3, V(1))
        tp_b = TriplePattern(V(0), 5, V(1))
        reqs = [Request(tp_a, rand_omega(rng, 6), 0),
                Request(tp_a, rand_omega(rng, 6), 0),
                Request(tp_b, rand_omega(rng, 6), 0),
                Request(tp_a, None, 0)]

        oracle = BrTPFServer(store, selector_backend="numpy")
        want = [oracle.handle(r) for r in reqs]

        solo = BrTPFServer(store, selector_backend="sharded",
                           shard_window=128)
        solo_frags = [solo.handle(r) for r in reqs]

        batched = BrTPFServer(store, selector_backend="sharded",
                              shard_window=128)
        got = batched.handle_batch(reqs)
        for f_w, f_s, f_g in zip(want, solo_frags, got, strict=True):
            np.testing.assert_array_equal(f_w.data, f_g.data)
            np.testing.assert_array_equal(f_s.data, f_g.data)
            assert f_w.cnt == f_s.cnt == f_g.cnt
            assert f_w.has_next == f_g.has_next

        # the three tp_a selections shared one grouped launch sequence
        # (the plan's window pages for the union of their
        # instantiations); solo pays per request. Both patterns have an
        # unbound subject, but the POS mirror bounds every plan by the
        # p-bound range -- far below the pre-mirror whole-shard scan.
        from repro.core.selectors import instantiate_patterns
        fed = batched.federated

        def pages_for(tp, reqs_of_tp):
            insts = [p for r in reqs_of_tp
                     for p in instantiate_patterns(tp, r.omega)]
            return len(fed.plan_windows(tp, insts, 128).pages)

        solo_expect = sum(pages_for(r.pattern, [r]) for r in reqs)
        batched_expect = (pages_for(tp_a, [reqs[0], reqs[1], reqs[3]])
                          + pages_for(tp_b, [reqs[2]]))
        assert solo.counters.kernel_launches == solo_expect
        # cross-pattern fusion (docs/fusion.md): both patterns' pruned
        # unions share launches instead of paying per-pattern pages
        assert batched.counters.fused_launches >= 1
        assert batched.counters.fused_segments \
            >= 2 * batched.counters.fused_launches
        assert batched.counters.kernel_launches <= batched_expect
        assert batched.counters.kernel_launches \
            <= solo.counters.kernel_launches
        whole_shard_pages = -(-fed.shard_n // 128)
        assert solo.counters.kernel_launches < 4 * whole_shard_pages
        # every member rode a fused launch, the tp_b solo included
        assert batched.counters.kernel_batched_requests == 4

        # with fusion off, the PR 3 contract holds: one grouped window
        # sequence per pattern, exactly the plan's page count
        unfused = BrTPFServer(
            store, ServerConfig(selector_backend="sharded",
                                shard_window=128, fuse_patterns=False))
        got_unfused = unfused.handle_batch(reqs)
        for f_w, f_g in zip(want, got_unfused, strict=True):
            np.testing.assert_array_equal(f_w.data, f_g.data)
        assert unfused.counters.kernel_launches == batched_expect
        assert unfused.counters.fused_launches == 0
        assert unfused.counters.kernel_batched_requests == 3
        # identical transfer/request accounting either way
        assert (batched.counters.num_requests
                == oracle.counters.num_requests)
        assert (batched.counters.data_received
                == oracle.counters.data_received)
        assert (batched.counters.server_lookups
                == oracle.counters.server_lookups)


def test_multi_shard_parity_subprocess():
    """True multi-device check: 4 forced host devices, the store
    partitioned over a 4-shard mesh, server responses byte-identical to
    the numpy oracle (all-gather geometry really crosses shards)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                           + os.environ.get("XLA_FLAGS", ""))
import numpy as np, jax
from repro.core import (BrTPFServer, Request, TriplePattern, TripleStore,
                        UNBOUND, encode_var)
V = encode_var
assert len(jax.devices()) == 4
rng = np.random.default_rng(3)
store = TripleStore(np.unique(
    rng.integers(0, 15, size=(800, 3)).astype(np.int32), axis=0))
s_np = BrTPFServer(store, page_size=25, selector_backend="numpy")
s_sh = BrTPFServer(store, page_size=25, selector_backend="sharded",
                   shard_window=64)
assert s_sh.federated.shards == 4
om = rng.integers(0, 15, size=(6, 2)).astype(np.int32)
om[rng.random((6, 2)) < 0.3] = UNBOUND
for tp in [TriplePattern(V(0), 3, V(1)), TriplePattern(5, V(0), V(1)),
           TriplePattern(V(0), 2, V(0))]:
    for omega in (None, om):
        page = 0
        while True:
            f_np = s_np.handle(Request(tp, omega, page))
            f_sh = s_sh.handle(Request(tp, omega, page))
            np.testing.assert_array_equal(f_np.data, f_sh.data)
            assert f_np.cnt == f_sh.cnt
            assert f_np.has_next == f_sh.has_next
            if not f_np.has_next:
                break
            page += 1
print("MULTI_SHARD_PARITY_OK")
"""
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "MULTI_SHARD_PARITY_OK" in proc.stdout
