"""Async batching front end: flush semantics, parity, live-sim validation.

The contract under test (src/repro/core/batching.py docstring):
responses through ``AsyncBrTPFServer`` are byte-identical to sequential
``handle`` calls, concurrent same-pattern requests coalesce into
strictly fewer grouped kernel launches, and the discrete-event sim's
launch model agrees with what the real front end does.
"""
import asyncio
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.core import (AsyncBrTPFClient, AsyncBrTPFServer, BrTPFClient,
                        BrTPFServer, MaxMprExceeded, Request, TriplePattern,
                        TripleStore, UNBOUND, bgp_from_arrays, encode_var,
                        serve_concurrent)
from repro.core.sim import (HttpRecord, QueryTrace, SimParams, live_replay)

V = encode_var

pytestmark = pytest.mark.tier1


def make_store(seed=0, n=500, terms=15):
    rng = np.random.default_rng(seed)
    return TripleStore(np.unique(
        rng.integers(0, terms, size=(n, 3)).astype(np.int32), axis=0))


def rand_omega(rng, m, v=2, terms=15, unbound_frac=0.3):
    om = rng.integers(0, terms, size=(m, v)).astype(np.int32)
    om[rng.random((m, v)) < unbound_frac] = UNBOUND
    return om


class RecordingServer(BrTPFServer):
    """BrTPFServer that records every handle_batch call (and can be made
    slow, so flushes overlap with new arrivals in executor mode)."""

    def __init__(self, *args, delay=0.0, **kwargs):
        super().__init__(*args, **kwargs)
        self.batches = []
        self.delay = delay

    def handle_batch(self, reqs):
        self.batches.append(list(reqs))
        if self.delay:
            time.sleep(self.delay)
        return super().handle_batch(reqs)


# ---------------------------------------------------------------------------
# Acceptance: concurrency coalescing + numpy parity
# ---------------------------------------------------------------------------


class TestConcurrentCoalescing:
    def test_16_clients_fewer_launches_and_numpy_identical(self):
        """16 concurrent same-pattern clients with batch_window_s > 0
        must issue strictly fewer kernel launches than 16 sequential
        handle calls, with responses byte-identical to the numpy
        backend."""
        store = make_store(0, n=600)
        tp = TriplePattern(V(0), 3, V(1))
        reqs = [Request(tp, rand_omega(np.random.default_rng(i), 6), 0)
                for i in range(16)]

        kserver = BrTPFServer(store, selector_backend="kernel")
        responses, front = serve_concurrent(
            kserver, [[r] for r in reqs], batch_window_s=2e-3)
        concurrent_launches = kserver.counters.kernel_launches

        seq = BrTPFServer(store, selector_backend="kernel")
        for r in reqs:
            seq.handle(r)
        assert concurrent_launches < seq.counters.kernel_launches
        assert concurrent_launches == 1          # one grouped launch
        assert front.stats.coalesced_requests == 16

        nserver = BrTPFServer(store, selector_backend="numpy")
        for (frag,), req in zip(responses, reqs, strict=True):
            want = nserver.handle(req)
            assert frag.data.dtype == want.data.dtype
            np.testing.assert_array_equal(frag.data, want.data)
            assert frag.cnt == want.cnt
            assert frag.has_next == want.has_next
        # transfer accounting identical to the sequential server too
        assert (kserver.counters.data_received
                == seq.counters.data_received)

    def test_window_zero_dispatches_immediately(self):
        store = make_store(1)
        server = RecordingServer(store, selector_backend="kernel")
        reqs = [[Request(TriplePattern(V(0), 3, V(1)),
                         rand_omega(np.random.default_rng(i), 4), 0)]
                for i in range(4)]
        _responses, front = serve_concurrent(server, reqs,
                                             batch_window_s=0.0)
        assert front.stats.flushes == 4
        assert all(len(b) == 1 for b in server.batches)


# ---------------------------------------------------------------------------
# Flush semantics
# ---------------------------------------------------------------------------


class TestFlushSemantics:
    def test_window_flush_ordering(self):
        """One window flush; responses resolve in enqueue order."""
        store = make_store(2)
        server = RecordingServer(store, selector_backend="kernel")
        front = AsyncBrTPFServer(server, batch_window_s=0.05,
                                 max_batch=100)
        tp = TriplePattern(V(0), 3, V(1))
        reqs = [Request(tp, rand_omega(np.random.default_rng(i), 4), 0)
                for i in range(5)]
        done_order = []

        async def client(i):
            frag = await front.handle(reqs[i])
            done_order.append(i)
            return frag

        async def main():
            # enqueue in a staggered but deterministic order
            tasks = []
            for i in range(5):
                tasks.append(asyncio.ensure_future(client(i)))
                await asyncio.sleep(0)
            out = await asyncio.gather(*tasks)
            await front.aclose()
            return out

        frags = asyncio.run(main())
        assert front.stats.flushes == 1
        assert front.stats.timer_flushes == 1
        assert [r.key() for r in server.batches[0]] \
            == [r.key() for r in reqs]
        assert done_order == list(range(5))
        solo = BrTPFServer(store, selector_backend="kernel")
        for req, frag in zip(reqs, frags, strict=True):
            want = solo.handle(req)
            np.testing.assert_array_equal(frag.data, want.data)

    def test_flush_on_full_beats_timer(self):
        """max_batch pending flushes immediately; the later timer finds
        an empty queue and is a no-op (no double flush)."""
        store = make_store(3)
        server = RecordingServer(store, selector_backend="kernel")
        front = AsyncBrTPFServer(server, batch_window_s=0.2, max_batch=3)
        tp = TriplePattern(V(0), 3, V(1))
        # warm the jit cache for this launch geometry so the elapsed
        # check below measures flush latency, not compile time
        warm = BrTPFServer(store, selector_backend="kernel")
        warm.handle_batch([
            Request(tp, rand_omega(np.random.default_rng(90 + i), 4), 0)
            for i in range(3)])

        async def main():
            t0 = time.perf_counter()
            await asyncio.gather(*[
                front.handle(Request(
                    tp, rand_omega(np.random.default_rng(i), 4), 0))
                for i in range(3)])
            elapsed = time.perf_counter() - t0
            # wait past the window: the armed timer must not re-flush
            await asyncio.sleep(0.25)
            await front.aclose()
            return elapsed

        elapsed = asyncio.run(main())
        assert elapsed < 0.2          # did not wait for the window
        assert front.stats.flushes == 1
        assert front.stats.full_flushes == 1
        assert len(server.batches) == 1 and len(server.batches[0]) == 3

    def test_partial_batch_flushes_on_timer(self):
        store = make_store(4)
        server = RecordingServer(store, selector_backend="kernel")
        front = AsyncBrTPFServer(server, batch_window_s=0.02,
                                 max_batch=100)
        tp = TriplePattern(V(0), 3, V(1))

        async def main():
            return await asyncio.gather(*[
                front.handle(Request(
                    tp, rand_omega(np.random.default_rng(i), 4), 0))
                for i in range(2)])

        frags = asyncio.run(main())
        assert len(frags) == 2
        assert front.stats.flushes == 1
        assert front.stats.timer_flushes == 1
        assert front.stats.full_flushes == 0

    def test_request_arriving_mid_flush_starts_new_batch(self):
        """With an executor, the loop stays live during a flush: a
        request arriving while handle_batch runs joins the NEXT batch,
        never the in-flight one."""
        store = make_store(5)
        server = RecordingServer(store, delay=0.08,
                                 selector_backend="kernel")
        with ThreadPoolExecutor(max_workers=1) as pool:
            front = AsyncBrTPFServer(server, batch_window_s=0.01,
                                     max_batch=10, executor=pool)
            tp = TriplePattern(V(0), 3, V(1))
            early = [Request(tp, rand_omega(np.random.default_rng(i), 4),
                             0) for i in range(2)]
            late = Request(tp, rand_omega(np.random.default_rng(9), 4), 0)

            async def late_client():
                # land inside the first flush's handle_batch (which
                # sleeps `delay` on the executor thread)
                await asyncio.sleep(0.04)
                return await front.handle(late)

            async def main():
                tasks = [asyncio.ensure_future(front.handle(r))
                         for r in early]
                tasks.append(asyncio.ensure_future(late_client()))
                out = await asyncio.gather(*tasks)
                await front.aclose()
                return out

            frags = asyncio.run(main())
        assert len(server.batches) == 2
        assert [r.key() for r in server.batches[0]] \
            == [r.key() for r in early]
        assert [r.key() for r in server.batches[1]] == [late.key()]
        solo = BrTPFServer(store, selector_backend="kernel")
        for req, frag in zip(early + [late], frags, strict=True):
            want = solo.handle(req)
            np.testing.assert_array_equal(frag.data, want.data)

    def test_aclose_flushes_pending(self):
        store = make_store(6)
        server = RecordingServer(store, selector_backend="kernel")
        front = AsyncBrTPFServer(server, batch_window_s=30.0,
                                 max_batch=100)
        req = Request(TriplePattern(V(0), 3, V(1)), None, 0)

        async def main():
            task = asyncio.ensure_future(front.handle(req))
            await asyncio.sleep(0)       # let it enqueue
            await front.aclose()         # don't wait 30 s
            frag = await task
            with pytest.raises(RuntimeError):
                await front.handle(req)
            return frag

        frag = asyncio.run(main())
        assert frag.data.shape[0] > 0
        assert front.stats.flushes == 1


# ---------------------------------------------------------------------------
# maxMpR validation under coalescing
# ---------------------------------------------------------------------------


class TestMaxMprUnderCoalescing:
    def test_oversized_request_fails_alone(self):
        """When coalesced requests disagree on validity, only the
        oversized one fails -- it never reaches handle_batch, whose
        batch-atomic check would otherwise poison its peers."""
        store = make_store(7)
        server = RecordingServer(store, max_mpr=5,
                                 selector_backend="kernel")
        front = AsyncBrTPFServer(server, batch_window_s=0.02,
                                 max_batch=100)
        tp = TriplePattern(V(0), 3, V(1))
        rng = np.random.default_rng(7)
        good = [Request(tp, rand_omega(rng, 4), 0) for _ in range(3)]
        bad = Request(tp, rand_omega(rng, 9), 0)   # 9 > maxMpR=5

        async def main():
            results = await asyncio.gather(
                *[front.handle(r) for r in good + [bad]],
                return_exceptions=True)
            await front.aclose()
            return results

        results = asyncio.run(main())
        assert isinstance(results[-1], MaxMprExceeded)
        assert front.stats.rejected == 1
        assert len(server.batches) == 1
        assert [r.key() for r in server.batches[0]] \
            == [r.key() for r in good]
        solo = BrTPFServer(store, max_mpr=5, selector_backend="kernel")
        for req, frag in zip(good, results[:3], strict=True):
            want = solo.handle(req)
            np.testing.assert_array_equal(frag.data, want.data)

    def test_direct_handle_batch_stays_atomic(self):
        """The pre-existing handle_batch contract is unchanged: an
        invalid member rejects the whole batch before any work."""
        store = make_store(8)
        server = BrTPFServer(store, max_mpr=5, selector_backend="kernel")
        tp = TriplePattern(V(0), 3, V(1))
        rng = np.random.default_rng(8)
        with pytest.raises(MaxMprExceeded):
            server.handle_batch([Request(tp, rand_omega(rng, 4), 0),
                                 Request(tp, rand_omega(rng, 9), 0)])
        assert server.counters.kernel_launches == 0
        assert server.fragments.data_entries == 0


# ---------------------------------------------------------------------------
# Candidate-range memo (kernel-path TPF paging)
# ---------------------------------------------------------------------------


class TestCandidateRangeMemo:
    def test_page_miss_after_selector_eviction_reuses_range(self):
        """A page>0 request whose selector memo entry was evicted must
        not re-materialize the candidate range: while another fragment
        still streams the pattern, the store-level range memo serves
        it."""
        store = make_store(10, n=900)
        server = BrTPFServer(store, page_size=20,
                             selector_backend="kernel")
        tp = TriplePattern(V(0), 3, V(1))
        rng = np.random.default_rng(10)
        om = rand_omega(rng, 8)
        om[0] = UNBOUND                     # multi-page fragment
        f0 = server.handle(Request(tp, om, 0))
        assert f0.has_next
        # a second live fragment keeps the pattern referenced, so
        # evicting om's entry must NOT drop the candidate range
        server.handle(Request(tp, rand_omega(rng, 4), 0))
        misses0 = store.range_memo_misses
        hits0 = store.range_memo_hits
        from repro.core import fragment_key
        server.fragments.evict(fragment_key(tp.as_tuple(), om))
        f1 = server.handle(Request(tp, om, 1))
        assert store.range_memo_misses == misses0   # no re-materialize
        assert store.range_memo_hits > hits0
        # ... and the page is still byte-identical to the numpy backend
        nserver = BrTPFServer(store, page_size=20,
                              selector_backend="numpy")
        nserver.handle(Request(tp, om, 0))
        want = nserver.handle(Request(tp, om, 1))
        np.testing.assert_array_equal(f1.data, want.data)

    def test_selector_memo_eviction_evicts_range_coherently(self):
        store = make_store(11, n=600)
        server = BrTPFServer(store, selector_backend="kernel")
        server.fragments.memo_capacity = 2
        pats = [TriplePattern(V(0), p, V(1)) for p in (3, 5, 7)]
        for tp in pats:
            server.handle(Request(tp, None, 0))
        # oldest pattern evicted from both memos; newest two retained
        assert pats[0].as_tuple() not in store._range_memo
        assert pats[1].as_tuple() in store._range_memo
        assert pats[2].as_tuple() in store._range_memo
        assert server.fragments.data_entries == 2

    def test_shared_pattern_keeps_range_until_last_fragment_evicted(self):
        """Two live fragments on one pattern: evicting one selector-memo
        entry must not drop the range the other still streams."""
        store = make_store(12, n=600)
        server = BrTPFServer(store, selector_backend="kernel")
        server.fragments.memo_capacity = 2
        tp = TriplePattern(V(0), 3, V(1))
        rng = np.random.default_rng(12)
        server.handle(Request(tp, rand_omega(rng, 4), 0))
        server.handle(Request(tp, rand_omega(rng, 4), 0))
        # a third selection on the same pattern evicts the first entry,
        # but the second still references the pattern -> range stays
        server.handle(Request(tp, rand_omega(rng, 4), 0))
        assert tp.as_tuple() in store._range_memo


# ---------------------------------------------------------------------------
# Live replay vs simulated launch counts
# ---------------------------------------------------------------------------


class TestLiveSimValidation:
    def test_live_launches_agree_with_sim_within_10pct(self):
        """The sim's batching-window launch model and the real front end
        must agree on launch counts for a concurrent same-pattern load
        (the ROADMAP 'make the server match the sim' loop, closed)."""
        store = make_store(13, n=600)
        tp_a = TriplePattern(V(0), 3, V(1))
        tp_b = TriplePattern(V(0), 5, V(1))
        rng = np.random.default_rng(13)

        def rec(tp, om):
            return HttpRecord(key=Request(tp, om, 0).key(), lookups=1,
                              scanned=10, recv=5,
                              pattern_key=tp.as_tuple(), cand=1024,
                              pats=8)

        traces_per_client = [
            [QueryTrace(f"q{ci}",
                        [rec(tp_a, rand_omega(rng, 4)),
                         rec(tp_b, rand_omega(rng, 4))],
                        completed=True)]
            for ci in range(16)]

        params = SimParams()
        server = BrTPFServer(store, selector_backend="kernel")
        lv = live_replay(traces_per_client, server, params,
                         batch_window_s=5e-3)
        assert lv.requests == 32
        assert lv.simulated_launches == 2    # one grouped launch per wave
        assert lv.within <= 0.10
        assert lv.observed_launches < 32     # strictly fewer than solo


# ---------------------------------------------------------------------------
# Async client vs sequential client
# ---------------------------------------------------------------------------


class TestAsyncClient:
    def test_async_client_matches_sync_client(self):
        """The concurrent BGP driver returns exactly the sequential
        brTPF client's solutions, while its in-flight omega chunks
        coalesce into fewer kernel launches."""
        store = make_store(14, n=2000, terms=10)
        bgp = bgp_from_arrays([[V(0), 3, V(1)], [V(1), 5, V(2)]])

        sync_server = BrTPFServer(store, page_size=40, max_mpr=10,
                                  selector_backend="kernel")
        sync_res = BrTPFClient(sync_server, max_mpr=10).execute(bgp)

        async_server = BrTPFServer(store, page_size=40, max_mpr=10,
                                   selector_backend="kernel")
        front = AsyncBrTPFServer(async_server, batch_window_s=2e-3,
                                 max_batch=64)

        async def main():
            client = AsyncBrTPFClient(front, max_mpr=10)
            try:
                return await client.execute(bgp)
            finally:
                await front.aclose()

        async_res = asyncio.run(main())
        assert sync_res.solutions.shape[0] > 0   # non-trivial query
        np.testing.assert_array_equal(async_res.solutions,
                                      sync_res.solutions)
        assert async_res.num_requests == sync_res.num_requests
        assert (async_server.counters.kernel_launches
                < sync_server.counters.kernel_launches)

    def test_budget_abort_cancels_inflight_fetches(self):
        """A budget-exhausted query must not leave orphan fetch tasks
        running into the next query (they would corrupt accounting)."""
        store = make_store(16, n=2000, terms=10)
        bgp = bgp_from_arrays([[V(0), 3, V(1)], [V(1), 5, V(2)]])
        server = BrTPFServer(store, page_size=20, max_mpr=5,
                             selector_backend="kernel")
        front = AsyncBrTPFServer(server, batch_window_s=2e-3)

        async def main():
            client = AsyncBrTPFClient(front, max_mpr=5,
                                      request_budget=4)
            res = await client.execute(bgp)
            assert res.timed_out
            await asyncio.sleep(0.05)   # drain any stragglers
            # nothing but this coroutine may still be alive
            leftover = [t for t in asyncio.all_tasks()
                        if t is not asyncio.current_task()]
            await front.aclose()
            return leftover

        leftover = asyncio.run(main())
        assert leftover == []

    def test_async_client_matches_numpy_reference(self):
        store = make_store(15, n=2000, terms=10)
        bgp = bgp_from_arrays([[V(0), 3, V(1)], [V(1), 5, V(2)]])
        ref_server = BrTPFServer(store, page_size=40, max_mpr=10,
                                 selector_backend="numpy")
        ref = BrTPFClient(ref_server, max_mpr=10).execute(bgp)

        server = BrTPFServer(store, page_size=40, max_mpr=10,
                             selector_backend="kernel")
        front = AsyncBrTPFServer(server, batch_window_s=2e-3)

        async def main():
            try:
                return await AsyncBrTPFClient(front,
                                              max_mpr=10).execute(bgp)
            finally:
                await front.aclose()

        got = asyncio.run(main())
        np.testing.assert_array_equal(got.solutions, ref.solutions)


# ---------------------------------------------------------------------------
# Admission control (docs/serving.md): bounded queue, retryable 503
# ---------------------------------------------------------------------------


class TestAdmissionControl:
    def _fresh_reqs(self, n, seed=100):
        """Distinct, never-served requests: the unified-store fast path
        must not swallow them before the queue-depth check."""
        tp = TriplePattern(V(0), 3, V(1))
        return [Request(tp, rand_omega(np.random.default_rng(seed + i), 4),
                        0) for i in range(n)]

    def test_queue_overflow_rejects_at_enqueue(self):
        from repro.core.batching import QueueSaturated
        server = BrTPFServer(make_store(20), selector_backend="numpy")
        # window far beyond the test's lifetime: the first request sits
        # in the queue, so the second must hit the depth check
        front = AsyncBrTPFServer(server, batch_window_s=60.0,
                                 queue_depth=1)
        r1, r2 = self._fresh_reqs(2)

        async def main():
            t1 = asyncio.create_task(front.handle(r1))
            await asyncio.sleep(0)          # let r1 reach the queue
            with pytest.raises(QueueSaturated):
                await front.handle(r2)
            rejected = front.stats.rejected
            await front.aclose()            # flushes r1, resolves t1
            return await t1, rejected

        frag, rejected = asyncio.run(main())
        assert rejected == 1
        # the admitted request is served normally (byte parity)
        want = BrTPFServer(make_store(20),
                           selector_backend="numpy").handle(r1)
        np.testing.assert_array_equal(frag.data, want.data)
        assert frag.cnt == want.cnt
        assert front.stats.requests == 1

    def test_queue_depth_validation_and_config_plumbing(self):
        from repro.core import ServerConfig
        server = BrTPFServer(make_store(21), selector_backend="numpy")
        with pytest.raises(ValueError):
            AsyncBrTPFServer(server, queue_depth=0)
        cfg = ServerConfig(selector_backend="numpy", queue_depth=3)
        front = AsyncBrTPFServer.from_config(make_store(21), cfg)
        try:
            assert front.queue_depth == 3
        finally:
            asyncio.run(front.aclose())

    def test_asgi_saturation_is_retryable_503(self):
        """Concurrent posts against a depth-1 queue: the overflow comes
        back as a brtpf/v1 503 error envelope marked retryable, while
        admitted requests are still served (200)."""
        from repro.core import ServerConfig
        from repro.core.wire import dumps
        from repro.serving.http import app_from_config, request_asgi
        store = make_store(22)
        cfg = ServerConfig(selector_backend="numpy", queue_depth=1,
                           max_mpr=12)
        app = app_from_config(store, cfg, batch_window_s=0.05)
        reqs = self._fresh_reqs(4, seed=200)

        async def main():
            resps = await asyncio.gather(*[
                request_asgi(app, "POST", "/fragment",
                             body=dumps(r.to_wire())) for r in reqs])
            await app.backend.aclose()
            return resps

        resps = asyncio.run(main())
        by_status = {}
        for r in resps:
            by_status.setdefault(r.status_code, []).append(r)
        assert 200 in by_status and 503 in by_status, sorted(by_status)
        for r in by_status[503]:
            env = r.json()
            assert env["kind"] == "error"
            assert env["retryable"] is True
            assert env["status"] == 503
        assert app.backend.stats.rejected == len(by_status[503])
