"""Unified fragment store: eviction semantics, cross-layer coherence,
launch skipping, and the shared client cache.

The contract under test (src/repro/core/fragments.py + ISSUE 4):

* ``LRUCache.contains`` / ``FragmentStore.http_contains`` /
  ``contains_data`` are non-counting peeks;
* eviction is LRU per layer and coherent across layers (evicting the
  HTTP entry drops the memo's page and vice versa -- single storage);
* a repeated request whose page is resident in the unified store issues
  ZERO kernel/window launches, on both accelerated backends, while
  responses stay byte-identical to the numpy oracle;
* the section-7 HTTP hit/miss counters are not distorted by memo-only
  traffic;
* the sync and async clients share one ``ClientFragmentCache``;
* ``live_replay`` validates observed vs simulated skipped-launch counts.
"""
import asyncio

import numpy as np
import pytest

from repro.core import (AsyncBrTPFClient, AsyncBrTPFServer, BrTPFClient,
                        BrTPFServer, ClientFragmentCache, FragmentStore,
                        LRUCache, Request, TriplePattern, TripleStore,
                        UNBOUND, bgp_from_arrays, encode_var,
                        fragment_key)

V = encode_var

pytestmark = pytest.mark.tier1


def make_store(seed=0, n=500, terms=15):
    rng = np.random.default_rng(seed)
    return TripleStore(np.unique(
        rng.integers(0, terms, size=(n, 3)).astype(np.int32), axis=0))


def rand_omega(rng, m, v=2, terms=15, unbound_frac=0.3):
    om = rng.integers(0, terms, size=(m, v)).astype(np.int32)
    om[rng.random((m, v)) < unbound_frac] = UNBOUND
    return om


# ---------------------------------------------------------------------------
# FragmentStore semantics
# ---------------------------------------------------------------------------


class TestFragmentStoreSemantics:
    def test_contains_is_non_counting(self):
        fs = FragmentStore()
        key = ((1, 2, 3), None)
        fs.put_data(key, ("payload", 7))
        fs.http_put((key[0], None, 0), "page0")
        h0, m0, ph0, pm0 = fs.hits, fs.misses, fs.page_hits, fs.page_misses
        assert fs.contains_data(key)
        assert not fs.contains_data(((9, 9, 9), None))
        assert fs.http_contains((key[0], None, 0))
        assert not fs.http_contains((key[0], None, 5))
        assert fs.page_resident((key[0], None, 3))   # via data residency
        assert (fs.hits, fs.misses) == (h0, m0)
        assert (fs.page_hits, fs.page_misses) == (ph0, pm0)

    def test_contains_does_not_bump_lru(self):
        fs = FragmentStore(memo_capacity=2)
        a, b, c = (((p, 0, 0), None) for p in (1, 2, 3))
        fs.put_data(a, "A")
        fs.put_data(b, "B")
        fs.contains_data(a)          # must NOT rescue `a` from eviction
        fs.put_data(c, "C")
        assert not fs.contains_data(a)
        assert fs.contains_data(b) and fs.contains_data(c)

    def test_data_layer_lru_eviction_order(self):
        fs = FragmentStore(memo_capacity=2)
        a, b, c = (((p, 0, 0), None) for p in (1, 2, 3))
        fs.put_data(a, "A")
        fs.put_data(b, "B")
        assert fs.get_data(a) == "A"     # counting hit bumps `a`
        fs.put_data(c, "C")              # evicts `b`, the LRU-oldest
        assert fs.contains_data(a)
        assert not fs.contains_data(b)
        assert fs.contains_data(c)
        assert fs.hits == 1 and fs.misses == 0

    def test_page_layer_lru_eviction_order(self):
        fs = FragmentStore(page_capacity=2)
        keys = [((1, 2, 3), None, p) for p in range(3)]
        fs.http_put(keys[0], "p0")
        fs.http_put(keys[1], "p1")
        assert fs.http_get(keys[0]) == "p0"   # bump page 0
        fs.http_put(keys[2], "p2")            # evicts page 1
        assert fs.http_contains(keys[0])
        assert not fs.http_contains(keys[1])
        assert fs.http_contains(keys[2])

    def test_coherent_cross_layer_eviction(self):
        """Evicting the HTTP entry drops the memo's page and vice versa
        -- both layers are views of ONE entry."""
        fs = FragmentStore()
        key = ((4, 5, 6), None)
        req0 = (key[0], None, 0)
        fs.put_data(key, ("data", 1))
        fs.http_put(req0, "page0")
        # HTTP-side eviction drops the page everywhere
        assert fs.evict_page(req0)
        assert not fs.http_contains(req0)
        assert fs.contains_data(key)          # data layer unaffected
        # ... and entry-level eviction drops BOTH layers at once
        fs.http_put(req0, "page0")
        assert fs.evict(key)
        assert not fs.contains_data(key)
        assert not fs.http_contains(req0)
        assert not fs.page_resident(req0)
        assert len(fs) == 0

    def test_on_release_fires_when_last_layer_goes(self):
        released = []
        fs = FragmentStore(on_release=released.append)
        key = ((7, 8, 9), None)
        fs.put_data(key, "data")
        fs.http_put((key[0], None, 0), "page0")
        fs.evict(key)
        assert released == [(7, 8, 9)]
        # two fragments on one pattern: only the LAST release fires
        released.clear()
        k1 = ((7, 8, 9), ((1, 1),))
        k2 = ((7, 8, 9), ((2, 2),))
        fs.put_data(k1, "a")
        fs.put_data(k2, "b")
        fs.evict(k1)
        assert released == []
        fs.evict(k2)
        assert released == [(7, 8, 9)]

    def test_bound_lru_cache_is_a_view(self):
        """A bound LRUCache keeps the section-7 accounting while pages
        live in the store; its capacity evicts store pages and store
        eviction is visible through the cache."""
        fs = FragmentStore()
        cache = LRUCache(capacity=2)
        cache.bind(fs)
        keys = [((1, 2, 3), None, p) for p in range(3)]
        assert cache.get(keys[0]) is None
        assert cache.misses == 1
        cache.put(keys[0], "p0")
        cache.put(keys[1], "p1")
        assert cache.get(keys[0]) == "p0"
        assert cache.hits == 1
        assert len(cache) == 2
        cache.put(keys[2], "p2")            # capacity: evicts page 1
        assert not fs.http_contains(keys[1])
        assert cache.contains(keys[0]) and cache.contains(keys[2])
        # store-side eviction is coherent with the cache view
        fs.evict(((1, 2, 3), None))
        assert len(cache) == 0
        assert not cache.contains(keys[0])

    def test_window_slices_register_as_range_pages(self):
        """CandidateRange.window gathers register as pages of the
        store's range fragment store: a repeated window read re-uses
        the gathered slice, and evicting the range drops its pages."""
        store = make_store(19, n=600)
        tp = TriplePattern(V(0), 3, V(1))
        rng = store.candidate_range(tp)
        w0 = rng.window(0, 7)
        ph0 = store._ranges.page_hits
        w0_again = rng.window(0, 7)
        assert w0_again is w0                  # served from the page layer
        assert store._ranges.page_hits == ph0 + 1
        np.testing.assert_array_equal(w0, rng.triples[:7])
        # coherent eviction: dropping the range drops its window pages
        store.evict_candidate_range(tp.as_tuple())
        assert store._ranges.num_pages == 0

    def test_weighted_trim_keeps_newest(self):
        fs = FragmentStore(memo_capacity=8, max_rows=10,
                           weigh=lambda p: p)
        fs.put_data(((1, 0, 0), None), 6)
        fs.put_data(((2, 0, 0), None), 6)    # 12 > 10: evicts oldest
        assert not fs.contains_data(((1, 0, 0), None))
        assert fs.contains_data(((2, 0, 0), None))
        fs.put_data(((3, 0, 0), None), 99)   # newest always kept
        assert fs.contains_data(((3, 0, 0), None))


# ---------------------------------------------------------------------------
# Acceptance: resident pages launch nothing, on BOTH accelerated backends
# ---------------------------------------------------------------------------


class TestZeroLaunchOnResidency:
    @pytest.mark.parametrize("backend", ["kernel", "sharded"])
    def test_repeated_request_launches_nothing(self, backend):
        store = make_store(20, n=700)
        server = BrTPFServer(store, page_size=50,
                             selector_backend=backend)
        oracle = BrTPFServer(store, page_size=50,
                             selector_backend="numpy")
        tp = TriplePattern(V(0), 3, V(1))
        om = rand_omega(np.random.default_rng(20), 8)
        req = Request(tp, om, 0)
        first = server.handle(req)
        launches0 = server.counters.kernel_launches
        assert launches0 > 0
        repeat = server.handle(req)
        # ZERO new kernel/window launches, one recorded skip
        assert server.counters.kernel_launches == launches0
        assert server.counters.launches_skipped == 1
        assert server.fragments.launches_skipped == 1
        # byte-identical to the numpy oracle, both times
        want = oracle.handle(req)
        for frag in (first, repeat):
            np.testing.assert_array_equal(frag.data, want.data)
            assert frag.cnt == want.cnt
            assert frag.has_next == want.has_next

    @pytest.mark.parametrize("backend", ["kernel", "sharded"])
    def test_selector_consults_store_directly(self, backend):
        """Both selector classes skip the launch themselves when handed
        a fragment store (direct users, not just the server)."""
        store = make_store(21, n=600)
        fs = FragmentStore()
        if backend == "kernel":
            from repro.core.kernel_selectors import KernelSelector
            sel = KernelSelector(store, fragments=fs)
        else:
            import jax
            from jax.sharding import Mesh
            from repro.core.federation import (FederatedStore,
                                               ShardedSelector)
            mesh = Mesh(np.array(jax.devices()), ("data",))
            sel = ShardedSelector(FederatedStore.build(store.triples,
                                                       mesh),
                                  window=512, fragments=fs)
        tp = TriplePattern(V(0), 3, V(1))
        om = rand_omega(np.random.default_rng(21), 6)
        data0, cnt0 = sel.select_with_cnt(tp, om)
        real0 = sum(1 for rec in sel.launches if not rec.skipped)
        assert real0 > 0
        data1, cnt1 = sel.select_with_cnt(tp, om)
        real1 = sum(1 for rec in sel.launches if not rec.skipped)
        skips = [rec for rec in sel.launches if rec.skipped]
        assert real1 == real0          # no new real launch
        assert len(skips) == 1 and skips[0].cand_streamed == 0
        assert fs.launches_skipped == 1
        np.testing.assert_array_equal(data0, data1)
        assert cnt0 == cnt1

    def test_http_populated_page_skips_launch_after_memo_eviction(self):
        """Cross-layer: the page was populated by the HTTP path; after
        the memo data is gone, the repeat is STILL launch-free."""
        store = make_store(22, n=600)
        cache = LRUCache(None)
        server = BrTPFServer(store, page_size=50, cache=cache,
                             selector_backend="kernel")
        tp = TriplePattern(V(0), 3, V(1))
        om = rand_omega(np.random.default_rng(22), 6)
        req = Request(tp, om, 0)
        server.handle(req)
        key = fragment_key(tp.as_tuple(), om)
        # drop ONLY the memo data: the HTTP page must survive
        server.fragments._drop_data(key)
        assert not server.fragments.contains_data(key)
        assert cache.contains(req.key())
        launches0 = server.counters.kernel_launches
        frag = server.handle(req)
        assert server.counters.kernel_launches == launches0
        assert server.counters.launches_skipped == 1
        want = BrTPFServer(store, page_size=50,
                           selector_backend="numpy").handle(req)
        np.testing.assert_array_equal(frag.data, want.data)


# ---------------------------------------------------------------------------
# Section-7 accounting must not be distorted by memo traffic
# ---------------------------------------------------------------------------


class TestHttpAccountingIntegrity:
    def test_memo_traffic_does_not_touch_http_counters(self):
        store = make_store(23, n=900)
        cache = LRUCache(None)
        server = BrTPFServer(store, page_size=20, cache=cache,
                             selector_backend="kernel")
        tp = TriplePattern(V(0), 3, V(1))
        om = rand_omega(np.random.default_rng(23), 8)
        om[0] = UNBOUND                       # multi-page fragment
        server.handle(Request(tp, om, 0))
        assert (cache.hits, cache.misses) == (0, 1)
        # page 1 is served from the MEMO (no launch) but it is a fresh
        # URL: the proxy would miss -- and must still count a miss
        launches0 = server.counters.kernel_launches
        server.handle(Request(tp, om, 1))
        assert server.counters.kernel_launches == launches0
        assert (cache.hits, cache.misses) == (0, 2)
        # a true repeat is an HTTP hit
        server.handle(Request(tp, om, 0))
        assert (cache.hits, cache.misses) == (1, 2)
        # the batch planner's residency peeks count nothing: both pages
        # are cached by now, so each counts exactly one ordinary hit
        server.handle_batch([Request(tp, om, 0), Request(tp, om, 1)])
        assert (cache.hits, cache.misses) == (3, 2)

    def test_http_hit_counts_match_unbound_reference(self):
        """A bound cache must report exactly the hit/miss sequence the
        standalone LRUCache (pre-unification behavior) reports for the
        same request stream."""
        store = make_store(24, n=700)
        rng = np.random.default_rng(24)
        pats = [TriplePattern(V(0), p, V(1)) for p in (3, 5)]
        reqs = []
        for _ in range(30):
            tp = pats[rng.integers(0, 2)]
            om = (rand_omega(np.random.default_rng(int(rng.integers(0, 4))), 4)
                  if rng.random() < 0.7 else None)
            reqs.append(Request(tp, om, int(rng.integers(0, 2))))
        bound = LRUCache(8)
        srv = BrTPFServer(store, page_size=30, cache=bound,
                          selector_backend="kernel")
        reference = LRUCache(8)   # standalone, hand-driven
        for req in reqs:
            srv.handle(req)
            if reference.get(req.key()) is None:
                reference.put(req.key(), True)
        assert (bound.hits, bound.misses) \
            == (reference.hits, reference.misses)


# ---------------------------------------------------------------------------
# Client cache: one shared implementation
# ---------------------------------------------------------------------------


class TestClientFragmentCache:
    def test_sync_and_async_share_one_implementation(self):
        store = make_store(25, n=800, terms=10)
        sync_client = BrTPFClient(BrTPFServer(store))
        front = AsyncBrTPFServer(BrTPFServer(store), batch_window_s=0.0)
        async_client = AsyncBrTPFClient(front)
        assert isinstance(sync_client.client_cache, ClientFragmentCache)
        assert isinstance(async_client.client_cache, ClientFragmentCache)

    def test_repeat_fetch_within_execution_hits_local_cache(self):
        store = make_store(26, n=800, terms=10)
        server = BrTPFServer(store, page_size=50)
        client = BrTPFClient(server)
        tp = TriplePattern(V(0), 3, V(1))
        f0 = client._fetch(tp, None, 0)
        n0 = server.counters.num_requests
        f1 = client._fetch(tp, None, 0)
        assert f1 is f0                      # served locally
        assert server.counters.num_requests == n0
        assert client.client_cache.hits == 1
        client.client_cache.clear()          # per-execution reset
        client._fetch(tp, None, 0)
        assert server.counters.num_requests == n0 + 1

    def test_async_repeat_fetch_hits_local_cache(self):
        store = make_store(27, n=800, terms=10)
        front = AsyncBrTPFServer(BrTPFServer(store, page_size=50),
                                 batch_window_s=0.0)
        client = AsyncBrTPFClient(front)
        tp = TriplePattern(V(0), 3, V(1))

        async def main():
            f0 = await client._fetch(tp, None, 0)
            f1 = await client._fetch(tp, None, 0)
            await front.aclose()
            return f0, f1

        f0, f1 = asyncio.run(main())
        assert f1 is f0
        assert client._requests_used == 1

    def test_disabled_cache_refetches(self):
        store = make_store(28, n=400, terms=10)
        server = BrTPFServer(store, page_size=50)
        client = BrTPFClient(server)
        client.client_cache = ClientFragmentCache(enabled=False)
        tp = TriplePattern(V(0), 3, V(1))
        client._fetch(tp, None, 0)
        client._fetch(tp, None, 0)
        assert server.counters.num_requests == 2

    def test_clients_still_match_reference_with_shared_cache(self):
        store = make_store(29, n=2000, terms=10)
        bgp = bgp_from_arrays([[V(0), 3, V(1)], [V(1), 5, V(2)]])
        ref = BrTPFClient(BrTPFServer(store, page_size=40, max_mpr=10),
                          max_mpr=10).execute(bgp)
        got = BrTPFClient(BrTPFServer(store, page_size=40, max_mpr=10,
                                      selector_backend="kernel"),
                          max_mpr=10).execute(bgp)
        np.testing.assert_array_equal(got.solutions, ref.solutions)
        assert got.num_requests == ref.num_requests


# ---------------------------------------------------------------------------
# Sim: skipped-launch validation against the real front end
# ---------------------------------------------------------------------------


class TestSkipValidation:
    def test_live_skips_agree_with_sim(self):
        """Repeated request keys across clients: the sim's memo model
        and the real server's fragment store must count the SAME
        skipped launches."""
        from repro.core.sim import (HttpRecord, QueryTrace, SimParams,
                                    live_replay)
        store = make_store(30, n=600)
        tp_a = TriplePattern(V(0), 3, V(1))
        tp_b = TriplePattern(V(0), 5, V(1))
        shared_omega = rand_omega(np.random.default_rng(30), 4)

        def rec(tp, om):
            return HttpRecord(key=Request(tp, om, 0).key(), lookups=1,
                              scanned=10, recv=5,
                              pattern_key=tp.as_tuple(), cand=1024,
                              pats=8)

        # every client issues the SAME two requests: after the first
        # wave computes them, every other arrival must skip
        traces_per_client = [
            [QueryTrace(f"q{ci}", [rec(tp_a, shared_omega),
                                   rec(tp_b, shared_omega)],
                        completed=True)]
            for ci in range(8)]
        server = BrTPFServer(store, selector_backend="kernel")
        lv = live_replay(traces_per_client, server, SimParams(),
                         batch_window_s=5e-3)
        assert lv.observed_skipped > 0
        assert lv.skip_within <= 0.10
        assert lv.observed_launches + lv.observed_skipped <= lv.requests

    def test_metrics_snapshot_reports_layers(self):
        store = make_store(31, n=500)
        cache = LRUCache(None)
        server = BrTPFServer(store, cache=cache,
                             selector_backend="kernel")
        tp = TriplePattern(V(0), 3, V(1))
        req = Request(tp, rand_omega(np.random.default_rng(31), 4), 0)
        server.handle(req)
        server.handle(req)
        snap = server.metrics_snapshot()
        assert snap["launches_skipped"] == 1
        assert snap["http"]["hits"] == 1
        assert snap["http"]["misses"] == 1
        assert snap["selector_memo"]["misses"] >= 1
        assert snap["range_memo"]["misses"] >= 1
        assert snap["counters"]["launches_skipped"] == 1
