"""DC001 bad: code after an unconditional return."""


def drain(items):
    out = []
    for item in items:
        out.append(item)
    return out
    out.clear()  # BAD: unreachable
