"""CC002 bad: mutating triple data with no reachable invalidation."""
import numpy as np


class Store:
    def __init__(self, triples):
        self.triples = triples  # construction is exempt


def append_triples(store, new_rows):
    store.triples = np.concatenate([store.triples, new_rows])  # BAD
    return store.triples
