"""AC001 good: the LaunchRecord lands on the accounting surface."""
from dataclasses import dataclass


@dataclass
class LaunchRecord:
    cand_streamed: int
    pat_slots: int
    groups: int


def run_launch(launches, rows, slots):
    launches.append(
        LaunchRecord(cand_streamed=rows, pat_slots=slots, groups=1))
    return launches[-1]
