"""CC001 bad: reaching into FragmentStore internals from outside."""


def page_count(fragments):
    return len(fragments._page_lru)  # BAD: FragmentStore internal
