"""RS001 bad: a blind retry loop -- catches TransportError inside a
bounded-attempt loop without consulting the central is_retryable()
predicate, so permanent errors get retried like transient ones."""
import asyncio


class TransportError(RuntimeError):
    status = 503


async def fetch(transport, req):
    attempt = 0
    while attempt < 3:
        try:
            return await transport.handle(req)
        except TransportError:  # BAD: blind retry, no is_retryable()
            attempt += 1
            await asyncio.sleep(0.01)
    return None
