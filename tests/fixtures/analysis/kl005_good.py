"""KL005 good: the fused bind-join call site records its segment count
into the module's LaunchRecord sink."""
from repro.core.kernel_selectors import LaunchRecord
from repro.kernels import ops as kops


class Selector:
    def __init__(self):
        self.launches = []

    def launch_fused(self, cand, seg_of_tile, pats, segments, groups):
        keep, idx, nmatch = kops.bindjoin_fused(cand, seg_of_tile, pats,
                                                segments=segments,
                                                groups=groups)
        self.launches.append(LaunchRecord(
            cand_streamed=int(cand.shape[0]), pat_slots=groups,
            groups=groups, segments=segments))
        return keep, idx, nmatch
