"""AS001 good: async sleep and executor dispatch only."""
import asyncio


async def collect(queue, executor, work):
    await asyncio.sleep(0.01)
    loop = asyncio.get_running_loop()
    await loop.run_in_executor(executor, work)
    return await queue.get()
