"""AC002 bad: one disposition path charges two launch counters."""


def charge(counters, launches):
    for rec in launches:
        if rec.skipped:
            counters.launches_skipped += 1
            continue
        counters.kernel_launches += 1  # BAD: path charges two counters
        counters.fast_path_selects += 1
