"""AS001 good (ASGI handler): fully async route, async HTTP client.

``httpx.AsyncClient(...)`` is a constructor, not a blocking request --
the rule matches the sync module-level verbs (httpx.get/post/request)
exactly and must leave the async client alone.
"""
import httpx


async def app(scope, receive, send):
    async with httpx.AsyncClient() as client:
        resp = await client.get("http://origin/fragment")
    await send({"type": "http.response.body", "body": resp.content})
