"""KL004 bad: a tile-capacity constant that is not a power of two."""
DEFAULT_BT = 1000  # BAD: not a power of two
DEFAULT_FILL = -1  # not a capacity token: ignored
