"""KL003 good: the grid parameter is marked static."""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


@functools.partial(jax.jit, static_argnames=("n_tiles", "interpret"))
def double(x, n_tiles, *, interpret: bool = False):
    return pl.pallas_call(
        _kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
        out_specs=pl.BlockSpec((8,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0],), jnp.int32),
        interpret=interpret,
    )(x)
