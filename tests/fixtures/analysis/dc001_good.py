"""DC001 good: no unreachable statements."""


def drain(items):
    out = []
    for item in items:
        out.append(item)
    return out
