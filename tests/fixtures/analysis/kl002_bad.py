"""KL002 bad: BlockSpec shape uses a traced (non-static) parameter."""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


@functools.partial(jax.jit, static_argnames=("interpret",))
def double(x, bt, *, interpret: bool = False):
    t = x.shape[0]
    return pl.pallas_call(
        _kernel,
        grid=(t // 8,),
        in_specs=[pl.BlockSpec((bt,), lambda i: (i,))],  # BAD: bt traced
        out_specs=pl.BlockSpec((8,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.int32),
        interpret=interpret,
    )(x)
