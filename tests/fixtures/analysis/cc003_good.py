"""CC003 good: the cutover drops every cached fragment with the swap."""


class Server:
    def __init__(self, federated):
        self.federated = federated


def repartition(server, fragments, heat):
    server.federated = server.federated.repartition(heat)
    fragments.clear()
    return server.federated
