"""DC002 good: the buffer is created once and reused."""
import numpy as np


def gather(groups):
    empty = np.empty((0, 3), dtype=np.int32)
    out = []
    for g in groups:
        out.append(g)
    return out, empty
