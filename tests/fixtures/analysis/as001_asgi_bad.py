"""AS001 bad (ASGI handler): nested asyncio.run inside a route."""
import asyncio


async def app(scope, receive, send):
    body = asyncio.run(fetch_fragment(scope))  # BAD: re-enters the loop
    await send({"type": "http.response.body", "body": body})


async def fetch_fragment(scope):
    return b"{}"
