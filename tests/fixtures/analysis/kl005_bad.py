"""KL005 bad: a fused bind-join launch whose segment count never
reaches a LaunchRecord sink -- invisible to fused_segments_per_launch."""
from repro.kernels import ops as kops


def launch_fused(cand, seg_of_tile, pats, segments, groups):
    keep, idx, nmatch = kops.bindjoin_fused(cand, seg_of_tile, pats,  # BAD
                                            segments=segments,
                                            groups=groups)
    return keep, idx, nmatch
