"""CC003 bad: placement cutover with no reachable invalidation."""


class Server:
    def __init__(self, federated):
        self.federated = federated  # construction is exempt


def repartition(server, heat):
    server.federated = server.federated.repartition(heat)  # BAD
    return server.federated
