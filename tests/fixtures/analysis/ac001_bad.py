"""AC001 bad: a LaunchRecord that never reaches the accounting list."""
from dataclasses import dataclass


@dataclass
class LaunchRecord:
    cand_streamed: int
    pat_slots: int
    groups: int


def run_launch(launches, rows, slots):
    rec = LaunchRecord(cand_streamed=rows, pat_slots=slots, groups=1)  # BAD
    return rec
