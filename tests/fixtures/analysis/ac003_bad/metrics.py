"""Fixture metrics module: emits launches and a hit rate only."""


class Counters:
    kernel_launches: int = 0
    launches_skipped: int = 0


def layer_metrics(server):
    return {"hit_rate": 0.0}
