"""KL001 good: pallas_call with the full launch-geometry kwargs."""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BT = 8


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def double(x, *, bt: int = BT, interpret: bool = False):
    t = x.shape[0]
    return pl.pallas_call(
        _kernel,
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((bt,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.int32),
        interpret=interpret,
    )(x)
