"""CC002 good: the mutation path releases the cached ranges."""
import numpy as np


class Store:
    def __init__(self, triples):
        self.triples = triples


def append_triples(store, fragments, pattern, new_rows):
    store.triples = np.concatenate([store.triples, new_rows])
    fragments.on_release(pattern)
    return store.triples
