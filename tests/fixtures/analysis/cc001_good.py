"""CC001 good: going through the public FragmentStore API."""


def page_count(fragments):
    return fragments.stats()["http"]["entries"]
