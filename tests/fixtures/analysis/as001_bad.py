"""AS001 bad: blocking sleep inside a coroutine."""
import time


async def collect(queue):
    time.sleep(0.01)  # BAD: blocks the event loop
    return await queue.get()
