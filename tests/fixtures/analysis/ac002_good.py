"""AC002 good: every disposition path charges exactly one counter."""


def charge(counters, launches):
    for rec in launches:
        if rec.skipped:
            counters.launches_skipped += 1
            continue
        if rec.fast_path:
            counters.fast_path_selects += rec.groups
            continue
        counters.kernel_launches += 1
