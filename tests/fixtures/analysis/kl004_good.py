"""KL004 good: power-of-two tile/window capacities."""
DEFAULT_BT = 1024
DEFAULT_BM = 128
DEFAULT_SHARD_WINDOW = 1024
DEFAULT_FILL = -1  # not a capacity token: ignored
