"""DC002 bad: the same pure value stored twice, unconditionally."""
import numpy as np


def gather(groups):
    empty = np.empty((0, 3), dtype=np.int32)
    out = []
    for g in groups:
        out.append(g)
    empty = np.empty((0, 3), dtype=np.int32)  # BAD: duplicate store
    return out, empty
