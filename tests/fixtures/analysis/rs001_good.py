"""RS001 good: the retry loop consults is_retryable(), and the
non-loop handler records the failure before absorbing it."""
import asyncio

from repro.serving.resilience import is_retryable


class TransportError(RuntimeError):
    status = 503


async def fetch(transport, req, stats):
    attempt = 0
    while attempt < 3:
        try:
            return await transport.handle(req)
        except TransportError as exc:
            if not is_retryable(exc):
                raise
            attempt += 1
            await asyncio.sleep(0.01)
    return None


async def fetch_once(transport, req, stats):
    try:
        return await transport.handle(req)
    except TransportError:
        stats.failures += 1
        return None
