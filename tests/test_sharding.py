"""Sharding rules + roofline analyzer unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding.rules import (constrain, default_rules, spec_for,
                                  use_rules)


def mesh1d():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


class TestSpecFor:
    def test_basic_mapping(self):
        rules = {"batch": "data", "ff": "model", "embed": None}
        assert spec_for(("batch", "embed", "ff"), rules) == \
            P("data", None, "model")

    def test_trailing_none_trimmed(self):
        rules = {"batch": "data"}
        assert spec_for(("batch", None, None), rules) == P("data")

    def test_duplicate_axis_dropped(self):
        """One mesh axis cannot shard two dims of one tensor."""
        rules = {"a": "model", "b": "model"}
        assert spec_for(("a", "b"), rules) == P("model")

    def test_multi_axis_rule(self):
        rules = {"batch": ("pod", "data")}
        assert spec_for(("batch", None), rules) == P(("pod", "data"))

    def test_default_rules_cover_model_axes(self):
        rules = default_rules()
        for name in ("batch", "vocab", "heads", "kv_heads", "ff",
                     "experts", "ssm_inner", "kv_seq"):
            assert name in rules


class TestConstrain:
    def test_noop_without_rules(self):
        x = jnp.ones((4, 4))
        assert constrain(x, "batch", "ff") is x

    def test_divisibility_guard(self):
        """Non-divisible dims fall back to replication, not an error."""
        mesh = mesh1d()
        with use_rules(mesh, {"batch": "data"}):
            x = jnp.ones((3, 2))  # 3 % 1 == 0 -> fine with 1 device
            y = constrain(x, "batch", None)
            assert y.shape == x.shape

    def test_applies_under_mesh(self):
        mesh = mesh1d()
        with use_rules(mesh, default_rules()):
            x = jnp.ones((4, 8))
            y = constrain(x, "batch", "embed")
            assert y.shape == x.shape


class TestRooflineAnalyzer:
    def test_dot_flops_and_while_trips(self):
        from repro.launch.roofline import HloAnalyzer

        hlo = """
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,16] constant({...})
  %dot.1 = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ni, %dot.1)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,16]) -> (s32[], f32[8,16]) {
  %a = f32[8,16] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,16]) tuple(%z, %a)
  ROOT %w.1 = (s32[], f32[8,16]) while(%t0), condition=%cond, body=%body
}
"""
        a = HloAnalyzer(hlo)
        cost = a.entry_cost()
        # dot: 2*8*16*16 = 4096 flops, x5 trips
        assert cost.flops == pytest.approx(5 * 4096, rel=0.01)

    def test_collective_bytes(self):
        from repro.launch.roofline import HloAnalyzer

        hlo = """
HloModule test

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %a = f32[128,256] parameter(0)
  ROOT %ar = f32[128,256] all-reduce(%a), replica_groups={}, to_apply=%add
}
"""
        cost = HloAnalyzer(hlo).entry_cost()
        assert cost.coll_bytes == 128 * 256 * 4
        assert cost.coll_counts == {"all-reduce": 1}

    def test_known_trip_count_preferred(self):
        from repro.launch.roofline import HloAnalyzer

        hlo = """
HloModule test

%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  %x = f32[4] get-tuple-element(%p), index=1
  %y = f32[4] add(%x, %x)
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[4]) tuple(%i, %y)
}

%cond (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]) parameter(0)
  ROOT %c = pred[] constant(false)
}

ENTRY %main (a: f32[4]) -> (s32[], f32[4]) {
  %a = f32[4] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[4]) tuple(%z, %a)
  ROOT %w.1 = (s32[], f32[4]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
}
"""
        cost = HloAnalyzer(hlo).entry_cost()
        assert cost.flops == pytest.approx(7 * 4, rel=0.01)

    def test_model_flops(self):
        from repro.configs.base import TRAIN_4K, DECODE_32K, get_arch
        from repro.launch.roofline import model_flops_for

        cfg = get_arch("qwen2-1.5b")
        n = cfg.param_count()
        assert model_flops_for(cfg, TRAIN_4K) == pytest.approx(
            6 * n * 256 * 4096)
        assert model_flops_for(cfg, DECODE_32K) == pytest.approx(
            2 * n * 128)
        moe = get_arch("olmoe-1b-7b")
        assert model_flops_for(moe, TRAIN_4K) == pytest.approx(
            6 * moe.active_param_count() * 256 * 4096)
