"""Omega-restricted candidate pruning (docs/pruning.md).

Covers the tentpole contracts:

* sub-range derivation: per-binding ``(lo, hi)`` bounds, the
  union-merge rule (disjoint, sorted, covering), and coverage of every
  instantiated pattern's matches;
* byte parity of pruned vs. unpruned selection on the kernel and
  sharded backends -- property-based over patterns x Omega shapes
  (hypothesis where available, seed-parametrized sweeps always),
  including repeated-variable patterns, empty Omega, Omega values
  absent from the store, and mixed-shape mappings;
* the sharded window-skip path: launches == planned pages, pages a
  strict subset when sub-ranges allow skipping;
* the small-work fast path: numpy block evaluation below the row
  threshold, decision recorded in ``LaunchRecord`` and charged to
  ``Counters.fast_path_selects`` -- never to the launch budget;
* honest range-memo accounting: probe paths neither charge misses nor
  churn entries, and a warm workload's per-server hit rate clears 50%
  even on a store polluted by another consumer.
"""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import (BrTPFServer, Request, TriplePattern, TripleStore,
                        UNBOUND, brtpf_select_with_cnt, encode_var)
from repro.core.federation import FederatedStore, ShardedSelector
from repro.core.kernel_selectors import KernelSelector
from repro.core.selectors import instantiate_patterns
from repro.core.store import merge_spans

V = encode_var

pytestmark = pytest.mark.tier1


def make_store(seed=0, n=500, terms=15):
    rng = np.random.default_rng(seed)
    return TripleStore(np.unique(
        rng.integers(0, terms, size=(n, 3)).astype(np.int32), axis=0))


def make_fed(store):
    return FederatedStore.build(
        store.triples, Mesh(np.array(jax.devices()[:1]), ("data",)))


def rand_omega(rng, m, v=2, terms=15, unbound_frac=0.3):
    om = rng.integers(0, terms, size=(m, v)).astype(np.int32)
    om[rng.random((m, v)) < unbound_frac] = UNBOUND
    return om


def rand_pattern(rng, terms=15, max_vars=3):
    comps = []
    for _ in range(3):
        if rng.random() < 0.5:
            comps.append(V(int(rng.integers(0, max_vars))))
        else:
            comps.append(int(rng.integers(0, terms)))
    return TriplePattern(*comps)


# ---------------------------------------------------------------------------
# Sub-range derivation
# ---------------------------------------------------------------------------


class TestSubranges:
    def test_merge_spans_rule(self):
        # overlap, adjacency, and gaps; empties dropped; sorted output
        bounds = np.array([[5, 9], [0, 3], [8, 12], [3, 4], [20, 20],
                           [15, 16]], np.int64)
        got = merge_spans(bounds)
        np.testing.assert_array_equal(
            got, np.array([[0, 4], [5, 12], [15, 16]], np.int64))
        assert merge_spans(np.empty((0, 2), np.int64)).shape == (0, 2)

    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
    def test_union_covers_every_instantiation(self, seed):
        """Every triple matching any instantiated pattern must lie in
        the gathered union, and the union must hold no duplicates --
        the two properties the pruned-path parity argument needs."""
        rng = np.random.default_rng(seed)
        store = make_store(seed)
        for _ in range(6):
            tp = rand_pattern(rng)
            om = rand_omega(rng, int(rng.integers(1, 8)),
                            v=3, unbound_frac=0.4)
            insts = instantiate_patterns(tp, om)
            sr = store.subranges(tp, insts=insts)
            if sr is None:
                continue
            rows = store.gather_subranges(sr)
            got = set(map(tuple, rows.tolist()))
            assert len(got) == rows.shape[0]        # no duplicates
            for p in insts:
                for t in store.match(p):
                    assert tuple(t.tolist()) in got
            assert sr.rows >= rows.shape[0]         # pre-dedup bound

    def test_empty_and_base_shaped_omega_prune_nothing(self):
        store = make_store(1)
        tp = TriplePattern(V(0), 3, V(1))
        assert store.subranges(tp, omega=None) is None or \
            store.subranges(tp, omega=None).rows >= \
            len(store.candidate_range(tp))
        # all-UNBOUND mappings instantiate the base pattern itself: the
        # sub-range union degenerates to the full prefix range, so the
        # selectors' ``rows < full`` check keeps the unpruned path
        om = np.full((3, 2), UNBOUND, np.int32)
        sr = store.subranges(tp, omega=om)
        assert sr is not None
        assert sr.rows >= len(store.candidate_range(tp))

    def test_wildcard_base_fully_unbound_instantiation(self):
        store = make_store(2)
        tp = TriplePattern(V(0), V(1), V(2))
        om = np.array([[UNBOUND, UNBOUND, UNBOUND]], np.int32)
        assert store.subranges(tp, omega=om) is None

    def test_absent_values_yield_empty_spans(self):
        store = make_store(3)
        tp = TriplePattern(V(0), 3, V(1))
        om = np.array([[9999, UNBOUND]], np.int32)
        sr = store.subranges(tp, omega=om)
        assert sr is not None and sr.rows == 0
        assert store.gather_subranges(sr).shape == (0, 3)

    def test_pruned_gather_memoizes_in_page_layer(self):
        """Pruned selections memoize independently of full-range reads:
        a repeated gather of the same span union is a page hit, and the
        pattern's full-range memo entry is untouched by it."""
        store = make_store(4)
        tp = TriplePattern(V(0), 3, V(1))
        om = np.array([[5, UNBOUND], [7, UNBOUND]], np.int32)
        sr = store.subranges(tp, omega=om)
        assert sr is not None
        h0 = store._ranges.page_hits
        a = store.gather_subranges(sr)
        b = store.gather_subranges(sr)
        np.testing.assert_array_equal(a, b)
        assert store._ranges.page_hits == h0 + 1


# ---------------------------------------------------------------------------
# Pruned selection parity (kernel + sharded backends)
# ---------------------------------------------------------------------------


def assert_kernel_identical(store, tp, omega, **kw):
    sel = KernelSelector(store, **kw)
    got, gcnt = sel.select_with_cnt(tp, omega)
    want, wcnt = brtpf_select_with_cnt(store, tp, omega)
    assert got.dtype == want.dtype and got.shape == want.shape
    np.testing.assert_array_equal(got, want)
    assert gcnt == wcnt
    return sel


class TestPrunedKernelParity:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5])
    def test_random_patterns_and_omegas(self, seed):
        """Seed-parametrized property sweep: parity for random
        pattern x Omega shapes, pruning decided organically."""
        rng = np.random.default_rng(seed)
        store = make_store(seed, n=600)
        for _ in range(4):
            tp = rand_pattern(rng)
            m = int(rng.integers(0, 8))
            om = None if m == 0 else rand_omega(rng, m, v=3,
                                               unbound_frac=0.4)
            assert_kernel_identical(store, tp, om)

    def test_uniform_bound_omega_prunes_and_matches(self):
        """Fully-uniform mappings force the pruned path; the launch
        record documents it."""
        store = make_store(6, n=700)
        tp = TriplePattern(V(0), 3, V(1))
        om = np.array([[5, UNBOUND], [7, UNBOUND], [2, UNBOUND]],
                      np.int32)
        sel = assert_kernel_identical(store, tp, om)
        assert sel.launches[-1].pruned

    def test_repeated_variable_patterns(self):
        rng = np.random.default_rng(7)
        store = make_store(7)
        assert_kernel_identical(store, TriplePattern(V(0), 2, V(0)),
                                rand_omega(rng, 5, v=1))
        assert_kernel_identical(store, TriplePattern(V(0), V(0), V(1)),
                                rand_omega(rng, 5))
        assert_kernel_identical(store, TriplePattern(V(0), V(0), V(0)),
                                rand_omega(rng, 5, v=1))

    def test_omega_values_absent_from_store(self):
        store = make_store(8)
        tp = TriplePattern(V(0), 3, V(1))
        om = np.array([[9999, UNBOUND], [8888, UNBOUND]], np.int32)
        sel = assert_kernel_identical(store, tp, om)
        assert sel.launches == []     # nothing to stream, no launch

    def test_mixed_shape_omega(self):
        """Mappings binding different variable subsets (multi-shape
        union, cross-index dedup)."""
        store = make_store(9, n=700)
        tp = TriplePattern(V(0), 3, V(1))
        om = np.array([[5, UNBOUND], [UNBOUND, 4], [2, 9]], np.int32)
        assert_kernel_identical(store, tp, om)

    def test_grouped_batch_mixed_tpf_and_pruned(self):
        rng = np.random.default_rng(10)
        store = make_store(10, n=700)
        tp = TriplePattern(V(0), 3, V(1))
        omegas = [None,
                  np.array([[5, UNBOUND], [2, UNBOUND]], np.int32),
                  rand_omega(rng, 6)]
        sel = KernelSelector(store)
        results = sel.select_same_pattern(tp, omegas)
        assert len(sel.launches) == 1      # still one grouped launch
        for (data, cnt), om in zip(results, omegas, strict=True):
            want, wcnt = brtpf_select_with_cnt(store, tp, om)
            np.testing.assert_array_equal(data, want)
            assert cnt == wcnt


class TestShardedWindowSkip:
    def test_skip_plan_and_parity(self):
        store = make_store(11, n=900, terms=30)
        fed = make_fed(store)
        tp = TriplePattern(V(0), V(1), V(2))   # base range = whole shard
        om = np.array([[5, 3, UNBOUND], [9, 2, UNBOUND]], np.int32)
        insts = instantiate_patterns(tp, om)
        plan = fed.plan_windows(tp, insts, 64)
        assert plan.pruned
        assert len(plan.pages) < plan.pages_total    # windows skipped
        sel = ShardedSelector(fed, window=64)
        got, gcnt = sel.select_with_cnt(tp, om)
        want, wcnt = brtpf_select_with_cnt(store, tp, om)
        np.testing.assert_array_equal(got, want)
        assert gcnt == wcnt
        assert len(sel.launches) == len(plan.pages)
        assert all(rec.pruned for rec in sel.launches)

    def test_pos_osp_mirrors_bound_ranges(self):
        """Unbound-subject patterns binary-search the POS/OSP mirror
        instead of scanning whole shards."""
        store = make_store(12, n=900, terms=30)
        fed = make_fed(store)
        whole_shard_pages = -(-fed.shard_n // 64)
        for tp in [TriplePattern(V(0), 3, V(1)),     # POS
                   TriplePattern(V(0), V(1), 7)]:    # OSP
            sel = ShardedSelector(fed, window=64)
            got, gcnt = sel.select_with_cnt(tp, None)
            want, wcnt = brtpf_select_with_cnt(store, tp, None)
            np.testing.assert_array_equal(got, want)
            assert gcnt == wcnt
            expect = -(-len(store.candidate_range(tp)) // 64)
            assert len(sel.launches) == expect
            assert len(sel.launches) < whole_shard_pages

    def test_hand_computed_page_counts(self):
        """Launch counts against hand-derived constants (independent of
        plan_windows, so a planning regression cannot re-derive its own
        expectation): a 16-triple single-shard store, window 4.

        Triples (i, 1, i) for i in 0..15 sort to SPO positions 0..15,
        so the shard has exactly 4 window pages. A TPF request for
        (?s, 1, ?o) has POS range 16 -> all 4 pages. Omega binding
        s in {0, 15} instantiates (0, 1, ?o) and (15, 1, ?o) -- SPO
        positions 0 and 15, i.e. pages 0 and 3 only.
        """
        triples = np.array([[i, 1, i] for i in range(16)], np.int32)
        store = TripleStore(triples)
        fed = make_fed(store)
        assert fed.shard_n == 16
        tp = TriplePattern(V(0), 1, V(1))
        sel = ShardedSelector(fed, window=4)
        got, gcnt = sel.select_with_cnt(tp, None)
        want, wcnt = brtpf_select_with_cnt(store, tp, None)
        np.testing.assert_array_equal(got, want)
        assert gcnt == wcnt
        assert len(sel.launches) == 4          # ceil(16 / 4), by hand
        om = np.array([[0, UNBOUND], [15, UNBOUND]], np.int32)
        sel = ShardedSelector(fed, window=4)
        got, gcnt = sel.select_with_cnt(tp, om)
        want, wcnt = brtpf_select_with_cnt(store, tp, om)
        np.testing.assert_array_equal(got, want)
        assert gcnt == wcnt
        assert len(sel.launches) == 2          # pages {0, 3}, by hand

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_random_parity_through_plans(self, seed):
        rng = np.random.default_rng(seed)
        store = make_store(seed + 20, n=700, terms=20)
        fed = make_fed(store)
        for _ in range(3):
            tp = rand_pattern(rng, terms=20)
            m = int(rng.integers(0, 6))
            om = None if m == 0 else rand_omega(rng, m, v=3, terms=20,
                                               unbound_frac=0.4)
            sel = ShardedSelector(fed, window=64)
            got, gcnt = sel.select_with_cnt(tp, om)
            want, wcnt = brtpf_select_with_cnt(store, tp, om)
            np.testing.assert_array_equal(got, want)
            assert gcnt == wcnt


# ---------------------------------------------------------------------------
# Small-work fast path
# ---------------------------------------------------------------------------


class TestFastPath:
    def test_kernel_fast_path_records_decision(self):
        store = make_store(13, n=700)
        tp = TriplePattern(V(0), 3, V(1))
        om = np.array([[5, UNBOUND], [7, UNBOUND]], np.int32)
        sel = assert_kernel_identical(store, tp, om,
                                      fast_path_rows=10**9)
        rec = sel.launches[-1]
        assert rec.fast_path and rec.pat_slots == 0
        assert rec.cand_streamed <= 10**9

    def test_threshold_zero_disables(self):
        store = make_store(13, n=700)
        tp = TriplePattern(V(0), 3, V(1))
        sel = assert_kernel_identical(store, tp, None)
        assert not sel.launches[-1].fast_path

    @pytest.mark.parametrize("backend", ["kernel", "sharded"])
    def test_server_charges_fast_path_not_launch_budget(self, backend):
        store = make_store(14, n=700)
        server = BrTPFServer(store, selector_backend=backend,
                             shard_window=128, fast_path_rows=10**9)
        oracle = BrTPFServer(store, selector_backend="numpy")
        rng = np.random.default_rng(14)
        reqs = [Request(TriplePattern(V(0), 3, V(1)),
                        rand_omega(rng, 4), 0),
                Request(TriplePattern(V(0), 5, V(1)), None, 0)]
        for r in reqs:
            f_k = server.handle(r)
            f_np = oracle.handle(r)
            np.testing.assert_array_equal(f_k.data, f_np.data)
            assert f_k.cnt == f_np.cnt
        assert server.counters.kernel_launches == 0
        assert server.counters.kernel_cand_streamed == 0
        assert server.counters.fast_path_selects == len(reqs)


# ---------------------------------------------------------------------------
# Honest range-memo accounting
# ---------------------------------------------------------------------------


class TestRangeMemoAccounting:
    def test_probe_paths_charge_nothing(self):
        """cardinality probes neither charge misses nor create memo
        entries -- and still reuse (and count) a hit when one exists."""
        store = make_store(15, n=400)
        tp = TriplePattern(V(0), 5, V(0))    # repeated var -> scan fallback
        m0, h0 = store.range_memo_misses, store.range_memo_hits
        store.cardinality(tp)
        assert store.range_memo_misses == m0      # no miss charged
        assert tp.as_tuple() not in store._range_memo   # no entry made
        store.match(tp)                           # streaming read: memoizes
        m1, h1 = store.range_memo_misses, store.range_memo_hits
        store.cardinality(tp)
        assert store.range_memo_misses == m1
        assert store.range_memo_hits > h1         # probe reused the entry

    def test_warm_workload_hit_rate_over_50pct(self):
        """Per-server delta accounting: a warm kernel-backend workload
        reports > 50% range-memo hits even when the shared store was
        polluted by another consumer's traffic beforehand."""
        store = make_store(16, n=900, terms=40)
        # pollute: another consumer churns the range memo (the
        # benchmarks' shared dataset store sees exactly this)
        for s in range(200):
            store.match(TriplePattern(s % 40, V(0), V(1)))
        server = BrTPFServer(store, selector_backend="kernel")
        rng = np.random.default_rng(16)
        pats = [TriplePattern(V(0), p, V(1)) for p in (3, 5, 7)]
        for _pass in range(2):
            for tp in pats:
                for _ in range(3):
                    server.handle(Request(tp, rand_omega(rng, 4,
                                                         terms=40), 0))
        snap = server.metrics_snapshot()
        assert snap["range_memo"]["hit_rate"] > 0.5
        # the polluted global counters would fail this without deltas
        global_rate = store.range_memo_hits / max(
            store.range_memo_hits + store.range_memo_misses, 1)
        assert global_rate < snap["range_memo"]["hit_rate"]


# ---------------------------------------------------------------------------
# Hypothesis property suite (runs where hypothesis is installed)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # optional dep: the sweeps above still run
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    MAX_TERMS = 9

    @st.composite
    def graphs(draw, max_triples=60):
        n = draw(st.integers(0, max_triples))
        rows = draw(st.lists(
            st.tuples(*[st.integers(0, MAX_TERMS - 1)] * 3),
            min_size=n, max_size=n))
        return np.asarray(rows, dtype=np.int32).reshape(-1, 3)

    @st.composite
    def patterns(draw, max_vars=3):
        comps = []
        for _ in range(3):
            if draw(st.booleans()):
                comps.append(V(draw(st.integers(0, max_vars - 1))))
            else:
                comps.append(draw(st.integers(0, MAX_TERMS - 1)))
        return TriplePattern(*comps)

    @st.composite
    def omegas(draw, num_vars=3, max_rows=6):
        n = draw(st.integers(0, max_rows))
        rows = draw(st.lists(
            st.tuples(*[st.integers(-1, MAX_TERMS + 2)] * num_vars),
            min_size=n, max_size=n))
        om = np.asarray(rows, dtype=np.int32).reshape(-1, num_vars)
        om[om < 0] = UNBOUND
        return om

    class TestHypothesisPrunedParity:
        @settings(max_examples=25, deadline=None)
        @given(g=graphs(), tp=patterns(), om=omegas())
        def test_kernel_pruned_parity(self, g, tp, om):
            store = TripleStore(g)
            omega = om if om.shape[0] else None
            assert_kernel_identical(store, tp, omega)

        @settings(max_examples=25, deadline=None)
        @given(g=graphs(), tp=patterns(), om=omegas())
        def test_subrange_union_coverage(self, g, tp, om):
            store = TripleStore(g)
            insts = instantiate_patterns(tp,
                                         om if om.shape[0] else None)
            sr = store.subranges(tp, insts=insts)
            if sr is None:
                return
            rows = store.gather_subranges(sr)
            got = set(map(tuple, rows.tolist()))
            assert len(got) == rows.shape[0]
            for p in insts:
                for t in store.match(p):
                    assert tuple(t.tolist()) in got
