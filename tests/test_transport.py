"""Serving edge (PR 7): wire schema, ASGI app, transports, router.

The contract under test (docs/serving.md): a fragment served over HTTP
is byte-identical to the same request handled in-process on every
selector backend; the GET-parameter and POST-envelope encodings decode
through one code path; maxMpR violations surface as HTTP 414 and
malformed envelopes as 400; ``GET /metrics`` speaks the same canonical
snapshot schema as ``metrics_snapshot()``; and ``ServerConfig`` is
equivalent to the deprecated per-kwarg constructor surface.

Replica-router tests live at the bottom; the deterministic ones
(routing/affinity/fleet parity with tiny batching windows) are tier1 --
failover-under-faults behavior is exercised in test_resilience.py.
"""
import asyncio
import json
import warnings

import numpy as np
import pytest

from repro.core import (AsyncBrTPFClient, BrTPFClient, BrTPFServer,
                        MaxMprExceeded, Request, ServerConfig,
                        TriplePattern, TripleStore, UNBOUND, WIRE_VERSION,
                        WireError, bgp_from_arrays, encode_var,
                        fragment_from_wire, fragment_to_wire,
                        metrics_snapshot, request_from_wire,
                        request_to_wire)
from repro.core.batching import AsyncBrTPFServer
from repro.core.metrics import latency_summary
from repro.core.wire import (ERROR_CODES, dumps, error_from_wire,
                             error_to_wire, loads)
from repro.serving.http import TestClient, app_from_config, create_app
from repro.serving.router import ReplicaRouter, stable_replica_index
from repro.serving.transport import AsgiTransport, LoopbackTransport

V = encode_var

TIER1 = pytest.mark.tier1


def make_store(seed=0, n=600, terms=18):
    rng = np.random.default_rng(seed)
    return TripleStore(np.unique(
        rng.integers(0, terms, size=(n, 3)).astype(np.int32), axis=0))


def rand_omega(rng, m, v=2, terms=18, unbound_frac=0.3):
    om = rng.integers(0, terms, size=(m, v)).astype(np.int32)
    om[rng.random((m, v)) < unbound_frac] = UNBOUND
    return om


def sample_requests(store, seed=3, count=24, max_mpr=30):
    """Mixed TPF/brTPF page requests whose patterns actually occur in
    the store (so pages are non-trivial)."""
    rng = np.random.default_rng(seed)
    triples = store.triples
    reqs = []
    for i in range(count):
        s, p, o = (int(x) for x in triples[rng.integers(len(triples))])
        shape = i % 4
        if shape == 0:
            tp = TriplePattern(V(0), p, o)
        elif shape == 1:
            tp = TriplePattern(s, p, V(0))
        elif shape == 2:
            tp = TriplePattern(V(0), p, V(1))
        else:
            tp = TriplePattern(V(0), V(1), o)
        omega = None
        if i % 3:
            omega = rand_omega(rng, int(rng.integers(1, max_mpr)),
                               v=len(tp.variables()))
        reqs.append(Request(pattern=tp, omega=omega, page=0))
    return reqs


def small_workload():
    return [
        ("q1", bgp_from_arrays([[V(0), 2, V(1)], [V(1), 3, V(2)]])),
        ("q2", bgp_from_arrays([[V(0), 1, V(1)], [V(0), 4, V(2)]])),
        ("q3", bgp_from_arrays([[V(0), 5, 7]])),
    ]


def canon(solutions):
    arr = np.asarray(solutions)
    if arr.size == 0:
        return arr.reshape(0, arr.shape[1] if arr.ndim == 2 else 0)
    return arr[np.lexsort(arr.T[::-1])]


# ---------------------------------------------------------------------------
# Wire schema round-trips (satellite 1)
# ---------------------------------------------------------------------------


class TestWireRoundTrip:
    pytestmark = TIER1

    def test_request_round_trip_brtpf(self):
        rng = np.random.default_rng(0)
        req = Request(pattern=TriplePattern(V(0), 3, V(1)),
                      omega=rand_omega(rng, 7), page=2)
        out = request_from_wire(loads(dumps(req.to_wire())))
        assert out.pattern == req.pattern
        assert out.page == req.page
        assert out.omega.dtype == np.int32
        assert np.array_equal(out.omega, req.omega)
        assert out.key() == req.key()

    def test_request_round_trip_tpf(self):
        req = Request(pattern=TriplePattern(5, V(0), V(1)))
        out = Request.from_wire(loads(dumps(request_to_wire(req))))
        assert out.omega is None
        assert out.key() == req.key()

    def test_request_wire_is_byte_stable(self):
        rng = np.random.default_rng(1)
        req = Request(pattern=TriplePattern(V(0), 2, 9),
                      omega=rand_omega(rng, 5, v=1), page=1)
        once = dumps(request_to_wire(req))
        twice = dumps(request_to_wire(request_from_wire(loads(once))))
        assert once == twice

    def test_fragment_round_trip_bytes(self):
        store = make_store()
        server = BrTPFServer(store, config=ServerConfig(page_size=20))
        req = sample_requests(store, count=1)[0]
        frag = server.handle(req)
        once = dumps(fragment_to_wire(frag))
        out = fragment_from_wire(loads(once))
        assert out.data.dtype == np.int32
        assert np.array_equal(out.data, np.asarray(frag.data))
        assert (out.cnt, out.page, out.page_size, out.has_next,
                out.meta_triples) == (frag.cnt, frag.page, frag.page_size,
                                      frag.has_next, frag.meta_triples)
        assert dumps(fragment_to_wire(out)) == once

    def test_envelope_carries_version(self):
        env = request_to_wire(Request(pattern=TriplePattern(1, 2, 3)))
        assert env["v"] == WIRE_VERSION
        assert env["kind"] == "request"

    @pytest.mark.parametrize("mutate", [
        lambda e: e.update(v="brtpf/v0"),
        lambda e: e.update(v=None),
        lambda e: e.update(kind="fragment"),
        lambda e: e.update(pattern=[1, 2]),
        lambda e: e.update(pattern="spo"),
        lambda e: e.update(page=-1),
        lambda e: e.update(page="0"),
        lambda e: e.update(omega=[[1, 2], [3]]),
        lambda e: e.update(omega=42),
    ])
    def test_malformed_request_rejected(self, mutate):
        env = request_to_wire(
            Request(pattern=TriplePattern(V(0), 2, 3),
                    omega=np.zeros((2, 1), dtype=np.int32)))
        mutate(env)
        with pytest.raises(WireError):
            request_from_wire(env)

    def test_invalid_json_rejected(self):
        with pytest.raises(WireError):
            loads(b"{not json")
        with pytest.raises(WireError):
            loads(b"[1,2,3]")

    def test_fragment_missing_field_rejected(self):
        store = make_store()
        server = BrTPFServer(store)
        env = fragment_to_wire(
            server.handle(Request(pattern=TriplePattern(V(0), 2, V(1)))))
        del env["cnt"]
        with pytest.raises(WireError):
            fragment_from_wire(env)

    def test_server_config_wire_round_trip(self):
        cfg = ServerConfig(page_size=25, max_mpr=10,
                           selector_backend="kernel", fast_path_rows=64)
        assert ServerConfig.from_wire(
            json.loads(dumps(cfg.to_wire()))) == cfg

    def test_request_timeout_ms_round_trips(self):
        req = Request(pattern=TriplePattern(V(0), 2, 3), timeout_ms=250.0)
        env = request_to_wire(req)
        assert env["timeout_ms"] == 250.0
        out = request_from_wire(loads(dumps(env)))
        assert out.timeout_ms == 250.0
        # the deadline is delivery metadata, NOT cache identity
        assert out.key() == Request(pattern=req.pattern).key()

    def test_request_without_timeout_is_byte_identical(self):
        """New field must not perturb the brtpf/v1 bytes of existing
        traffic (it is emitted only when set)."""
        req = Request(pattern=TriplePattern(V(0), 2, 3))
        env = request_to_wire(req)
        assert "timeout_ms" not in env
        assert request_from_wire(loads(dumps(env))).timeout_ms is None

    @pytest.mark.parametrize("bad", [0, -5, "100", True, [100]])
    def test_invalid_timeout_ms_rejected(self, bad):
        env = request_to_wire(Request(pattern=TriplePattern(1, 2, 3)))
        env["timeout_ms"] = bad
        with pytest.raises(WireError):
            request_from_wire(env)


class TestErrorEnvelope:
    """Wire error schema (docs/serving.md error-code table)."""

    pytestmark = TIER1

    def test_round_trip_all_codes(self):
        for code in ERROR_CODES:
            env = error_to_wire(503, "busy", retryable=True, code=code,
                                retry_after_ms=12.5)
            out = error_from_wire(loads(dumps(env)))
            assert out["status"] == 503
            assert out["error"] == "busy"
            assert out["retryable"] is True
            assert out["code"] == code
            assert out["retry_after_ms"] == 12.5

    def test_wire_is_byte_stable(self):
        env = error_to_wire(504, "deadline", retryable=True,
                            code="DEADLINE_EXCEEDED")
        once = dumps(env)
        decoded = error_from_wire(loads(once))
        again = dumps(error_to_wire(decoded["status"], decoded["error"],
                                    retryable=decoded["retryable"],
                                    code=decoded["code"],
                                    retry_after_ms=decoded["retry_after_ms"]))
        assert once == again

    def test_optional_fields_emitted_only_when_set(self):
        """Pre-PR-10 consumers must see pre-PR-10 bytes for plain
        errors: code/retry_after_ms appear only when provided."""
        env = error_to_wire(400, "bad request")
        assert "code" not in env and "retry_after_ms" not in env
        out = error_from_wire(loads(dumps(env)))
        assert out["code"] is None
        assert out["retry_after_ms"] is None
        assert out["retryable"] is False

    def test_unknown_code_rejected_at_encode(self):
        with pytest.raises(ValueError):
            error_to_wire(500, "boom", code="EXPLODED")

    @pytest.mark.parametrize("mutate", [
        lambda e: e.update(kind="fragment"),
        lambda e: e.update(status="503"),
        lambda e: e.pop("error"),
        lambda e: e.update(code="NOT_A_CODE"),
        lambda e: e.update(retryable="yes"),
        lambda e: e.update(retry_after_ms=-1),
    ])
    def test_malformed_error_rejected(self, mutate):
        env = error_to_wire(503, "busy", retryable=True,
                            code="QUEUE_SATURATED", retry_after_ms=5.0)
        mutate(env)
        with pytest.raises(WireError):
            error_from_wire(env)


# hypothesis-gated stability sweep (optional dep, like test_pruning.py)
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    MAX_TERMS = 9

    @st.composite
    def wire_requests(draw):
        comps = []
        nvars = 0
        for _ in range(3):
            if draw(st.booleans()):
                comps.append(V(nvars))
                nvars += 1
            else:
                comps.append(draw(st.integers(0, MAX_TERMS - 1)))
        omega = None
        page = draw(st.integers(0, 3))
        if nvars and draw(st.booleans()):
            m = draw(st.integers(0, 6))
            rows = draw(st.lists(
                st.tuples(*[st.integers(-1, MAX_TERMS)] * nvars),
                min_size=m, max_size=m))
            om = np.asarray(rows, dtype=np.int32).reshape(m, nvars)
            om[om < 0] = UNBOUND
            omega = om
        timeout_ms = draw(st.one_of(
            st.none(),
            st.floats(min_value=0.5, max_value=60_000.0,
                      allow_nan=False, allow_infinity=False)))
        return Request(pattern=TriplePattern(*comps), omega=omega,
                       page=page, timeout_ms=timeout_ms)

    @pytest.mark.tier1
    class TestHypothesisWireStability:
        @settings(max_examples=60, deadline=None)
        @given(req=wire_requests())
        def test_round_trip_preserves_key_and_bytes(self, req):
            once = dumps(request_to_wire(req))
            out = request_from_wire(loads(once))
            assert out.key() == req.key()
            assert dumps(request_to_wire(out)) == once
            assert out.timeout_ms == req.timeout_ms


# ---------------------------------------------------------------------------
# ServerConfig vs legacy kwargs (satellite 2)
# ---------------------------------------------------------------------------


class TestServerConfig:
    pytestmark = TIER1

    def test_legacy_kwargs_deprecated_but_equivalent(self):
        store = make_store()
        cfg = ServerConfig(page_size=17, max_mpr=9,
                           meta_triples_per_page=5, fast_path_rows=32)
        modern = BrTPFServer(store, config=cfg)
        with pytest.warns(DeprecationWarning):
            legacy = BrTPFServer(store, page_size=17, max_mpr=9,
                                 meta_triples_per_page=5,
                                 fast_path_rows=32)
        assert legacy.config == modern.config == cfg
        for req in sample_requests(store, count=8, max_mpr=9):
            a = dumps(fragment_to_wire(modern.handle(req)))
            b = dumps(fragment_to_wire(legacy.handle(req)))
            assert a == b

    def test_config_plus_legacy_kwarg_is_an_error(self):
        with pytest.raises(TypeError):
            BrTPFServer(make_store(), config=ServerConfig(), page_size=10)

    def test_invalid_config_values(self):
        with pytest.raises(ValueError):
            ServerConfig(selector_backend="gpu")
        with pytest.raises(ValueError):
            ServerConfig(page_size=0)
        with pytest.raises(ValueError):
            ServerConfig(max_mpr=0)

    def test_defaults_unchanged_without_warning(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            server = BrTPFServer(make_store())
        assert server.config == ServerConfig()

    def test_async_front_end_from_config(self):
        cfg = ServerConfig(page_size=13, max_mpr=7)
        front = AsyncBrTPFServer.from_config(make_store(), cfg)
        assert front.max_mpr == 7
        assert front.server.config == cfg


# ---------------------------------------------------------------------------
# ASGI app over the TestClient (tentpole + satellite 4)
# ---------------------------------------------------------------------------


CFG = ServerConfig(page_size=20, max_mpr=12)


@pytest.fixture()
def store():
    return make_store()


@pytest.fixture()
def client(store):
    with TestClient(app_from_config(store, CFG,
                                    batch_window_s=1e-3)) as tc:
        yield tc


class TestHttpApp:
    pytestmark = TIER1

    def test_service_description(self, client):
        resp = client.get("/")
        assert resp.status_code == 200
        desc = resp.json()
        assert desc["v"] == WIRE_VERSION
        assert desc["max_mpr"] == CFG.max_mpr
        assert "fragment" in desc["endpoints"]

    def test_post_fragment_byte_identical_to_inprocess(self, store,
                                                       client):
        oracle = BrTPFServer(store, config=CFG)
        for req in sample_requests(store, max_mpr=CFG.max_mpr):
            resp = client.post("/fragment", json_body=req.to_wire())
            assert resp.status_code == 200
            assert resp.headers["content-type"] == "application/json"
            assert resp.content == dumps(
                fragment_to_wire(oracle.handle(req)))

    def test_get_and_post_encodings_agree(self, store, client):
        for req in sample_requests(store, count=9, max_mpr=CFG.max_mpr):
            params = {"s": req.pattern.s, "p": req.pattern.p,
                      "o": req.pattern.o, "page": req.page}
            if req.omega is not None:
                params["omega"] = json.dumps(req.omega.tolist())
                params["omega_vars"] = req.omega.shape[1]
            get = client.get("/fragment", params=params)
            post = client.post("/fragment", json_body=req.to_wire())
            assert get.status_code == post.status_code == 200
            assert get.content == post.content

    def test_over_max_mpr_is_414(self, client):
        rng = np.random.default_rng(5)
        req = Request(pattern=TriplePattern(V(0), 2, V(1)),
                      omega=rand_omega(rng, CFG.max_mpr + 1))
        resp = client.post("/fragment", json_body=req.to_wire())
        assert resp.status_code == 414
        assert resp.json()["kind"] == "error"

    def test_malformed_body_is_400(self, client):
        env = request_to_wire(Request(pattern=TriplePattern(1, 2, 3)))
        env["v"] = "tpf/v9"
        assert client.post("/fragment", json_body=env).status_code == 400
        resp = client.request("GET", "/fragment",
                              params={"s": "1", "p": "x", "o": "3"})
        assert resp.status_code == 400

    def test_unknown_path_and_method(self, client):
        assert client.get("/nope").status_code == 404
        assert client.post("/metrics").status_code == 405
        assert client.request("DELETE", "/fragment").status_code == 405

    def test_metrics_same_schema_as_inprocess(self, store, client):
        for req in sample_requests(store, count=6, max_mpr=CFG.max_mpr):
            client.post("/fragment", json_body=req.to_wire())
        wire = client.get("/metrics").json()
        # ``routes`` is the one transport-only section (server-side
        # per-endpoint latency -- the in-process snapshot has no HTTP
        # routes); everything else must match byte-for-byte.
        routes = wire.pop("routes")
        assert isinstance(routes, dict)
        local = json.loads(dumps(client.app.backend.metrics_snapshot()))
        assert wire == local
        assert wire["v"] == WIRE_VERSION
        assert wire["counters"]["num_requests"] == 6
        assert "batch" in wire

    def test_metrics_per_route_latency_schema(self, store, client):
        for req in sample_requests(store, count=4, max_mpr=CFG.max_mpr):
            client.post("/fragment", json_body=req.to_wire())
        client.get("/")
        client.get("/metrics")
        routes = client.get("/metrics").json()["routes"]
        # routes recorded so far: description, fragment POSTs and the
        # previous /metrics call (a request records after responding,
        # so the in-flight GET /metrics is not in its own summary)
        assert set(routes) == {"GET /", "POST /fragment", "GET /metrics"}
        # schema stability: every route speaks the exact
        # latency_summary() schema, nothing more, nothing less
        expected_keys = set(latency_summary([]))
        for route, summary in routes.items():
            assert set(summary) == expected_keys, route
        assert routes["POST /fragment"]["requests"] == 4
        assert routes["GET /metrics"]["requests"] == 1
        frag = routes["POST /fragment"]
        assert 0.0 <= frag["p50_latency_ms"] <= frag["p95_latency_ms"] \
               <= frag["p99_latency_ms"]
        assert frag["req_per_s"] > 0.0
        # bounded state: unknown paths must not mint route labels
        client.get("/definitely-not-a-route")
        assert set(client.get("/metrics").json()["routes"]) \
               == {"GET /", "POST /fragment", "GET /metrics"}


@pytest.mark.parametrize("backend,extra", [
    pytest.param("numpy", {}, marks=TIER1),
    pytest.param("kernel", {"fast_path_rows": 4}, marks=TIER1),
    pytest.param("sharded", {"shard_window": 64}),
])
def test_http_parity_across_selector_backends(backend, extra):
    """The ISSUE's acceptance bar: HTTP fragments byte-identical to
    in-process ``handle`` on every selector backend."""
    store = make_store(seed=7)
    cfg = ServerConfig(page_size=25, max_mpr=16,
                       selector_backend=backend, **extra)
    oracle = BrTPFServer(store, config=cfg)
    with TestClient(app_from_config(store, cfg,
                                    batch_window_s=1e-3)) as tc:
        for req in sample_requests(store, seed=11, count=12,
                                   max_mpr=cfg.max_mpr):
            resp = tc.post("/fragment", json_body=req.to_wire())
            assert resp.status_code == 200
            assert resp.content == dumps(
                fragment_to_wire(oracle.handle(req)))


# ---------------------------------------------------------------------------
# Transport parity: loopback == ASGI == in-process oracle
# ---------------------------------------------------------------------------


class TestTransportParity:
    pytestmark = TIER1

    def _oracle(self, store, cfg):
        server = BrTPFServer(store, config=cfg)
        out = {}
        for name, bgp in small_workload():
            res = BrTPFClient(server, max_mpr=cfg.max_mpr).execute(bgp)
            out[name] = (canon(res.solutions), res.num_requests)
        return out

    def _run_transport(self, make_transport):
        async def main():
            transport = make_transport()
            try:
                out = {}
                client = AsyncBrTPFClient(transport)
                for name, bgp in small_workload():
                    res = await client.execute(bgp)
                    out[name] = (canon(res.solutions), res.num_requests)
                return out, await transport.metrics()
            finally:
                await transport.aclose()
        return asyncio.run(main())

    def test_loopback_and_asgi_match_oracle(self):
        store = make_store(seed=9)
        cfg = ServerConfig(page_size=30)
        expected = self._oracle(store, cfg)

        def loopback():
            return LoopbackTransport(AsyncBrTPFServer.from_config(
                store, cfg, batch_window_s=1e-3))

        def asgi():
            return AsgiTransport(app_from_config(store, cfg,
                                                 batch_window_s=1e-3))

        for factory in (loopback, asgi):
            got, metrics = self._run_transport(factory)
            assert set(got) == set(expected)
            for name in expected:
                sols, nreq = expected[name]
                assert np.array_equal(got[name][0], sols), name
                assert got[name][1] == nreq, name
            total = sum(nreq for _, nreq in expected.values())
            assert metrics["counters"]["num_requests"] == total
            # wire boundary charged the attached mappings exactly once
            assert metrics["counters"]["mappings_sent"] > 0

    def test_asgi_transport_maps_414(self):
        store = make_store()
        cfg = ServerConfig(max_mpr=4)

        async def main():
            transport = AsgiTransport(app_from_config(
                store, cfg, batch_window_s=1e-3))
            rng = np.random.default_rng(2)
            req = Request(pattern=TriplePattern(V(0), 2, V(1)),
                          omega=rand_omega(rng, 9))
            try:
                with pytest.raises(MaxMprExceeded):
                    await transport.handle(req)
            finally:
                await transport.aclose()
        asyncio.run(main())


# ---------------------------------------------------------------------------
# Replica router. The deterministic routing/affinity/parity tests are
# tier1 (tiny batching windows keep them fast); only the full
# ASGI-wrapped fleet test stays out of the fast gate.
# ---------------------------------------------------------------------------


class TestReplicaRouter:
    @TIER1
    def test_stable_replica_index_deterministic(self):
        tp = (V(0), 3, 7)
        assert stable_replica_index(tp, 4) == stable_replica_index(tp, 4)
        hits = {stable_replica_index((s, 2, V(0)), 4)
                for s in range(64)}
        assert len(hits) > 1  # patterns spread across the fleet

    @TIER1
    def test_pattern_affinity_pins_requests(self):
        store = make_store()
        router = ReplicaRouter(store, ServerConfig(), replicas=3,
                               batch_window_s=1e-3)
        req = Request(pattern=TriplePattern(V(0), 2, V(1)))
        idxs = {router.route(req) for _ in range(10)}
        assert len(idxs) == 1
        asyncio.run(router.aclose())

    @TIER1
    def test_round_robin_advances(self):
        store = make_store()
        router = ReplicaRouter(store, ServerConfig(), replicas=3,
                               policy="round_robin", batch_window_s=1e-3)
        req = Request(pattern=TriplePattern(V(0), 2, V(1)))
        assert [router.route(req) for _ in range(6)] == [0, 1, 2, 0, 1, 2]
        asyncio.run(router.aclose())

    @TIER1
    def test_invalid_construction(self):
        with pytest.raises(ValueError):
            ReplicaRouter(make_store(), replicas=0)
        with pytest.raises(ValueError):
            ReplicaRouter(make_store(), policy="sticky")

    @TIER1
    @pytest.mark.parametrize("policy", ["pattern", "round_robin"])
    def test_fleet_parity_and_merged_metrics(self, policy):
        store = make_store(seed=13)
        cfg = ServerConfig(page_size=30)
        oracle = BrTPFServer(store, config=cfg)
        reqs = sample_requests(store, seed=17, count=18,
                               max_mpr=cfg.max_mpr)
        expected = [dumps(fragment_to_wire(oracle.handle(r)))
                    for r in reqs]

        async def main():
            router = ReplicaRouter(store, cfg, replicas=3, policy=policy,
                                   batch_window_s=1e-3)
            try:
                frags = [await router.handle(r) for r in reqs]
                return frags, router.metrics_snapshot()
            finally:
                await router.aclose()

        frags, snap = asyncio.run(main())
        assert [dumps(fragment_to_wire(f)) for f in frags] == expected
        assert snap["counters"]["num_requests"] == len(reqs)
        assert snap["router"]["policy"] == policy
        assert snap["router"]["replicas"] == 3
        assert sum(snap["router"]["requests_per_replica"]) == len(reqs)
        assert len(snap["replicas"]) == 3
        per_replica = sum(s["counters"]["num_requests"]
                          for s in snap["replicas"])
        assert per_replica == len(reqs)

    def test_router_behind_asgi_app(self):
        store = make_store()
        cfg = ServerConfig(page_size=25)
        oracle = BrTPFServer(store, config=cfg)
        with TestClient(app_from_config(store, cfg, batch_window_s=1e-3,
                                        replicas=2)) as tc:
            for req in sample_requests(store, seed=19, count=8,
                                       max_mpr=cfg.max_mpr):
                resp = tc.post("/fragment", json_body=req.to_wire())
                assert resp.status_code == 200
                assert resp.content == dumps(
                    fragment_to_wire(oracle.handle(req)))
            snap = tc.get("/metrics").json()
            assert snap["router"]["replicas"] == 2


def test_create_app_wraps_existing_backend():
    store = make_store()
    front = AsyncBrTPFServer.from_config(store, ServerConfig(max_mpr=6),
                                         batch_window_s=1e-3)
    with TestClient(create_app(front)) as tc:
        assert tc.get("/").json()["max_mpr"] == 6
