"""Property-based tests (hypothesis) for brTPF system invariants."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (BGP, BrTPFClient, BrTPFServer, TriplePattern,
                        TripleStore, UNBOUND, brtpf_select, compatible,
                        encode_var, evaluate_bgp_reference, merge,
                        tpf_select)

MAX_TERMS = 9


@st.composite
def graphs(draw, max_triples=60):
    n = draw(st.integers(0, max_triples))
    rows = draw(st.lists(
        st.tuples(*[st.integers(0, MAX_TERMS - 1)] * 3),
        min_size=n, max_size=n))
    return np.asarray(rows, dtype=np.int32).reshape(-1, 3)


@st.composite
def patterns(draw, max_vars=3):
    comps = []
    for _ in range(3):
        if draw(st.booleans()):
            comps.append(encode_var(draw(st.integers(0, max_vars - 1))))
        else:
            comps.append(draw(st.integers(0, MAX_TERMS - 1)))
    return TriplePattern(*comps)


@st.composite
def omegas(draw, num_vars=3, max_rows=8):
    n = draw(st.integers(0, max_rows))
    rows = draw(st.lists(
        st.tuples(*[st.integers(-1, MAX_TERMS - 1)] * num_vars),
        min_size=n, max_size=n))
    return np.asarray(rows, dtype=np.int32).reshape(-1, num_vars)


@settings(max_examples=150, deadline=None)
@given(graphs(), patterns())
def test_tpf_select_sound_complete(triples, tp):
    store = TripleStore(triples)
    got = set(map(tuple, store.match(tp).tolist()))
    want = {tuple(t) for t in np.unique(triples, axis=0).reshape(-1, 3)
            .tolist() if tp.matches_triple(t)} if triples.size else set()
    assert got == want


@settings(max_examples=150, deadline=None)
@given(graphs(), patterns(), omegas())
def test_brtpf_subset_and_membership(triples, tp, omega):
    """Invariants straight from Definition 1:
    (i)  s_(tp, Omega)(G) is a subset of s_tp(G);
    (ii) every returned triple joins with some mapping in Omega;
    (iii) every TPF triple that joins with Omega is returned."""
    from repro.core import mapping_from_triple
    store = TripleStore(triples)
    br = set(map(tuple, brtpf_select(store, tp, omega).tolist()))
    tpf = set(map(tuple, tpf_select(store, tp).tolist()))
    assert br <= tpf
    nv = omega.shape[1]

    def joins(t):
        mu = mapping_from_triple(tp, np.asarray(t, np.int32), nv)
        if mu is None:
            return False
        return any(compatible(mu, row) for row in omega)

    if omega.shape[0] == 0:
        assert br == tpf
    else:
        for t in br:
            assert joins(t)
        for t in tpf - br:
            assert not joins(t)


@settings(max_examples=100, deadline=None)
@given(graphs(), patterns())
def test_cardinality_definition2(triples, tp):
    """cnt contract of Definition 2: cnt = 0 iff the fragment is empty,
    cnt > 0 otherwise (our backend is exact, so eps = 0)."""
    store = TripleStore(triples)
    cnt = store.cardinality(tp)
    n = store.match(tp).shape[0]
    assert (cnt == 0) == (n == 0)
    assert cnt == n


@settings(max_examples=100, deadline=None)
@given(graphs(max_triples=40), st.integers(1, 6), st.integers(2, 9))
def test_client_correct_for_random_star_joins(triples, max_mpr, page_size):
    """End-to-end: the brTPF client computes exactly the reference BGP
    result for star joins over random graphs, for any maxMpR/page size."""
    v = encode_var
    bgp = BGP((TriplePattern(v(0), 1, v(1)),
               TriplePattern(v(0), 2, v(2))), 3)
    store = TripleStore(triples)
    server = BrTPFServer(store, page_size=page_size, max_mpr=max_mpr)
    got = BrTPFClient(server, max_mpr=max_mpr).execute(bgp).solutions
    want = evaluate_bgp_reference(store.triples, bgp)
    assert np.array_equal(np.unique(got, axis=0).reshape(-1, 3),
                          want.reshape(-1, 3))


@settings(max_examples=200, deadline=None)
@given(omegas(), omegas())
def test_compatibility_symmetric_and_merge_consistent(a, b):
    for mu in a:
        for nu in b:
            assert compatible(mu, nu) == compatible(nu, mu)
            if compatible(mu, nu):
                m = merge(mu.copy(), nu)
                bound = m != UNBOUND
                # merge binds exactly the union of bound vars
                assert np.array_equal(
                    bound, (mu != UNBOUND) | (nu != UNBOUND))
