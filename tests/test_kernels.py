"""Pallas kernel tests: sweep shapes/dtypes, assert against ref.py oracles.

Kernels execute in interpret mode on CPU (the TPU lowering is the target;
interpret runs the same kernel body).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import bindjoin, compact_mask, pattern_vec_from, tpf_match
from repro.kernels import ref


def rand_triples(rng, t, terms=50):
    return rng.integers(0, terms, size=(t, 3)).astype(np.int32)


def rand_patterns(rng, m, terms=50, wild_frac=0.5):
    pats = rng.integers(0, terms, size=(m, 3)).astype(np.int32)
    wild = rng.random((m, 3)) < wild_frac
    pats[wild] = -1
    return pats


class TestBindJoin:
    @pytest.mark.parametrize("t", [1, 7, 100, 1024, 2500])
    @pytest.mark.parametrize("m", [1, 5, 30, 128, 200])
    def test_shape_sweep_vs_ref(self, t, m):
        rng = np.random.default_rng(t * 1000 + m)
        cand = rand_triples(rng, t)
        pats = rand_patterns(rng, m)
        valid = (rng.random(m) < 0.9).astype(np.int32)
        keep, idx = bindjoin(jnp.asarray(cand), jnp.asarray(pats),
                             jnp.asarray(valid))
        # oracle on the same (padded) problem, cropped
        ref_keep, ref_idx = ref.bindjoin_ref(
            jnp.asarray(cand[:, 0]), jnp.asarray(cand[:, 1]),
            jnp.asarray(cand[:, 2]), jnp.asarray(pats[:, 0]),
            jnp.asarray(pats[:, 1]), jnp.asarray(pats[:, 2]),
            jnp.asarray(valid))
        np.testing.assert_array_equal(np.asarray(keep), np.asarray(ref_keep))
        # idx agrees wherever a match exists (no-match sentinel differs
        # only by padding amount).
        has = np.asarray(ref_keep)
        np.testing.assert_array_equal(np.asarray(idx)[has],
                                      np.asarray(ref_idx)[has])

    @pytest.mark.parametrize("bt,bm", [(256, 128), (1024, 128), (512, 256)])
    def test_block_shape_sweep(self, bt, bm):
        rng = np.random.default_rng(bt + bm)
        cand = rand_triples(rng, 3000, terms=20)
        pats = rand_patterns(rng, 300, terms=20)
        valid = np.ones(300, np.int32)
        keep, _ = bindjoin(jnp.asarray(cand), jnp.asarray(pats),
                           jnp.asarray(valid), bt=bt, bm=bm)
        ref_keep, _ = ref.bindjoin_ref(
            *(jnp.asarray(cand[:, i]) for i in range(3)),
            *(jnp.asarray(pats[:, i]) for i in range(3)),
            jnp.asarray(valid))
        np.testing.assert_array_equal(np.asarray(keep), np.asarray(ref_keep))

    def test_all_invalid_patterns_match_nothing(self):
        cand = jnp.zeros((64, 3), jnp.int32)
        pats = jnp.full((8, 3), -1, jnp.int32)  # all-wildcard
        keep, _ = bindjoin(cand, pats, jnp.zeros((8,), jnp.int32))
        assert not bool(keep.any())

    def test_all_wildcard_pattern_matches_everything(self):
        rng = np.random.default_rng(0)
        cand = jnp.asarray(rand_triples(rng, 333))
        pats = jnp.full((1, 3), -1, jnp.int32)
        keep, idx = bindjoin(cand, pats, jnp.ones((1,), jnp.int32))
        assert bool(keep.all())
        assert int(idx.max()) == 0

class TestTpfMatch:
    @pytest.mark.parametrize("t", [1, 100, 32768, 40000])
    @pytest.mark.parametrize("pat", [
        (-1, -1, -1, 0, 0, 0),
        (3, -1, -1, 0, 0, 0),
        (-1, 2, 7, 0, 0, 0),
        (1, 2, 3, 0, 0, 0),
        (-1, -1, -1, 0, 1, 0),   # s == o (repeated variable)
        (-1, 4, -1, 1, 0, 1),
    ])
    def test_sweep_vs_ref(self, t, pat):
        rng = np.random.default_rng(abs(hash(pat)) % 2**32 + t)
        cand = rand_triples(rng, t, terms=9)
        vec = pattern_vec_from(pat[:3], *pat[3:])
        mask = tpf_match(jnp.asarray(cand), jnp.asarray(vec))
        want = ref.tpf_match_ref(
            *(jnp.asarray(cand[:, i]) for i in range(3)),
            jnp.asarray(vec))
        np.testing.assert_array_equal(np.asarray(mask), np.asarray(want))

    def test_matches_store_semantics(self):
        """Kernel agrees with the host TripleStore matcher."""
        from repro.core import TriplePattern, TripleStore, encode_var
        rng = np.random.default_rng(11)
        triples = np.unique(rand_triples(rng, 500, terms=12), axis=0)
        store = TripleStore(triples)
        V = encode_var
        cases = [TriplePattern(V(0), 5, V(1)),
                 TriplePattern(V(0), 5, V(0)),
                 TriplePattern(2, V(0), V(1)),
                 TriplePattern(V(0), V(1), V(2))]
        for tp in cases:
            comps = tp.as_tuple()
            eq_so = int(comps[0] < 0 and comps[0] == comps[2])
            eq_sp = int(comps[0] < 0 and comps[0] == comps[1])
            eq_po = int(comps[1] < 0 and comps[1] == comps[2])
            vec = pattern_vec_from(
                tuple(-1 if c < 0 else c for c in comps),
                eq_sp, eq_so, eq_po)
            mask = np.asarray(tpf_match(jnp.asarray(store.triples),
                                        jnp.asarray(vec)))
            got = store.triples[mask]
            want = store.match(tp)
            assert (set(map(tuple, got.tolist()))
                    == set(map(tuple, want.tolist()))), tp


class TestCompact:
    @pytest.mark.parametrize("n,cap", [(10, 4), (100, 100), (7, 16)])
    def test_compact(self, n, cap):
        rng = np.random.default_rng(n + cap)
        mask = jnp.asarray(rng.random(n) < 0.3)
        idx, count = compact_mask(mask, cap)
        want = np.nonzero(np.asarray(mask))[0]
        assert int(count) == want.shape[0]
        take = min(cap, want.shape[0])
        np.testing.assert_array_equal(np.asarray(idx)[:take], want[:take])
        assert all(int(i) == -1 for i in np.asarray(idx)[take:])
