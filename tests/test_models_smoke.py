"""Per-architecture smoke tests: reduced config, one forward + train step
on CPU, asserting output shapes and no NaNs (per the brief)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, reduced_for_smoke
from repro.models.model import build_model

ARCHS = sorted(all_archs().keys())


def _batch(cfg, b=2, s=8, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    tokens = jax.random.randint(ks[0], (b, s + 1), 0, cfg.vocab_size)
    batch = {"tokens": tokens[:, :-1], "targets": tokens[:, 1:]}
    if cfg.encoder_layers:
        batch["enc_input"] = jax.random.normal(
            ks[1], (b, 5, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced_for_smoke(all_archs()[arch])
    model = build_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))
    # axes tree mirrors params
    jax.tree.map(lambda p, a: None, params,
                 jax.tree.map(lambda a: a, axes,
                              is_leaf=lambda a: a is None
                              or isinstance(a, tuple)))
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch["tokens"],
                                batch.get("enc_input"))
    b, s = batch["tokens"].shape
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite moe aux"


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    """One SGD step must produce finite loss and finite updated params."""
    cfg = reduced_for_smoke(all_archs()[arch])
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    def loss_fn(p):
        loss, _ = model.loss(p, batch)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
    finite = jax.tree.map(lambda p: bool(jnp.isfinite(p).all()),
                          new_params)
    assert all(jax.tree.leaves(finite)), f"{arch}: non-finite update"
    # and the loss is a plausible cross-entropy for random init
    assert 0.0 < float(loss) < 3.0 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg = reduced_for_smoke(all_archs()[arch])
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    b = 2
    cache, cache_axes = model.init_cache(b, 16)
    token = jnp.zeros((b, 1), jnp.int32)
    enc_out = None
    if cfg.encoder_layers:
        enc_input = jax.random.normal(jax.random.PRNGKey(2),
                                      (b, 5, cfg.d_model), jnp.float32)
        enc_out = model.encode(params, enc_input)
    logits, new_cache = model.decode_step(params, cache, token,
                                          jnp.int32(0), enc_out=enc_out)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # cache structure is preserved
    assert (jax.tree.structure(new_cache)
            == jax.tree.structure(cache))
