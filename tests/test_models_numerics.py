"""Numerical correctness of the model building blocks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, reduced_for_smoke
from repro.models import rwkv as R
from repro.models.model import build_model


class TestWKV:
    @pytest.mark.parametrize("chunk", [1, 4, 8, 16])
    @pytest.mark.parametrize("s", [8, 16, 33])
    def test_chunked_matches_reference(self, chunk, s):
        rng = np.random.default_rng(chunk * 100 + s)
        b, h, n = 2, 3, 4
        r, k, v = (jnp.asarray(rng.normal(size=(b, s, h, n)),
                               jnp.float32) for _ in range(3))
        logw = jnp.asarray(-np.abs(rng.normal(size=(b, s, h, n))) - 0.01,
                           jnp.float32)
        u = jnp.asarray(rng.normal(size=(h, n)), jnp.float32)
        o_ref, st_ref = R.wkv_reference(r, k, v, logw, u)
        o_chk, st_chk = R.wkv_chunked(r, k, v, logw, u, chunk)
        np.testing.assert_allclose(np.asarray(o_chk), np.asarray(o_ref),
                                   rtol=2e-4, atol=2e-4)
        if s % chunk == 0:  # padded tail changes the final state
            np.testing.assert_allclose(np.asarray(st_chk),
                                       np.asarray(st_ref),
                                       rtol=2e-4, atol=2e-4)

    def test_step_matches_reference(self):
        rng = np.random.default_rng(0)
        b, s, h, n = 1, 6, 2, 4
        r, k, v = (jnp.asarray(rng.normal(size=(b, s, h, n)),
                               jnp.float32) for _ in range(3))
        logw = jnp.asarray(-np.abs(rng.normal(size=(b, s, h, n))) - 0.01,
                           jnp.float32)
        u = jnp.asarray(rng.normal(size=(h, n)), jnp.float32)
        o_ref, st_ref = R.wkv_reference(r, k, v, logw, u)
        state = jnp.zeros((b, h, n, n), jnp.float32)
        outs = []
        for t in range(s):
            o, state = R.wkv_step(r[:, t], k[:, t], v[:, t], logw[:, t],
                                  u, state)
            outs.append(o)
        o_seq = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(o_seq), np.asarray(o_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(state), np.asarray(st_ref),
                                   rtol=1e-4, atol=1e-4)


def _smoke_cfg(name):
    return reduced_for_smoke(all_archs()[name])


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "rwkv6-7b",
                                  "jamba-1.5-large-398b", "olmoe-1b-7b"])
def test_decode_matches_forward(arch):
    """Greedy decode step-by-step must reproduce the full forward pass
    (teacher forcing) -- validates every cache path."""
    cfg = _smoke_cfg(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params, _ = model.init(key)
    b, s = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size)
    full_logits, _ = model.forward(params, tokens)

    cache, _ = model.init_cache(b, s)
    step_logits = []
    for t in range(s):
        lg, cache = model.decode_step(params, cache, tokens[:, t:t + 1],
                                      jnp.int32(t))
        step_logits.append(lg[:, 0])
    step_logits = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "rwkv6-7b",
                                  "jamba-1.5-large-398b"])
def test_prefill_then_decode_matches_forward(arch):
    """prefill(prompt) + decode steps == forward over the whole sequence:
    validates the cache-seeding path used by the serving engine."""
    cfg = _smoke_cfg(arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    b, s_prompt, s_total = 2, 5, 9
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s_total), 0,
                                cfg.vocab_size)
    full_logits, _ = model.forward(params, tokens)

    last, cache = model.prefill(params, tokens[:, :s_prompt],
                                max_seq=s_total)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full_logits[:, s_prompt - 1]),
                               rtol=2e-3, atol=2e-3)
    for t in range(s_prompt, s_total):
        lg, cache = model.decode_step(params, cache, tokens[:, t:t + 1],
                                      jnp.int32(t))
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full_logits[:, t]),
                                   rtol=2e-3, atol=2e-3)


def test_encdec_decode_matches_forward():
    cfg = _smoke_cfg("seamless-m4t-medium")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    b, s, f = 2, 6, 5
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size)
    enc_input = jax.random.normal(jax.random.PRNGKey(2),
                                  (b, f, cfg.d_model), jnp.float32)
    full_logits, _ = model.forward(params, tokens, enc_input)

    enc_out = model.encode(params, enc_input)
    cache, _ = model.init_cache(b, s)
    outs = []
    for t in range(s):
        lg, cache = model.decode_step(params, cache, tokens[:, t:t + 1],
                                      jnp.int32(t), enc_out=enc_out)
        outs.append(lg[:, 0])
    step_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_moe_gather_matches_einsum_dispatch():
    """With drop-free capacity, gather- and einsum-based MoE dispatch
    compute identical outputs."""
    from repro.models import moe as MOE

    cfg = _smoke_cfg("olmoe-1b-7b")  # capacity_factor=4 -> no drops
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    blk = jax.tree.map(lambda p: p[0],
                       params["stack"]["pos0"]["ffn"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32)
    out_e, aux_e = MOE._moe_ffn_einsum(blk, x, cfg)
    out_g, aux_g = MOE.moe_ffn_gather(blk, x, cfg)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_e),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_g), float(aux_e), rtol=1e-5)
