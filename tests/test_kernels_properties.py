"""Property-based (hypothesis) kernel tests, split from test_kernels.py
so the non-property kernel tests stay collectible when hypothesis is not
installed in the environment."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import bindjoin  # noqa: E402

from test_kernels import rand_patterns, rand_triples  # noqa: E402


class TestBindJoinProperties:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 60), st.integers(1, 20), st.integers(0, 2**31 - 1))
    def test_property_matches_oracle(self, t, m, seed):
        rng = np.random.default_rng(seed)
        cand = rand_triples(rng, t, terms=6)
        pats = rand_patterns(rng, m, terms=6, wild_frac=0.6)
        valid = np.ones(m, np.int32)
        keep, _ = bindjoin(jnp.asarray(cand), jnp.asarray(pats),
                           jnp.asarray(valid))
        want = np.zeros(t, bool)
        for i, c in enumerate(cand):
            for pm in pats:
                ok = all(pm[k] < 0 or pm[k] == c[k] for k in range(3))
                want[i] |= ok
        np.testing.assert_array_equal(np.asarray(keep), want)
